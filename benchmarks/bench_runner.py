"""Fault-tolerant runner overhead + chaos smoke (DESIGN.md §10).

Runs ``SimulationRunner`` under injected faults — one simulated
preemption and one NaN poisoning — on whatever devices exist (CI sets 4
host devices), ASSERTS full recovery (the resumed run must finish with a
clean health verdict and the expected rollback/restart counts), and
measures the checkpoint save/restore wall-times the runner adds per
interval. With ``--smoke`` writes ``BENCH_runner_smoke.json`` for the
regression gate (rule ``*_ms_per_ckpt``), otherwise ``BENCH_runner.json``
(the committed baseline); the report carries the lifecycle counters
through the ``repro.telemetry/v1`` schema.
"""
import os
import sys
import tempfile
import time

from benchmarks._util import ROOT, emit


def _timed_ms(fn, iters=3):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def main():
    smoke = "--smoke" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    n = int(args[0]) if args else (64 if smoke else 256)
    import jax
    from repro import telemetry
    from repro.configs.msp_brain import BrainConfig
    from repro.runtime import chaos
    from repro.runtime.sim_runner import SimRunnerConfig, SimulationRunner

    r = len(jax.devices())
    cfg = BrainConfig(neurons_per_rank=n, local_levels=3, frontier_cap=32,
                      max_synapses=8, rate_period=10,
                      requests_cap_factor=100, subs_cap_factor=100)
    chunks = 4
    metrics = {}
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ck")
        # ---- chaos smoke: poison once, then preempt; a fresh runner
        # must resume and finish with a clean verdict ------------------
        with telemetry.span("bench.runner.chaos", n=n):
            runner = SimulationRunner(SimRunnerConfig(ck, ckpt_every=1),
                                      cfg=cfg)
            runner.chaos_hooks.append(
                chaos.poison_nan_once(field="v", after_chunk=1))
            runner.chaos_hooks.append(chaos.preempt_after(2))
            status = runner.run(chunks)
            assert status == "preempted", status
            assert runner.sim.lifecycle["rollbacks"] >= 1, \
                "NaN poisoning did not trigger a rollback"
            resumed = SimulationRunner(SimRunnerConfig(ck, ckpt_every=1),
                                       cfg=cfg)
            assert resumed.run(
                chunks - int(jax.device_get(
                    resumed.sim.state.chunk))) == "done"
            sim = resumed.sim
            assert int(jax.device_get(sim.state.chunk)) == chunks
            assert sim.health()["health_flags"] == 0, "unclean recovery"
            assert sim.lifecycle["restarts"] >= 1
        lifecycle = dict(sim.lifecycle)

        # ---- checkpoint save/restore wall time per interval ----------
        ck2 = os.path.join(d, "ck2")
        metrics["save_ms_per_ckpt"] = _timed_ms(lambda: sim.save(ck2))
        metrics["restore_ms_per_ckpt"] = _timed_ms(
            lambda: sim.restore(ck2))
        metrics["probe_ms_per_ckpt"] = _timed_ms(
            lambda: sim.probe_health())

    emit(f"runner_save_r{r}_n{n}", metrics["save_ms_per_ckpt"] * 1e3,
         f"restore_ms={metrics['restore_ms_per_ckpt']:.1f}")
    emit(f"runner_chaos_r{r}_n{n}", 0.0,
         f"rollbacks={lifecycle['rollbacks']} "
         f"restarts={lifecycle['restarts']}")
    params = {"num_ranks": r, "n_per_rank": n, "chunks": chunks}
    rep = telemetry.report.make_report(
        "runner", {f"r{r}_n{n}": telemetry.report.case(params, metrics)},
        smoke=smoke,
        mesh={"num_ranks": r, "backend": jax.default_backend()},
        counters=telemetry.report.counters_block(sim.metrics()),
        spans=telemetry.export(),
        lifecycle=lifecycle)
    out = "BENCH_runner_smoke.json" if smoke else "BENCH_runner.json"
    telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
