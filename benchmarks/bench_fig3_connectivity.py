"""Paper Fig. 3: connectivity-update time, old vs location-aware Barnes-Hut.
Weak scaling over rank counts (reduced CPU scale). Run by benchmarks.run in
subprocesses with varying host-device counts; directly runnable too:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src:. python -m benchmarks.bench_fig3_connectivity 256
"""
import sys

from benchmarks._util import brain_sim, emit


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    import jax
    r = len(jax.devices())
    times = {}
    for alg in ("old", "new"):
        # rate_period=10 so the chunk is dominated by the connectivity update;
        # cap_factor=1 keeps new's padded request slots == old's searcher count
        dt, st = brain_sim(dict(
            neurons_per_rank=n, local_levels=3, frontier_cap=32,
            max_synapses=16, connectivity_alg=alg, spike_alg="new",
            rate_period=10, requests_cap_factor=1), chunks=2)
        times[alg] = dt
    speedup = times["old"] / times["new"]
    emit(f"fig3_connectivity_old_r{r}_n{n}", times["old"] * 1e6)
    emit(f"fig3_connectivity_new_r{r}_n{n}", times["new"] * 1e6,
         f"speedup={speedup:.2f}x")


if __name__ == "__main__":
    main()
