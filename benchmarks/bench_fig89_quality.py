"""Paper Figs. 8/9: quality of the rate approximation — 32 neurons, target
calcium 0.7, growth 1e-3, N(5,1) background (paper §V-D setup), comparing old
(exact spikes) vs new (rate) transmission. Reports calcium median/IQR at
checkpoints. Default 60k steps (600 chunks); --full for the paper's 200k."""
import sys

import numpy as np

from benchmarks._util import emit


def main():
    full = "--full" in sys.argv
    chunks = 2000 if full else 600
    import dataclasses
    import jax
    from repro.configs.msp_brain import BrainConfig
    from repro.sim import Simulator

    ndev = len(jax.devices())
    # paper: 32 neurons SPREAD ACROSS RANKS (one per rank at 32 ranks) so the
    # rate approximation is fully exercised; here 32 total over ndev ranks
    base = BrainConfig(neurons_per_rank=max(32 // ndev, 1), local_levels=3,
                       frontier_cap=32, max_synapses=32,
                       fraction_excitatory=1.0, requests_cap_factor=64)
    marks = [chunks // 4, chunks // 2, 3 * chunks // 4, chunks]
    for alg in ("old", "new"):
        cfg = dataclasses.replace(base, spike_alg=alg)
        sim = Simulator.from_config(cfg)
        for i in range(1, chunks + 1):
            st = sim.step()
            if i in marks:
                ca = np.asarray(st.neurons.calcium)
                q1, med, q3 = np.percentile(ca, [25, 50, 75])
                syn = float((st.in_edges >= 0).sum()) / 32
                emit(f"fig89_calcium_{alg}_step{i * 100}", med * 1e6,
                     f"iqr={q3 - q1:.3f};syn_per_neuron={syn:.1f}")

    # function next to the calcium-approximation quality: the engram
    # pattern-completion workload (workloads.engram, DESIGN.md §13) —
    # recall overlap on the rate-based transmission the figure evaluates
    from repro.workloads import engram as weng
    m, _ = weng.run_engram()
    emit("fig89_engram_recall", m["recall_overlap"] * 1e6,
         f"selectivity={m['engram_selectivity']:.3f};"
         f"background={m['background_activation']:.3f}")


if __name__ == "__main__":
    main()
