"""Beyond-paper: the paper's principle in the LM framework — MoE dispatch
strategy (move_data vs move_compute vs auto) measured two ways: HLO collective
wire bytes (the roofline parser) and wall time on 8 host devices."""
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.launch import roofline as rl
from repro.compat import make_mesh


def main():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.parallel import sharding as shd
    ndev = len(jax.devices())
    da = max(ndev // 4, 1)
    mesh = make_mesh((da, ndev // da), ("data", "model"))
    cfg0 = get_smoke_config("moonshot-v1-16b-a3b").replace(scan_layers=True)
    params = build_model(cfg0).init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 128),
                                          0, 512)}
    for strat in ("move_data", "move_compute", "auto"):
        cfg = cfg0.replace(parallel=cfg0.parallel.replace(moe_strategy=strat))
        api = build_model(cfg)

        def step(p, b):
            with shd.use_mesh(mesh):
                return api.loss(p, b, mesh)[0]

        jitted = jax.jit(step)
        compiled = jitted.lower(params, batch).compile()
        ana = rl.analyze_hlo(compiled.as_text(), ndev)
        t, _ = time_fn(jitted, params, batch, iters=3)
        emit(f"lm_moe_{strat}_d{ndev}", t * 1e6,
             f"coll_wire_MB={ana['collective_bytes_total'] / 1e6:.1f}")


if __name__ == "__main__":
    main()
