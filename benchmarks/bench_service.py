"""Multi-tenant service throughput + isolation smoke (DESIGN.md §12).

Per tenant count B: spins up a ``SimulationService`` over a shared
compiled ``SlotBatch``, drives B same-budget tenants to completion, and
measures

  * ``requests_per_s``        completed requests / steady wall time;
  * ``p50_us_per_chunk`` /    per-tick (== per-chunk-boundary) service
    ``p99_us_per_chunk``      latency distribution, compile tick excluded;
  * ``isolation_overhead_x``  per-tenant chunk wall time vs a solo
                              ``Simulator`` chunk — the price of
                              co-tenancy (vmapped lanes + per-slot
                              verdicts + host bookkeeping).

Then the chaos smoke: B=4 tenants with one NaN-poisoned via
``chaos.poison_slot_nan`` — ASSERTS the poisoned slot quarantines + rolls
back and every tenant still completes (the bit-identity proof lives in
tests/test_service.py; the bench gate only needs recovery + counts).

With ``--smoke`` writes ``BENCH_service_smoke.json`` for the regression
gate (rules ``requests_per_s``, ``isolation_overhead_x``,
``*_us_per_*``), otherwise ``BENCH_service.json`` — the committed
baseline, which includes the smoke-scale cases so the gate pairs by
exact name at matched params (same reasoning as bench_connectivity).
"""
import os
import sys
import time

import numpy as np

from benchmarks._util import ROOT, emit


def _drive(svc, handles):
    """Tick to idle; returns (compile_ms, tick_times_s) with the first
    (trace+compile) tick split out of the steady distribution."""
    t0 = time.perf_counter()
    more = svc.tick()
    compile_ms = (time.perf_counter() - t0) * 1e3
    ticks = []
    while more:
        t0 = time.perf_counter()
        more = svc.tick()
        ticks.append(time.perf_counter() - t0)
    assert all(h.result is not None for h in handles)
    return compile_ms, ticks


def _bench_case(cfg, batch, tenants, chunks, solo_us):
    from repro.service import ServiceConfig, SimRequest, SimulationService
    svc = SimulationService(
        cfg, ServiceConfig(num_slots=tenants, queue_cap=2 * tenants),
        batch=batch)
    handles = [svc.submit(SimRequest(seed=100 + i, chunks=chunks))
               for i in range(tenants)]
    compile_ms, ticks = _drive(svc, handles)
    assert svc.stats()["requests_completed"] == tenants
    tick_us = np.array(ticks) * 1e6
    metrics = {
        "compile_ms": compile_ms,
        "requests_per_s": tenants / max(sum(ticks), 1e-9),
        "p50_us_per_chunk": float(np.percentile(tick_us, 50)),
        "p99_us_per_chunk": float(np.percentile(tick_us, 99)),
        "isolation_overhead_x":
            float(np.percentile(tick_us, 50)) / tenants / solo_us,
    }
    return metrics


def _solo_us_per_chunk(cfg, chunks):
    """Steady per-chunk wall time of a solo Simulator (the denominator
    of isolation_overhead_x)."""
    from repro.sim import Simulator
    sim = Simulator(cfg)
    sim.run(1)                        # compile
    best = float("inf")
    for _ in range(chunks):
        t0 = time.perf_counter()
        sim.run(1)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _chaos_smoke(cfg, batch):
    """One poisoned tenant among 4: assert quarantine + rollback + full
    recovery. Returns (service stats, handles) for the report."""
    from repro import telemetry
    from repro.runtime import chaos
    from repro.service import (RequestStatus, ServiceConfig, SimRequest,
                               SimulationService)
    with telemetry.span("bench.service.chaos", tenants=4):
        svc = SimulationService(cfg, ServiceConfig(num_slots=4),
                                batch=batch)
        svc.chaos_hooks.append(chaos.poison_slot_nan(1, after_chunk=1))
        handles = [svc.submit(SimRequest(seed=200 + i, chunks=3))
                   for i in range(4)]
        svc.run_until_idle()
        stats = svc.stats()
        assert stats["quarantines"] >= 1, \
            "slot poisoning did not trigger a quarantine"
        assert stats["slot_rollbacks"] >= 1, \
            "quarantine did not roll the slot back"
        assert all(h.result.status is RequestStatus.DONE
                   for h in handles), "a tenant failed to recover"
    return stats, handles


def main():
    smoke = "--smoke" in sys.argv
    import jax
    from repro import telemetry
    from repro.configs.msp_brain import BrainConfig
    from repro.service import SlotBatch

    r = len(jax.devices())
    # smoke-scale cases always run (the committed baseline carries them
    # too, so the gate pairs by exact name at matched params); the full
    # run adds a larger-n case for the record
    sizes = [(32, 3, (2, 4))]
    if not smoke:
        sizes.append((64, 4, (4,)))

    cases, chaos_stats, chaos_handles = {}, None, None
    for n, chunks, tenant_counts in sizes:
        cfg = BrainConfig(neurons_per_rank=n, local_levels=3,
                          frontier_cap=32, max_synapses=8, rate_period=10,
                          requests_cap_factor=100, subs_cap_factor=100)
        solo_us = _solo_us_per_chunk(cfg, chunks)
        for b in tenant_counts:
            batch = SlotBatch(cfg, b)
            with telemetry.span("bench.service.case", tenants=b, n=n):
                m = _bench_case(cfg, batch, b, chunks, solo_us)
            m["solo_us_per_chunk"] = solo_us
            cases[f"b{b}_r{r}_n{n}"] = telemetry.report.case(
                {"tenants": b, "num_ranks": r, "n_per_rank": n,
                 "chunks": chunks}, m)
            emit(f"service_b{b}_r{r}_n{n}", m["p50_us_per_chunk"],
                 f"req_per_s={m['requests_per_s']:.2f} "
                 f"overhead_x={m['isolation_overhead_x']:.2f}")
            if b == 4 and n == 32:
                chaos_stats, chaos_handles = _chaos_smoke(cfg, batch)

    rep = telemetry.report.make_report(
        "service", cases, smoke=smoke,
        mesh={"num_ranks": r, "backend": jax.default_backend()},
        spans=telemetry.export(),
        service=telemetry.report.service_block(chaos_stats,
                                               chaos_handles))
    out = "BENCH_service_smoke.json" if smoke else "BENCH_service.json"
    telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
