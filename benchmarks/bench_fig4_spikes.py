"""Paper Fig. 4: spike-transmission cost. Two sweeps:

  * spike_alg old vs new — per-step spiked-ID exchange vs Delta-periodic
    rate exchange (the chunk is dominated by the activity phase);
  * rate_exchange dense vs sparse (spike_alg='new') — the replicated (R, n)
    rates all-gather vs the demand-driven subscription push (DESIGN.md §7),
    with the measured exchanged-rate-record counters next to wall time.

Exchange volume comes from ``stats['rates_sent']`` (rate records actually
shipped: dense = n*(R-1) per rank per Delta, sparse = the subscribed
pushes), so the byte drop R*n*4 -> |subs|*4 is measured, not modeled. The
sparse exchange additionally ships one 4B subscription-request id per
pushed rate (``stats['subscription_requests']``) — reported separately and
folded into ``total_bytes_ratio`` so the sparse win is not overstated.

Writes a ``repro.telemetry/v1`` report — device counters/histograms of the
sparse run and host-side spans included — with ``--json`` to
``BENCH_spikes.json`` at the repo root (the recorded perf-trajectory
baseline: r=4, n=1024); ``--smoke`` runs a small n for CI and writes
``BENCH_spikes_smoke.json`` instead, so reproducing the CI step locally
cannot clobber the committed baseline. Compile (warmup chunk) and
steady-state per-chunk time are reported separately.
"""
import os
import sys

from benchmarks._util import PAPER_BYTES, ROOT, brain_sim_timed, emit


def bench(n, chunks=2):
    import jax
    import numpy as np
    from repro import telemetry
    from repro.core.spikes import NO_SUB
    r = len(jax.devices())
    base = dict(neurons_per_rank=n, local_levels=3, frontier_cap=32,
                max_synapses=16, connectivity_alg="new", rate_period=100,
                requests_cap_factor=max(r, 4), subs_cap_factor=max(r, 4))
    runs = {"old": dict(base, spike_alg="old"),
            "dense": dict(base, rate_exchange="dense"),
            "sparse": dict(base, rate_exchange="sparse")}
    sims, metrics = {}, {}
    for name, cfg in runs.items():
        with telemetry.span(f"bench.spikes.{name}", n=n):
            timing, sims[name] = brain_sim_timed(cfg, chunks=chunks)
        metrics[f"{name}_compile_ms"] = timing.compile_ms
        metrics[f"{name}_steady_us_per_chunk"] = timing.steady_us

    chunks_total = chunks + 1   # the warmup chunk also accumulates
    states = {name: sim.state for name, sim in sims.items()}
    for name in ("dense", "sparse"):
        sent = float(states[name].stats["rates_sent"].sum())
        metrics[f"{name}_rate_records_per_delta"] = sent / chunks_total
        metrics[f"{name}_rate_bytes_per_delta"] = \
            sent / chunks_total * PAPER_BYTES["rate"]
    subs = np.asarray(states["sparse"].subs)
    metrics["subs_per_rank_mean"] = float((subs != NO_SUB).sum()) / r
    metrics["dense_table_bytes_per_rank"] = r * n * PAPER_BYTES["rate"]
    metrics["subscription_overflow"] = \
        float(states["sparse"].stats["subscription_overflow"].sum())
    # the 4B request ids shipped alongside the pushed rates (dense: none)
    reqs = float(states["sparse"].stats["subscription_requests"].sum())
    metrics["sparse_request_bytes_per_delta"] = \
        reqs / chunks_total * PAPER_BYTES["rate"]
    metrics["rate_bytes_ratio"] = metrics["dense_rate_bytes_per_delta"] / \
        max(metrics["sparse_rate_bytes_per_delta"], 1.0)
    metrics["total_bytes_ratio"] = metrics["dense_rate_bytes_per_delta"] / \
        max(metrics["sparse_rate_bytes_per_delta"]
            + metrics["sparse_request_bytes_per_delta"], 1.0)
    # the whole point: the push must ship strictly less than the broadcast
    if r > 1:
        assert metrics["total_bytes_ratio"] > 1.0, metrics["total_bytes_ratio"]
    params = {"num_ranks": r, "n_per_rank": n,
              "delta": base["rate_period"], "chunks": chunks_total}
    return params, metrics, sims["sparse"].metrics()


def bench_connectome(n, chunks=2):
    """The dense-vs-sparse exchange sweep on a generated hemibrain-shaped
    surrogate (``--connectome``): the same byte counters, but measured on
    a heavy-tailed degree distribution through ``from_connectome`` (whose
    sparse registry is sized from the measured unique-remote-source
    count, not the near-uniform synthetic default). CSV-only — the
    surrogate's subscription footprint is not comparable to the
    committed synthetic baseline, so it is reported, not gated."""
    import time

    import jax
    import numpy as np
    from repro import telemetry
    from repro.configs.msp_brain import BrainConfig
    from repro.core.spikes import NO_SUB
    from repro.sim import Simulator
    from repro.workloads import datasets as wds
    r = len(jax.devices())
    base = dict(neurons_per_rank=n, local_levels=3, frontier_cap=32,
                max_synapses=16, connectivity_alg="new", rate_period=100,
                requests_cap_factor=max(r, 4), subs_cap_factor=max(r, 4))
    ds = wds.generate_hemibrain_surrogate(r * n, n,
                                          max_degree=base["max_synapses"])
    metrics = {"edges": float(ds.num_edges),
               "max_out_degree": float(ds.out_degrees().max())}
    states = {}
    for name in ("dense", "sparse"):
        cfg = BrainConfig(**dict(base, rate_exchange=name))
        with telemetry.span(f"bench.spikes.conn.{name}", n=n):
            sim = Simulator.from_connectome(cfg, ds)
            t0 = time.perf_counter()
            st = sim.step()
            jax.block_until_ready(st.positions)
            metrics[f"{name}_compile_ms"] = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            for _ in range(chunks):
                st = sim.step()
            jax.block_until_ready(st.positions)
            metrics[f"{name}_steady_us_per_chunk"] = \
                (time.perf_counter() - t0) / chunks * 1e6
        states[name] = sim.state
    chunks_total = chunks + 1
    for name in ("dense", "sparse"):
        sent = float(states[name].stats["rates_sent"].sum())
        metrics[f"{name}_rate_bytes_per_delta"] = \
            sent / chunks_total * PAPER_BYTES["rate"]
    subs = np.asarray(states["sparse"].subs)
    metrics["subs_per_rank_mean"] = float((subs != NO_SUB).sum()) / r
    metrics["subscription_overflow"] = \
        float(states["sparse"].stats["subscription_overflow"].sum())
    reqs = float(states["sparse"].stats["subscription_requests"].sum())
    metrics["sparse_request_bytes_per_delta"] = \
        reqs / chunks_total * PAPER_BYTES["rate"]
    metrics["total_bytes_ratio"] = metrics["dense_rate_bytes_per_delta"] / \
        max(metrics["sparse_rate_bytes_per_delta"]
            + metrics["sparse_request_bytes_per_delta"], 1.0)
    emit(f"fig4_spikes_conn_dense_r{r}_n{n}",
         metrics["dense_steady_us_per_chunk"],
         f"rateB/Delta={metrics['dense_rate_bytes_per_delta']:.0f} "
         f"edges={ds.num_edges}")
    emit(f"fig4_spikes_conn_sparse_r{r}_n{n}",
         metrics["sparse_steady_us_per_chunk"],
         f"rate+reqB/Delta={metrics['sparse_rate_bytes_per_delta']:.0f}"
         f"+{metrics['sparse_request_bytes_per_delta']:.0f} "
         f"({metrics['total_bytes_ratio']:.1f}x less, "
         f"overflow={metrics['subscription_overflow']:.0f})")
    return metrics


def main():
    smoke = "--smoke" in sys.argv
    write_json = smoke or "--json" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    n = int(args[0]) if args else (64 if smoke else 256)
    import jax
    from repro import telemetry
    r = len(jax.devices())
    if "--connectome" in sys.argv:
        bench_connectome(n)
        return
    params, m, device_metrics = bench(n)
    emit(f"fig4_spikes_old_r{r}_n{n}", m["old_steady_us_per_chunk"],
         f"compile_ms={m['old_compile_ms']:.0f}")
    emit(f"fig4_spikes_new_dense_r{r}_n{n}", m["dense_steady_us_per_chunk"],
         f"speedup={m['old_steady_us_per_chunk'] / m['dense_steady_us_per_chunk']:.2f}x "
         f"rateB/Delta={m['dense_rate_bytes_per_delta']:.0f}")
    emit(f"fig4_spikes_new_sparse_r{r}_n{n}", m["sparse_steady_us_per_chunk"],
         f"rate+reqB/Delta={m['sparse_rate_bytes_per_delta']:.0f}"
         f"+{m['sparse_request_bytes_per_delta']:.0f} "
         f"({m['total_bytes_ratio']:.1f}x less)")
    if write_json:
        # smoke output goes to its own file: reproducing the CI smoke step
        # locally must not clobber the committed r=4/n=1024 baseline
        out = "BENCH_spikes_smoke.json" if smoke else "BENCH_spikes.json"
        rep = telemetry.report.make_report(
            "spikes", {f"r{r}_n{n}": telemetry.report.case(params, m)},
            smoke=smoke,
            mesh={"num_ranks": r, "backend": jax.default_backend()},
            counters=telemetry.report.counters_block(device_metrics),
            histograms=telemetry.report.histograms_block(device_metrics),
            spans=telemetry.export())
        telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
