"""Paper Fig. 4: spike-transmission time, per-step spiked-ID exchange vs
Delta-periodic rate exchange. The chunk is dominated by the activity phase
(rate_period=100, connectivity barely active)."""
import sys

from benchmarks._util import brain_sim, emit


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    import jax
    r = len(jax.devices())
    times = {}
    for alg in ("old", "new"):
        dt, st = brain_sim(dict(
            neurons_per_rank=n, local_levels=3, frontier_cap=32,
            max_synapses=16, connectivity_alg="new", spike_alg=alg,
            rate_period=100, requests_cap_factor=max(r, 4)), chunks=2)
        times[alg] = dt
    emit(f"fig4_spikes_old_r{r}_n{n}", times["old"] * 1e6)
    emit(f"fig4_spikes_new_r{r}_n{n}", times["new"] * 1e6,
         f"speedup={times['old'] / times['new']:.2f}x")


if __name__ == "__main__":
    main()
