"""Paper Fig. 4: spike-transmission cost. Two sweeps:

  * spike_alg old vs new — per-step spiked-ID exchange vs Delta-periodic
    rate exchange (the chunk is dominated by the activity phase);
  * rate_exchange dense vs sparse (spike_alg='new') — the replicated (R, n)
    rates all-gather vs the demand-driven subscription push (DESIGN.md §7),
    with the measured exchanged-rate-record counters next to wall time.

Exchange volume comes from ``stats['rates_sent']`` (rate records actually
shipped: dense = n*(R-1) per rank per Delta, sparse = the subscribed
pushes), so the byte drop R*n*4 -> |subs|*4 is measured, not modeled. The
sparse exchange additionally ships one 4B subscription-request id per
pushed rate (``stats['subscription_requests']``) — reported separately and
folded into ``total_bytes_ratio`` so the sparse win is not overstated.

``--json`` writes ``BENCH_spikes.json`` at the repo root (the recorded
perf-trajectory baseline: r=4, n=1024); ``--smoke`` runs a small n for CI
and writes ``BENCH_spikes_smoke.json`` instead, so reproducing the CI step
locally cannot clobber the committed baseline.
"""
import json
import os
import sys

from benchmarks._util import PAPER_BYTES, ROOT, brain_sim, emit


def bench(n, chunks=2):
    import jax
    import numpy as np
    from repro.core.spikes import NO_SUB
    r = len(jax.devices())
    base = dict(neurons_per_rank=n, local_levels=3, frontier_cap=32,
                max_synapses=16, connectivity_alg="new", rate_period=100,
                requests_cap_factor=max(r, 4), subs_cap_factor=max(r, 4))
    runs = {"old": dict(base, spike_alg="old"),
            "dense": dict(base, rate_exchange="dense"),
            "sparse": dict(base, rate_exchange="sparse")}
    times, states = {}, {}
    for name, cfg in runs.items():
        times[name], states[name] = brain_sim(cfg, chunks=chunks)

    chunks_total = chunks + 1   # brain_sim's warmup chunk also accumulates
    rep = {"num_ranks": r, "n_per_rank": n, "delta": base["rate_period"],
           "old_us_per_chunk": times["old"] * 1e6,
           "dense_us_per_chunk": times["dense"] * 1e6,
           "sparse_us_per_chunk": times["sparse"] * 1e6}
    for name in ("dense", "sparse"):
        sent = float(states[name].stats["rates_sent"].sum())
        rep[f"{name}_rate_records_per_delta"] = sent / chunks_total
        rep[f"{name}_rate_bytes_per_delta"] = \
            sent / chunks_total * PAPER_BYTES["rate"]
    subs = np.asarray(states["sparse"].subs)
    rep["subs_per_rank_mean"] = float((subs != NO_SUB).sum()) / r
    rep["dense_table_bytes_per_rank"] = r * n * PAPER_BYTES["rate"]
    rep["subscription_overflow"] = \
        float(states["sparse"].stats["subscription_overflow"].sum())
    # the 4B request ids shipped alongside the pushed rates (dense: none)
    reqs = float(states["sparse"].stats["subscription_requests"].sum())
    rep["sparse_request_bytes_per_delta"] = \
        reqs / chunks_total * PAPER_BYTES["rate"]
    rep["rate_bytes_ratio"] = rep["dense_rate_bytes_per_delta"] / \
        max(rep["sparse_rate_bytes_per_delta"], 1.0)
    rep["total_bytes_ratio"] = rep["dense_rate_bytes_per_delta"] / \
        max(rep["sparse_rate_bytes_per_delta"]
            + rep["sparse_request_bytes_per_delta"], 1.0)
    # the whole point: the push must ship strictly less than the broadcast
    if r > 1:
        assert rep["total_bytes_ratio"] > 1.0, rep["total_bytes_ratio"]
    return rep, times


def main():
    smoke = "--smoke" in sys.argv
    write_json = smoke or "--json" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    n = int(args[0]) if args else (64 if smoke else 256)
    import jax
    r = len(jax.devices())
    rep, times = bench(n)
    emit(f"fig4_spikes_old_r{r}_n{n}", times["old"] * 1e6)
    emit(f"fig4_spikes_new_dense_r{r}_n{n}", times["dense"] * 1e6,
         f"speedup={times['old'] / times['dense']:.2f}x "
         f"rateB/Delta={rep['dense_rate_bytes_per_delta']:.0f}")
    emit(f"fig4_spikes_new_sparse_r{r}_n{n}", times["sparse"] * 1e6,
         f"rate+reqB/Delta={rep['sparse_rate_bytes_per_delta']:.0f}"
         f"+{rep['sparse_request_bytes_per_delta']:.0f} "
         f"({rep['total_bytes_ratio']:.1f}x less)")
    if write_json:
        # smoke output goes to its own file: reproducing the CI smoke step
        # locally must not clobber the committed r=4/n=1024 baseline
        out = "BENCH_spikes_smoke.json" if smoke else "BENCH_spikes.json"
        report = {"smoke": smoke, f"r{r}_n{n}": rep}
        with open(os.path.join(ROOT, out), "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
