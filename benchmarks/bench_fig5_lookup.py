"""Paper Fig. 5: receive-side cost — binary-search ID lookup (old) vs PRNG
reconstruction (new). Micro-benchmark of the two jitted receive paths on one
device (the paper reports new is ~1.5x slower here; the Fig. 4 win dwarfs it).
"""
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.core import spikes


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 4096
    s_max = 32
    r = 4
    key = jax.random.key(0)
    in_edges = jax.random.randint(key, (n, s_max), 0, r * n).astype(jnp.int32)
    spiked = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.05, (n,))
    gid = jnp.arange(n, dtype=jnp.int32)
    ids = jnp.sort(jnp.where(spiked, gid, jnp.iinfo(jnp.int32).max))
    all_ids = jnp.tile(ids[None], (r, 1))
    rates = jnp.full((r, n), 0.05, jnp.float32)

    lookup = jax.jit(lambda: spikes.lookup_spikes(all_ids, in_edges, n))
    recon = jax.jit(lambda: spikes.reconstruct_spikes(
        0, 7, rates, in_edges, 0, n))
    t_old, _ = time_fn(lookup, iters=10)
    t_new, _ = time_fn(recon, iters=10)
    emit(f"fig5_lookup_search_n{n}", t_old * 1e6)
    emit(f"fig5_lookup_prng_n{n}", t_new * 1e6,
         f"prng/search={t_new / t_old:.2f}x")


if __name__ == "__main__":
    main()
