"""Regression gate: compare a smoke run against the committed
``BENCH_*.json`` baselines with per-metric thresholds (DESIGN.md §9).

Usage::

    python -m benchmarks.check_regression --smoke [--capture-trace DIR]

``--smoke`` runs every bench family's ``--smoke`` mode in this process's
device environment (CI sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=4``), producing the ``BENCH_*_smoke.json`` candidates; the
gate then compares each candidate case against the committed baseline
(``telemetry.report.normalize`` reads both the v1 schema and the
pre-schema flat layouts), prints a comparison table, writes the merged
telemetry report to ``TELEMETRY_smoke.json``, and exits 1 on any
regression. Without ``--smoke`` it only compares files already on disk.

Rules (``RULES``): scale-free ratio metrics (``*_ratio`` — deterministic
byte-model/counter ratios) are compared across different problem sizes —
candidate cases pair with the same-named baseline case when present, else
with the baseline's smallest-``n_per_rank`` case. Scale-dependent metrics
(wall times incl. ``walltime_reduction_pct``, byte counts) are only
compared when the paired cases' shape params (``n_per_rank``,
``num_ranks``) match exactly. Baselines whose byte model is not
scale-free down to smoke size (connectivity: a whole-update term that
shrinks relative to phase B below n=256) commit a smoke-scale case
captured in the CI gate environment, so the smoke run pairs with it by
exact name at matched params and every rule applies tightly.
``--capture-trace DIR`` additionally runs a tiny Simulator under
``profile_dir=DIR`` so CI archives a real profiler trace artifact.
"""
from __future__ import annotations

import argparse
import fnmatch
import os
import sys
from dataclasses import dataclass
from typing import List, Optional

from benchmarks._util import ROOT

BENCHES = {
    # family -> (module, committed baseline file)
    "activity": ("benchmarks.bench_activity", "BENCH_activity.json"),
    "connectivity": ("benchmarks.bench_connectivity",
                     "BENCH_connectivity.json"),
    "spikes": ("benchmarks.bench_fig4_spikes", "BENCH_spikes.json"),
    "fig11": ("benchmarks.bench_fig11_total", "BENCH_fig11.json"),
    "runner": ("benchmarks.bench_runner", "BENCH_runner.json"),
    "service": ("benchmarks.bench_service", "BENCH_service.json"),
    "workloads": ("benchmarks.bench_workloads", "BENCH_workloads.json"),
}


@dataclass(frozen=True)
class Rule:
    """One gating rule. ``pattern`` is an fnmatch over metric names;
    ``higher_better`` sets the regression direction; ``tol_frac`` the
    allowed fractional slack (0.5 = candidate may be up to 50% worse);
    ``params_must_match`` restricts the comparison to case pairs whose
    shape params are identical (scale-dependent metrics)."""
    pattern: str
    higher_better: bool
    tol_frac: float
    params_must_match: bool

    def check(self, base: float, cand: float) -> bool:
        """True = OK, False = regression."""
        if self.higher_better:
            return cand >= base * (1.0 - self.tol_frac)
        return cand <= base * (1.0 + self.tol_frac)


# first matching rule wins; metrics matching no rule are informational
RULES = (
    # scale-free efficiency ratios: the paper's claims. A halving of the
    # HBM-traffic or byte-volume win is a real regression at any size.
    # (These are deterministic byte-model/counter ratios, not wall time.)
    Rule("*_ratio", True, 0.5, False),
    # workload quality (bench_workloads): function, not speed. Recall
    # overlap is a deterministic fraction of a fixed protocol at matched
    # shape — quality must not regress (ISSUE 10); the dynamic-params
    # compile count is exact (any second trace is a retrace regression);
    # the assimilation error is dynamics-derived, so generous slack.
    Rule("recall_overlap", True, 0.3, True),
    Rule("engram_selectivity", True, 0.5, True),
    Rule("dyn_compile_count", False, 0.0, True),
    Rule("assim_final_abs_err", False, 1.0, True),
    # scale-dependent wall times: noisy on shared CI — generous slack,
    # and only ever compared at identical (n_per_rank, num_ranks)
    Rule("walltime_reduction_pct", True, 1.0, True),
    Rule("*compile_ms", False, 2.0, True),
    Rule("*_us_per_*", False, 1.0, True),
    # fault-tolerance overhead: checkpoint save/restore/probe wall time
    # per interval — host I/O dominated, very noisy on shared CI
    Rule("*_ms_per_ckpt", False, 3.0, True),
    # multi-tenant service (bench_service): throughput and the
    # per-tenant co-tenancy overhead factor — wall-time based, generous
    Rule("requests_per_s", True, 0.5, True),
    Rule("isolation_overhead_x", False, 1.0, True),
    # scale-dependent measured byte counters: deterministic, tight
    Rule("*_bytes_per_*", False, 0.25, True),
    # per-stage connectivity attribution (sort/tree/apply/exchange
    # roofline or analytic bytes — bench_connectivity): deterministic
    Rule("*_hbm_bytes", False, 0.25, True),
    Rule("*_records_per_*", False, 0.25, True),
)

MATCH_PARAMS = ("n_per_rank", "num_ranks")


@dataclass
class Finding:
    bench: str
    case: str
    metric: str
    baseline: float
    candidate: float
    ok: bool
    rule: Optional[Rule]


def rule_for(metric: str) -> Optional[Rule]:
    for r in RULES:
        if fnmatch.fnmatch(metric, r.pattern):
            return r
    return None


def _pair_case(cand_name: str, cand_case: dict, base_cases: dict):
    """Baseline case for a candidate case: exact name, else smallest-n."""
    if cand_name in base_cases:
        return cand_name, base_cases[cand_name]
    def n_of(c):
        return c.get("params", {}).get("n_per_rank", float("inf"))
    if not base_cases:
        return None, None
    name = min(base_cases, key=lambda k: n_of(base_cases[k]))
    return name, base_cases[name]


def compare(bench: str, baseline: dict, candidate: dict) -> List[Finding]:
    """Compare two *normalized* reports (telemetry.report.normalize).
    Returns one Finding per gated metric pair."""
    out: List[Finding] = []
    for cname, ccase in candidate.get("cases", {}).items():
        bname, bcase = _pair_case(cname, ccase, baseline.get("cases", {}))
        if bcase is None:
            continue
        bp, cp = bcase.get("params", {}), ccase.get("params", {})
        params_match = all(bp.get(k) == cp.get(k) for k in MATCH_PARAMS)
        for metric, cval in ccase.get("metrics", {}).items():
            if not isinstance(cval, (int, float)) or isinstance(cval, bool):
                continue
            bval = bcase.get("metrics", {}).get(metric)
            if bval is None:
                continue
            rule = rule_for(metric)
            if rule is None:
                continue
            if rule.params_must_match and not params_match:
                continue
            ok = rule.check(float(bval), float(cval))
            out.append(Finding(bench, f"{bname}->{cname}", metric,
                               float(bval), float(cval), ok, rule))
    return out


def run_smoke_benches(families) -> None:
    """Run each family's --smoke in-process (one Python, shared jax
    backend/device env — CI sets the host-device count via XLA_FLAGS)."""
    import importlib
    for fam in families:
        module, _ = BENCHES[fam]
        argv_backup = sys.argv
        sys.argv = [module, "--smoke"]
        try:
            importlib.import_module(module).main()
        finally:
            sys.argv = argv_backup


def capture_trace(trace_dir: str) -> None:
    """Run a small Simulator under profile_dir so CI archives a real
    profiler trace next to the telemetry JSON. The traced run is
    deliberately tinier than smoke (short rate window, one chunk):
    interpret-mode Pallas records every emulated op, and tracing a full
    smoke run overflows the profiler's 2 GB XSpace protobuf."""
    import dataclasses
    from repro.configs.msp_brain import SMOKE_CONFIG
    from repro.sim import Simulator
    cfg = dataclasses.replace(SMOKE_CONFIG, rate_period=10)
    sim = Simulator(cfg, profile_dir=trace_dir)
    sim.run(1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="run every bench family's --smoke first")
    ap.add_argument("--families", default=",".join(BENCHES),
                    help="comma-separated subset of bench families")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "TELEMETRY_smoke.json"))
    ap.add_argument("--capture-trace", default=None, metavar="DIR",
                    help="also capture a jax.profiler trace of a smoke run")
    args = ap.parse_args(argv)
    families = [f for f in args.families.split(",") if f in BENCHES]

    from repro import telemetry

    if args.smoke:
        run_smoke_benches(families)
    if args.capture_trace:
        capture_trace(args.capture_trace)

    findings: List[Finding] = []
    merged_cases = {}
    compared = []
    for fam in families:
        _, base_file = BENCHES[fam]
        base_path = os.path.join(ROOT, base_file)
        cand_path = os.path.join(
            ROOT, base_file.replace(".json", "_smoke.json"))
        if not os.path.exists(cand_path):
            continue
        cand = telemetry.report.normalize(
            telemetry.report.load(cand_path), bench=fam)
        for cname, ccase in cand["cases"].items():
            merged_cases[f"{fam}/{cname}"] = ccase
        if not os.path.exists(base_path):
            print(f"[check_regression] {fam}: no baseline {base_file} — "
                  "skipped", flush=True)
            continue
        base = telemetry.report.normalize(
            telemetry.report.load(base_path), bench=fam)
        findings.extend(compare(fam, base, cand))
        compared.append(fam)

    bad = [f for f in findings if not f.ok]
    header = f"{'bench':<14}{'case':<18}{'metric':<34}" \
             f"{'baseline':>12}{'smoke':>12}  verdict"
    print(header)
    print("-" * len(header))
    for f in findings:
        print(f"{f.bench:<14}{f.case:<18}{f.metric:<34}"
              f"{f.baseline:>12.1f}{f.candidate:>12.1f}  "
              f"{'ok' if f.ok else 'REGRESSION'}")
    print(f"\n[check_regression] {len(findings)} metrics gated across "
          f"{compared or 'no'} families; {len(bad)} regression(s)")

    rep = telemetry.report.make_report(
        "regression", merged_cases, smoke=True,
        spans=telemetry.export())
    rep["findings"] = [{
        "bench": f.bench, "case": f.case, "metric": f.metric,
        "baseline": f.baseline, "candidate": f.candidate, "ok": f.ok,
    } for f in findings]
    telemetry.report.write(args.out, rep)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
