"""Shared benchmark helpers: timing, subprocess fan-out over device counts,
CSV emission (format: name,us_per_call,derived)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def time_fn(fn, *args, warmup=1, iters=3):
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def emit(name: str, us_per_call: float, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def run_sub(module: str, devices: int, *args, timeout=560):
    """Run a benchmark module in a subprocess with N host devices; returns its
    stdout (the module prints CSV lines)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + ROOT
    cmd = [sys.executable, "-m", module] + [str(a) for a in args]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=ROOT)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-2000:])
        return f"{module},-1,ERROR\n"
    return proc.stdout


# paper record sizes (bytes) for Table I/II accounting
PAPER_BYTES = {
    "old_request": 17, "new_request": 42, "new_response": 9,
    "spike_id": 8, "rate": 4, "tree_node": 32,
}


def brain_sim(cfg_overrides, chunks=2, stats_only=False):
    """Build + run the brain sim on whatever devices exist, through the
    ``repro.sim.Simulator`` facade; returns (time_per_chunk_s, final_state)."""
    import jax
    from repro.configs.msp_brain import BrainConfig
    from repro.sim import Simulator
    cfg = BrainConfig(**cfg_overrides)
    sim = Simulator.from_config(cfg)
    st = sim.step()  # warmup/compile + first plasticity round
    jax.block_until_ready(st.positions)
    t0 = time.perf_counter()
    for _ in range(chunks):
        st = sim.step()
    jax.block_until_ready(st.positions)
    dt = (time.perf_counter() - t0) / chunks
    return dt, st


def paper_bytes_from_stats(stats, alg_conn: str, alg_spike: str,
                           num_ranks: int):
    """Tables I/II accounting with the paper's record sizes."""
    s = {k: float(v.sum()) for k, v in stats.items()}
    b = 0.0
    if alg_conn == "new":
        b += s["bh_requests"] * PAPER_BYTES["new_request"]
        b += s["bh_requests"] * PAPER_BYTES["new_response"]
    else:
        b += s["formation_requests"] * (PAPER_BYTES["old_request"] + 1)
        b += s["tree_nodes_downloaded"] * PAPER_BYTES["tree_node"]
    if alg_spike == "new":
        # rates_sent already counts rate records actually shipped (dense:
        # n*(R-1) broadcast per rank per Delta; sparse: the subscribed
        # pushes) — no fan-out factor here. The sparse exchange also ships
        # one 4B subscription-request id per pushed rate (zero under dense).
        b += s["rates_sent"] * PAPER_BYTES["rate"]
        b += s.get("subscription_requests", 0.0) * PAPER_BYTES["rate"]
    else:
        b += s["spikes_sent"] * PAPER_BYTES["spike_id"] * max(num_ranks - 1, 0)
    return b, s
