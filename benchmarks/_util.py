"""Shared benchmark helpers: timing with an explicit compile/steady split,
subprocess fan-out over device counts, CSV emission (format:
name,us_per_call,derived)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Any, NamedTuple

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


class Timing(NamedTuple):
    """One measurement: first-call latency (trace + compile + first run,
    ms) and fenced steady-state per-iteration time (us). The two are
    reported separately in every ``BENCH_*.json`` (telemetry.report
    schema) — a compile-time regression must never hide in the
    steady-state number or vice versa."""
    compile_ms: float
    steady_us: float


def measure(fn, *args, warmup=1, iters=3):
    """Time ``fn(*args)`` with the compile/steady split: the first call
    (traced + compiled + executed, fenced) is ``compile_ms``; after
    ``warmup`` more fenced calls, ``iters`` fenced calls average into
    ``steady_us``. Returns (Timing, last_output)."""
    import jax
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    compile_ms = (time.perf_counter() - t0) * 1e3
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    steady_us = (time.perf_counter() - t0) / iters * 1e6
    return Timing(compile_ms, steady_us), out


def time_fn(fn, *args, warmup=1, iters=3):
    """Back-compat shim over ``measure``: (steady seconds/iter, output).
    The first warmup call doubles as the compile fence."""
    timing, out = measure(fn, *args, warmup=max(warmup - 1, 0), iters=iters)
    return timing.steady_us / 1e6, out


def emit(name: str, us_per_call: float, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def run_sub(module: str, devices: int, *args, timeout=560):
    """Run a benchmark module in a subprocess with N host devices; returns its
    stdout (the module prints CSV lines)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + ROOT
    cmd = [sys.executable, "-m", module] + [str(a) for a in args]
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=ROOT)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-2000:])
        return f"{module},-1,ERROR\n"
    return proc.stdout


# paper record sizes (bytes) for Table I/II accounting
PAPER_BYTES = {
    "old_request": 17, "new_request": 42, "new_response": 9,
    "spike_id": 8, "rate": 4, "tree_node": 32,
}


def brain_sim_timed(cfg_overrides, chunks=2):
    """Build + run the brain sim on whatever devices exist, through the
    ``repro.sim.Simulator`` facade, with the compile/steady split: the
    warmup chunk (compile + first plasticity round, fenced) is
    ``compile_ms``; ``chunks`` more fenced chunks average into
    ``steady_us``. Returns (Timing, simulator) — callers read the final
    state from ``sim.state`` and full telemetry from ``sim.metrics()``."""
    import jax
    from repro.configs.msp_brain import BrainConfig
    from repro.sim import Simulator
    cfg = BrainConfig(**cfg_overrides)
    sim = Simulator.from_config(cfg)
    t0 = time.perf_counter()
    st = sim.step()  # warmup/compile + first plasticity round
    jax.block_until_ready(st.positions)
    compile_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    for _ in range(chunks):
        st = sim.step()
    jax.block_until_ready(st.positions)
    steady_us = (time.perf_counter() - t0) / chunks * 1e6
    return Timing(compile_ms, steady_us), sim


def brain_sim(cfg_overrides, chunks=2, stats_only=False):
    """Back-compat shim over ``brain_sim_timed``:
    (steady time_per_chunk_s, final_state)."""
    timing, sim = brain_sim_timed(cfg_overrides, chunks=chunks)
    return timing.steady_us / 1e6, sim.state


def paper_bytes_from_stats(stats, alg_conn: str, alg_spike: str,
                           num_ranks: int):
    """Tables I/II accounting with the paper's record sizes."""
    s = {k: float(v.sum()) for k, v in stats.items()}
    b = 0.0
    if alg_conn == "new":
        b += s["bh_requests"] * PAPER_BYTES["new_request"]
        b += s["bh_requests"] * PAPER_BYTES["new_response"]
    else:
        b += s["formation_requests"] * (PAPER_BYTES["old_request"] + 1)
        b += s["tree_nodes_downloaded"] * PAPER_BYTES["tree_node"]
    if alg_spike == "new":
        # rates_sent already counts rate records actually shipped (dense:
        # n*(R-1) broadcast per rank per Delta; sparse: the subscribed
        # pushes) — no fan-out factor here. The sparse exchange also ships
        # one 4B subscription-request id per pushed rate (zero under dense).
        b += s["rates_sent"] * PAPER_BYTES["rate"]
        b += s.get("subscription_requests", 0.0) * PAPER_BYTES["rate"]
    else:
        b += s["spikes_sent"] * PAPER_BYTES["spike_id"] * max(num_ranks - 1, 0)
    return b, s
