"""Scenario subsystem benchmark: the three library experiments at
SMOKE_CONFIG scale — wall time per chunk, per-region synapse counts, the
lesion loss/regrowth signature, and the paper's bit-identity invariant
(old vs new connectivity) under the focal_stimulation protocol.

  PYTHONPATH=src:. python -m benchmarks.bench_scenarios
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks._util import emit


def _run(scn, cfg, num_chunks):
    import jax
    from repro.scenarios import observables, protocol
    from repro.sim import Simulator
    sim = Simulator.from_config(cfg, scenario=scn)
    st = sim.step()  # compile + first round
    jax.block_until_ready(st.positions)
    rec = observables.init_recorder(num_chunks, len(scn.regions) + 1)
    t0 = time.perf_counter()
    for i in range(num_chunks):
        st = sim.step()
        alive = protocol.alive_mask(scn.events, scn.regions, st.positions,
                                    (i + 2) * cfg.rate_period) \
            if scn.events else None
        rec = observables.record(rec, st.positions, st.neurons.calcium,
                                 st.neurons.rate, st.out_edges, scn.regions,
                                 alive)
    jax.block_until_ready(st.positions)
    dt = (time.perf_counter() - t0) / num_chunks
    return dt, st, observables.flush(rec)


def main():
    from repro.scenarios import library

    cfg = library.SMOKE_SCENARIO_CONFIG
    chunks = 12
    for name in ("baseline_growth", "focal_stimulation", "lesion_rewiring"):
        scn = library.get_scenario(name)
        lesion_chunk = 6   # recorder row i holds chunk i+1 (warmup chunk 0)
        if name == "lesion_rewiring":
            # lesion mid-bench so both phases land inside `chunks` rounds
            scn = dataclasses.replace(scn, events=(library.Lesion(
                "core", t=lesion_chunk * cfg.rate_period),))
        dt, st, hist = _run(scn, cfg, chunks)
        syn = hist["synapses"]          # (chunks, nb) by source region
        per_region = "|".join(f"{v:.0f}" for v in syn[-1])
        emit(f"scenario_{name}", dt * 1e6,
             f"synapses_by_region={per_region}")

        if name == "lesion_rewiring":
            # region 0 = lesioned core, region 1 = rest. Recorder row i holds
            # chunk i+1; the lesion applies in chunk `lesion_chunk - 1`'s
            # connectivity update (row lesion_chunk - 2). Loss: the core's
            # synapses vanish there. Regrowth: the rest region grows past its
            # first post-lesion count.
            pre, post = syn[lesion_chunk - 3], syn[lesion_chunk - 2]
            after = syn[-1]
            lost = pre[0] > 0 and post[0] == 0 and after[0] == 0
            regrown = after[1] > post[1]
            emit("scenario_lesion_loss", 0,
                 f"core {pre[0]:.0f}->{post[0]:.0f} ok={lost}")
            emit("scenario_lesion_regrowth", 0,
                 f"rest {post[1]:.0f}->{after[1]:.0f} ok={regrown}")

    # --- bit-identity: old vs new connectivity under focal_stimulation ----
    from repro.sim import Simulator
    scn = library.get_scenario("focal_stimulation")
    edge_tables = {}
    for alg in ("old", "new"):
        c = dataclasses.replace(cfg, connectivity_alg=alg, spike_alg="old")
        st = Simulator.from_config(c, scenario=scn).run(6)
        edge_tables[alg] = (np.sort(np.asarray(st.out_edges), 1),
                            np.sort(np.asarray(st.in_edges), 1))
    identical = all(np.array_equal(edge_tables["old"][i],
                                   edge_tables["new"][i]) for i in (0, 1))
    emit("scenario_old_new_bit_identical", 0, f"ok={identical}")
    if not identical:
        raise SystemExit("old/new connectivity diverged under stimulation")


if __name__ == "__main__":
    main()
