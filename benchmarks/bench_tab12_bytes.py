"""Paper Tables I/II: bytes transferred, old vs new algorithm pairs, using the
paper's record sizes (17/42/9 B requests, 8 B spike IDs, 4 B rates, tree-node
downloads) counted from simulation event counters."""
import sys

from benchmarks._util import brain_sim, emit, paper_bytes_from_stats


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    import jax
    r = len(jax.devices())
    out = {}
    for conn, spike in (("old", "old"), ("new", "new")):
        dt, st = brain_sim(dict(
            neurons_per_rank=n, local_levels=3, frontier_cap=32,
            max_synapses=16, connectivity_alg=conn, spike_alg=spike,
            requests_cap_factor=max(r, 4)), chunks=3)
        b, s = paper_bytes_from_stats(st.stats, conn, spike, r)
        out[conn] = b
        emit(f"tab{'1' if conn == 'old' else '2'}_bytes_{conn}_r{r}_n{n}",
             b, f"formed={s['synapses_formed']:.0f}")
    ratio = out["old"] / max(out["new"], 1.0)
    emit(f"tab12_bytes_ratio_r{r}_n{n}", ratio, "old/new")


if __name__ == "__main__":
    main()
