"""Beyond-paper: split-KV decode (move compute to the cache shards) vs
batch-sharded local decode — collective bytes + wall time, 8 host devices."""
import jax
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.launch import roofline as rl
from repro.compat import make_mesh


def main():
    from repro.configs import get_smoke_config
    from repro.models import build_model, decode_state_specs
    from repro.parallel import sharding as shd
    ndev = len(jax.devices())
    mesh = make_mesh((1, ndev), ("data", "model"))
    for mode in ("local", "split_kv"):
        cfg = get_smoke_config("qwen2-7b").replace(num_kv_heads=4)
        cfg = cfg.replace(parallel=cfg.parallel.replace(decode_attention=mode))
        api = build_model(cfg)
        params = build_model(cfg).init(jax.random.key(0))
        state = api.init_decode_state(4, 2048)
        state["pos"] = jnp.asarray(1024, jnp.int32)
        toks = jnp.ones((4,), jnp.int32)

        def step(p, s, t):
            with shd.use_mesh(mesh):
                return api.decode_step(p, s, t, mesh)

        jitted = jax.jit(step)
        compiled = jitted.lower(params, state, toks).compile()
        ana = rl.analyze_hlo(compiled.as_text(), ndev)
        t, _ = time_fn(jitted, params, state, toks, iters=5)
        emit(f"lm_decode_{mode}_d{ndev}", t * 1e6,
             f"coll_wire_MB={ana['collective_bytes_total'] / 1e6:.2f}")


if __name__ == "__main__":
    main()
