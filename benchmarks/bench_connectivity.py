"""Connectivity-update cost: reference jnp phase-B vs the fused Pallas
Barnes-Hut traversal kernel (connectivity_impl).

Times one full connectivity update (deletion routing + octree build +
phase A + phase B + accept) on a single rank for both lowerings, and counts
materialized HBM bytes:

  reference  ``roofline.materialized_bytes`` of the optimized HLO of the
             whole update — every (Q, F) frontier temporary the restart
             loop materializes is counted trip-aware. NB on CPU XLA
             additionally *serializes* the frontier scatters into
             per-update-element while loops, so the reference count is a
             lowering-specific upper proxy (the metric's documented
             contract: relative comparisons of lowerings, not absolute
             HBM truth);
  fused      the reference total minus the roofline bytes of the standalone
             phase-B lowering, plus the traversal kernel's analytic
             streaming traffic (``bh_traverse.traverse_hbm_bytes``: tree +
             members + neuron data + queries in once, results out once,
             zero per-round temporaries). On CPU the kernel runs in
             interpret mode, whose HLO inlines the *interpreter*, so the
             TPU custom call's traffic is computed in closed form instead
             (the same accounting bench_activity uses).

Emits CSV and writes ``BENCH_connectivity.json`` at the repo root — the
baseline the perf trajectory records against (n per rank in {256, 1024};
``--smoke`` runs n=64 only for CI).
"""
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import ROOT, emit, time_fn
from repro import compat
from repro.configs.msp_brain import BrainConfig
from repro.connectome import routing, traverse
from repro.connectome import tree as ctree
from repro.core import engine
from repro.kernels.bh_traverse import traverse_hbm_bytes
from repro.launch import roofline
from repro.sim import Simulator
from repro.sim import phases as sim_phases


def make_conn_fn(cfg, mesh):
    """Standalone connectivity update through the facade's PhaseContext +
    registry dispatch."""
    num_ranks = mesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)

    def body(st):
        ctx = sim_phases.make_context(cfg, jax.lax.axis_index("ranks"),
                                      "ranks", num_ranks)
        return sim_phases.connectivity_phase(st, ctx)

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                    out_specs=specs, check_vma=False))


def phase_b_reference_bytes(cfg, st, num_ranks):
    """Roofline bytes of the standalone jnp phase-B at the update's shapes
    (the part the fused kernel replaces)."""
    n = cfg.neurons_per_rank
    q = num_ranks * routing.cap_requests(cfg, num_ranks)
    vac = jnp.maximum(st.neurons.de_elements, 0.0)
    tree = ctree.build_local_tree(st.positions, vac, 0, cfg, num_ranks)
    stacked = traverse.stack_levels(tree.counts, tree.centroids, 0)
    kw = dict(seed=cfg.seed, sizes=stacked.sizes, theta=cfg.theta,
              sigma=cfg.sigma, frontier=cfg.frontier_cap,
              n_levels=cfg.local_levels + 1)

    def f(counts, cents, members, npos, vac, x, start, gids, valid):
        return traverse.phase_b_core(counts, cents, members, npos, vac, x,
                                     start, gids, valid, jnp.int32(0),
                                     jnp.int32(0), **kw)

    args = (stacked.counts, stacked.centroids, tree.leaf_members,
            st.positions, vac, jnp.zeros((q, 3), jnp.float32),
            jnp.zeros((q,), jnp.int32), jnp.zeros((q,), jnp.int32),
            jnp.ones((q,), bool))
    hlo = jax.jit(f).lower(*args).compile().as_text()
    return roofline.materialized_bytes(hlo), q, tree, stacked


def bench_one(n, mesh):
    base = BrainConfig(neurons_per_rank=n, local_levels=3, frontier_cap=32)
    num_ranks = mesh.shape["ranks"]

    # one plasticity round first so the edge tables/rates are representative
    st = Simulator.from_config(base, mesh=mesh).step()
    jax.block_until_ready(st.positions)

    rep = {"n_per_rank": n, "s_max": base.max_synapses,
           "num_ranks": num_ranks}
    times = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(base, connectivity_impl=impl)
        fn = make_conn_fn(cfg, mesh)
        dt, _ = time_fn(fn, st, iters=3)
        times[impl] = dt
        rep[f"{impl}_us_per_update"] = dt * 1e6
        if impl == "reference":
            hlo = fn.lower(st).compile().as_text()
            rep["reference_hbm_bytes_per_update"] = \
                roofline.materialized_bytes(hlo)

    pb_bytes, q, tree, stacked = phase_b_reference_bytes(base, st, num_ranks)
    rep["reference_phase_b_hbm_bytes"] = pb_bytes
    n_levels, c_max = stacked.counts.shape
    kernel_bytes = traverse_hbm_bytes(
        n_levels, c_max, tree.leaf_members.shape[0],
        tree.leaf_members.shape[1], n, q)
    rep["fused_phase_b_hbm_bytes"] = kernel_bytes
    rep["fused_hbm_bytes_per_update"] = \
        rep["reference_hbm_bytes_per_update"] - pb_bytes + kernel_bytes
    rep["hbm_bytes_ratio"] = rep["reference_hbm_bytes_per_update"] / \
        max(rep["fused_hbm_bytes_per_update"], 1.0)
    rep["phase_b_queries"] = q
    assert rep["hbm_bytes_ratio"] >= 1.0, \
        f"fused must not touch MORE HBM, got {rep['hbm_bytes_ratio']:.2f}x"
    return rep, times


def main():
    smoke = "--smoke" in sys.argv
    sizes = [64] if smoke else [256, 1024]
    mesh = engine.make_brain_mesh()
    report = {"smoke": smoke}
    for n in sizes:
        rep, times = bench_one(n, mesh)
        report[f"n{n}"] = rep
        emit(f"connectivity_reference_n{n}", times["reference"] * 1e6,
             f"hbm_B/update={rep['reference_hbm_bytes_per_update']:.0f}")
        emit(f"connectivity_fused_n{n}", times["fused"] * 1e6,
             f"hbm_B/update={rep['fused_hbm_bytes_per_update']:.0f} "
             f"({rep['hbm_bytes_ratio']:.1f}x less)")
    with open(os.path.join(ROOT, "BENCH_connectivity.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
