"""Connectivity-update cost: reference jnp lowering vs the fused Pallas
kernels (connectivity_impl + tree_impl + apply_impl), with per-stage
attribution.

Times one full connectivity update (deletion routing + octree build +
phase A + phase B + accept) on a single rank for both lowerings — compile
and steady state reported separately (``_util.measure``) — and counts
materialized HBM bytes:

  reference  ``roofline.materialized_bytes`` of the optimized HLO of the
             whole update — every (Q, F) frontier temporary the restart
             loop materializes is counted trip-aware. NB on CPU XLA
             additionally *serializes* the frontier scatters into
             per-update-element while loops, so the reference count is a
             lowering-specific upper proxy (the metric's documented
             contract: relative comparisons of lowerings, not absolute
             HBM truth);
  fused      the reference total minus the roofline bytes of each
             standalone reference stage the kernels replace (phase B,
             the Morton sort, the synapse-apply/routing composite), plus
             each kernel's analytic streaming traffic. On CPU the kernels
             run in interpret mode, whose HLO inlines the *interpreter*,
             so the TPU custom calls' traffic is computed in closed form
             instead (the same accounting bench_activity uses).

Per-stage sub-metrics make a steady-time or byte anomaly attributable
without re-deriving the decomposition (the n64 interpret-overhead case):

  ``{impl}_sort_*``   the (rel, slot) Morton sort+rank pair feeding the
                      tree build — argsort+searchsorted vs radix kernel;
  ``{impl}_tree_*``   the whole local-tree build (sort + the shared
                      scatter-add/aggregation back half);
  ``{impl}_apply_*``  the synapse-table composite: 2x deletion routing
                      (pre-collective half), 2x drain+compact, 1x accept;
  ``exchange_*``      what still crosses ranks per update (branch-node
                      all-gather, 2x deletion all-to-all, 42B formation
                      requests, dense rate gather) — impl-independent,
                      bytes analytic, time measured over the collectives
                      alone.

Emits CSV and writes a ``repro.telemetry/v1`` report: ``--smoke`` (n=64)
to ``BENCH_connectivity_smoke.json``, otherwise ``BENCH_connectivity.json``
(n per rank in {256, 1024}) — the committed baseline
``benchmarks/check_regression.py`` gates against (the smoke file is
separate so reproducing the CI step locally cannot clobber the baseline).

The committed baseline additionally carries the smoke-scale ``n64`` case
captured under CI's gate environment (4 host devices — the byte model
depends on device count via ``q = num_ranks * cap_requests``, and the
ratio is not scale-free below n=256), so the smoke gate pairs it by exact
name at matched params. Regenerate that case with
``XLA_FLAGS=--xla_force_host_platform_device_count=4 ... --smoke`` and
copy it into the baseline; the n256/n1024 cases come from the plain
single-device run.
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import ROOT, emit, measure
from repro import compat, telemetry
from repro.configs.msp_brain import BrainConfig
from repro.connectome import routing, traverse
from repro.connectome import tree as ctree
from repro.core import engine, morton, spikes
from repro.kernels import ops as kops
from repro.kernels.bh_traverse import traverse_hbm_bytes
from repro.kernels.radix_sort import morton_sort_hbm_bytes
from repro.kernels.synapse_apply import apply_hbm_bytes, route_build_hbm_bytes
from repro.launch import roofline
from repro.sim import Simulator, registry
from repro.sim import phases as sim_phases

FUSED_FIELDS = dict(connectivity_impl="fused", tree_impl="fused",
                    apply_impl="fused")


def make_conn_fn(cfg, mesh):
    """Standalone connectivity update through the facade's PhaseContext +
    registry dispatch."""
    num_ranks = mesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)

    def body(st):
        ctx = sim_phases.make_context(cfg, jax.lax.axis_index("ranks"),
                                      "ranks", num_ranks)
        return sim_phases.connectivity_phase(st, ctx)

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                    out_specs=specs, check_vma=False))


def phase_b_reference_bytes(cfg, st, num_ranks):
    """Roofline bytes of the standalone jnp phase-B at the update's shapes
    (the part the traversal kernel replaces)."""
    n = cfg.neurons_per_rank
    q = num_ranks * routing.cap_requests(cfg, num_ranks)
    vac = jnp.maximum(st.neurons.de_elements[:n], 0.0)
    tree = ctree.build_local_tree(st.positions[:n], vac, 0, cfg, num_ranks)
    stacked = traverse.stack_levels(tree.counts, tree.centroids, 0)
    kw = dict(seed=cfg.seed, sizes=stacked.sizes, theta=cfg.theta,
              sigma=cfg.sigma, frontier=cfg.frontier_cap,
              n_levels=cfg.local_levels + 1)

    def f(counts, cents, members, npos, vac, x, start, gids, valid):
        return traverse.phase_b_core(counts, cents, members, npos, vac, x,
                                     start, gids, valid, jnp.int32(0),
                                     jnp.int32(0), **kw)

    args = (stacked.counts, stacked.centroids, tree.leaf_members,
            st.positions[:n], vac, jnp.zeros((q, 3), jnp.float32),
            jnp.zeros((q,), jnp.int32), jnp.zeros((q,), jnp.int32),
            jnp.ones((q,), bool))
    hlo = jax.jit(f).lower(*args).compile().as_text()
    return roofline.materialized_bytes(hlo), q, tree, stacked


# ------------------------------------------------------------ stage benches
def make_sort_fns(cfg, num_ranks):
    """The (rel, slot) Morton sort+rank pair at rank 0's geometry —
    'reference' (argsort + searchsorted ``positions_within``) vs the radix
    kernel. Exactly the part ``tree_impl`` swaps."""
    leaf_level, n_leaf, base_cell = ctree._tree_geometry(0, cfg, num_ranks)
    base = base_cell * 8 ** cfg.local_levels

    def reference(pos):
        rel = jnp.clip(morton.morton_encode(pos, leaf_level) - base,
                       0, n_leaf - 1)
        return rel, ctree.positions_within(rel, n_leaf)

    def fused(pos):
        return kops.morton_sort(pos, jnp.int32(base), leaf_level=leaf_level,
                                n_leaf=n_leaf)

    return {"reference": jax.jit(reference), "fused": jax.jit(fused)}


def make_tree_fns(cfg, num_ranks):
    """The whole local-tree build per ``tree_impl`` (sort + shared
    scatter-add/aggregation back half)."""
    return {impl: jax.jit(
        lambda pos, vac, build=registry.resolve("tree", impl):
        build(pos, vac, 0, cfg, num_ranks))
        for impl in ("reference", "fused")}


def make_apply_fns(cfg, num_ranks):
    """The synapse-table composite one update runs per ``apply_impl``:
    deletion routing for both tables (pre-collective half — the exchange
    itself is the ``exchange_*`` sub-metric), both drain+compact passes,
    and the accept pass."""
    n = cfg.neurons_per_rank
    cap = routing.cap_deletions(cfg, False)
    fns = {}
    for impl in ("reference", "fused"):
        ai = registry.resolve("apply", impl)

        def f(out_edges, in_edges, kill_out, kill_in, vac_d, rlid, rsrc,
              rvalid, key, ai=ai, impl=impl):
            gcol = jnp.arange(n, dtype=jnp.int32)[:, None]

            def route(kill, edges):
                fo = jnp.where(kill, edges, -1).reshape(-1)
                fm = jnp.broadcast_to(gcol, kill.shape).reshape(-1)
                if impl == "reference":
                    return routing.route_build_core(
                        fo, fm, n, num_ranks, cap, ctree.positions_within)[0]
                return kops.route_build(fo, fm, n=n, num_ranks=num_ranks,
                                        cap=cap)[0]

            mo = route(kill_out, out_edges).reshape(num_ranks * cap, 2)
            mi = route(kill_in, in_edges).reshape(num_ranks * cap, 2)
            ie = ai.deletion(in_edges, jnp.clip(mo[:, 0], 0, n - 1),
                             mo[:, 1], (mo[:, 0] >= 0) & (mo[:, 0] < n))
            oe = ai.deletion(out_edges, jnp.clip(mi[:, 0], 0, n - 1),
                             mi[:, 1], (mi[:, 0] >= 0) & (mi[:, 0] < n))
            acc, ie = ai.accept(rlid, rsrc, rvalid, vac_d, ie, key)
            return oe, ie, acc

        fns[impl] = jax.jit(f)
    return fns


def apply_stage_inputs(cfg, st, q, seed=7):
    """Representative apply-stage inputs from the live state: rank 0's
    tables, ~10% retraction kill masks, a full formation request batch."""
    n = cfg.neurons_per_rank
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed), 4)
    oe, ie = st.out_edges[:n], st.in_edges[:n]
    kill_out = (oe >= 0) & (jax.random.uniform(k1, oe.shape) < 0.1)
    kill_in = (ie >= 0) & (jax.random.uniform(k2, ie.shape) < 0.1)
    vac_d = jnp.maximum(st.neurons.de_elements[:n], 0.0)
    rlid = jax.random.randint(k3, (q,), 0, n, jnp.int32)
    rsrc = jax.random.randint(k4, (q,), 0, n, jnp.int32)
    rvalid = jnp.arange(q) % 3 != 0
    return oe, ie, kill_out, kill_in, vac_d, rlid, rsrc, rvalid, \
        jax.random.key(seed)


def make_exchange_fn(cfg, mesh):
    """The update's collectives in isolation, fed from cheap slices and
    broadcasts of the live state (no sorts or scatters, so the measured
    steady time is the exchange itself): the branch-node all-gather, the
    two deletion all-to-alls, and the dense rate-table gather."""
    num_ranks = mesh.shape["ranks"]
    c_per = morton.cells_per_rank(num_ranks)
    cap = routing.cap_deletions(cfg, False)
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)
    P = jax.sharding.PartitionSpec

    def body(st):
        bc = jnp.broadcast_to(st.neurons.rate[:1], (c_per,))
        bz = jnp.broadcast_to(st.positions[:1], (c_per, 3))
        top_c = jax.lax.all_gather(bc, "ranks", axis=0, tiled=True)
        top_z = jax.lax.all_gather(bz, "ranks", axis=0, tiled=True)
        buf = jnp.full((num_ranks, cap, 2), -1, jnp.int32) + \
            st.in_edges[0, 0] * 0
        if num_ranks > 1:
            b1 = jax.lax.all_to_all(buf, "ranks", 0, 0, tiled=True)
            b2 = jax.lax.all_to_all(buf, "ranks", 0, 0, tiled=True)
        else:
            b1, b2 = buf, buf
        rates = spikes.exchange_rates(st.neurons.rate, "ranks", num_ranks)
        s = top_c.sum() + top_z.sum() + rates.sum() + \
            (b1.sum() + b2.sum()).astype(jnp.float32)
        return jnp.reshape(s, (1,))

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                    out_specs=P("ranks"), check_vma=False))


def exchange_hbm_bytes(cfg, num_ranks, q):
    """Analytic bytes one rank sends+receives per update — the residency
    boundary the fused kernels do NOT remove (DESIGN.md §11): branch
    nodes (count f32 + centroid 3xf32 per cell), two (R, cap, 2) i32
    deletion buffers, the 42B formation-and-calculation requests, and the
    dense (R, n) rate-table gather."""
    c_per = morton.cells_per_rank(num_ranks)
    cap = routing.cap_deletions(cfg, False)
    return (num_ranks * c_per * 16 + 2 * num_ranks * cap * 8 + q * 42 +
            num_ranks * cfg.neurons_per_rank * 4)


def roofline_of(fn, *args):
    return roofline.materialized_bytes(
        fn.lower(*args).compile().as_text())


def bench_one(n, mesh):
    base = BrainConfig(neurons_per_rank=n, local_levels=3, frontier_cap=32)
    num_ranks = mesh.shape["ranks"]
    s_max = base.max_synapses
    cap = routing.cap_deletions(base, False)
    q = num_ranks * routing.cap_requests(base, num_ranks)

    # one plasticity round first so the edge tables/rates are representative
    st = Simulator.from_config(base, mesh=mesh).step()
    jax.block_until_ready(st.positions)

    metrics = {}
    for impl in ("reference", "fused"):
        over = FUSED_FIELDS if impl == "fused" else {}
        cfg = dataclasses.replace(base, **over)
        fn = make_conn_fn(cfg, mesh)
        with telemetry.span(f"bench.connectivity.{impl}", n=n):
            timing, _ = measure(fn, st, iters=3)
        metrics[f"{impl}_compile_ms"] = timing.compile_ms
        metrics[f"{impl}_steady_us_per_update"] = timing.steady_us
        if impl == "reference":
            hlo = fn.lower(st).compile().as_text()
            metrics["reference_hbm_bytes_per_update"] = \
                roofline.materialized_bytes(hlo)

    # ---- per-stage attribution (bytes: roofline vs analytic kernel) ------
    pos = st.positions[:n]
    vac = jnp.maximum(st.neurons.de_elements[:n], 0.0)
    sort_fns = make_sort_fns(base, num_ranks)
    tree_fns = make_tree_fns(base, num_ranks)
    apply_fns = make_apply_fns(base, num_ranks)
    apply_args = apply_stage_inputs(base, st, q)
    for impl in ("reference", "fused"):
        t, _ = measure(sort_fns[impl], pos, iters=3)
        metrics[f"{impl}_sort_us_per_update"] = t.steady_us
        t, _ = measure(tree_fns[impl], pos, vac, iters=3)
        metrics[f"{impl}_tree_us_per_update"] = t.steady_us
        t, _ = measure(apply_fns[impl], *apply_args, iters=3)
        metrics[f"{impl}_apply_us_per_update"] = t.steady_us
    exch = make_exchange_fn(base, mesh)
    t, _ = measure(exch, st, iters=3)
    metrics["exchange_us_per_update"] = t.steady_us

    metrics["reference_sort_hbm_bytes"] = \
        roofline_of(sort_fns["reference"], pos)
    metrics["reference_tree_hbm_bytes"] = \
        roofline_of(tree_fns["reference"], pos, vac)
    metrics["reference_apply_hbm_bytes"] = \
        roofline_of(apply_fns["reference"], *apply_args)
    metrics["fused_sort_hbm_bytes"] = morton_sort_hbm_bytes(n)
    # the scatter-add/aggregation back half is shared: fused tree = the
    # reference build with the sort term swapped for the kernel's traffic
    metrics["fused_tree_hbm_bytes"] = \
        metrics["reference_tree_hbm_bytes"] - \
        metrics["reference_sort_hbm_bytes"] + metrics["fused_sort_hbm_bytes"]
    qm = num_ranks * cap
    metrics["fused_apply_hbm_bytes"] = (
        2 * route_build_hbm_bytes(n, s_max, num_ranks, cap) +
        2 * apply_hbm_bytes(n, s_max, qm, 8) +      # deletion drains
        apply_hbm_bytes(n, s_max, 8, q))            # accept pass
    metrics["exchange_hbm_bytes"] = exchange_hbm_bytes(base, num_ranks, q)

    pb_bytes, q, tree, stacked = phase_b_reference_bytes(base, st, num_ranks)
    metrics["reference_phase_b_hbm_bytes"] = pb_bytes
    n_levels, c_max = stacked.counts.shape
    kernel_bytes = traverse_hbm_bytes(
        n_levels, c_max, tree.leaf_members.shape[0],
        tree.leaf_members.shape[1], n, q)
    metrics["fused_phase_b_hbm_bytes"] = kernel_bytes
    # fused total: swap each replaced reference stage for its kernel's
    # analytic traffic (the tree build swaps only its sort half — the
    # aggregation back half is shared and stays in the total)
    metrics["fused_hbm_bytes_per_update"] = max(
        metrics["reference_hbm_bytes_per_update"] - pb_bytes -
        metrics["reference_sort_hbm_bytes"] -
        metrics["reference_apply_hbm_bytes"] + kernel_bytes +
        metrics["fused_sort_hbm_bytes"] + metrics["fused_apply_hbm_bytes"],
        float(kernel_bytes))
    metrics["hbm_bytes_ratio"] = metrics["reference_hbm_bytes_per_update"] / \
        max(metrics["fused_hbm_bytes_per_update"], 1.0)
    assert metrics["hbm_bytes_ratio"] >= 1.0, \
        f"fused must not touch MORE HBM, got {metrics['hbm_bytes_ratio']:.2f}x"
    params = {"n_per_rank": n, "s_max": s_max,
              "num_ranks": num_ranks, "phase_b_queries": q}
    return params, metrics


def main():
    smoke = "--smoke" in sys.argv
    sizes = [64] if smoke else [256, 1024]
    mesh = engine.make_brain_mesh()
    cases = {}
    for n in sizes:
        params, metrics = bench_one(n, mesh)
        cases[f"n{n}"] = telemetry.report.case(params, metrics)
        emit(f"connectivity_reference_n{n}",
             metrics["reference_steady_us_per_update"],
             f"hbm_B/update={metrics['reference_hbm_bytes_per_update']:.0f} "
             f"compile_ms={metrics['reference_compile_ms']:.0f}")
        emit(f"connectivity_fused_n{n}",
             metrics["fused_steady_us_per_update"],
             f"hbm_B/update={metrics['fused_hbm_bytes_per_update']:.0f} "
             f"({metrics['hbm_bytes_ratio']:.1f}x less) "
             f"compile_ms={metrics['fused_compile_ms']:.0f}")
        for stage in ("sort", "tree", "apply"):
            emit(f"connectivity_{stage}_n{n}",
                 metrics[f"fused_{stage}_us_per_update"],
                 f"ref_us={metrics[f'reference_{stage}_us_per_update']:.0f} "
                 f"ref_B={metrics[f'reference_{stage}_hbm_bytes']:.0f} "
                 f"fused_B={metrics[f'fused_{stage}_hbm_bytes']:.0f}")
        emit(f"connectivity_exchange_n{n}",
             metrics["exchange_us_per_update"],
             f"B/update={metrics['exchange_hbm_bytes']:.0f}")
    rep = telemetry.report.make_report(
        "connectivity", cases, smoke=smoke,
        mesh={"num_ranks": mesh.shape["ranks"],
              "backend": jax.default_backend()},
        spans=telemetry.export())
    out = "BENCH_connectivity_smoke.json" if smoke \
        else "BENCH_connectivity.json"
    telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
