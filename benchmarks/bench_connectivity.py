"""Connectivity-update cost: reference jnp phase-B vs the fused Pallas
Barnes-Hut traversal kernel (connectivity_impl).

Times one full connectivity update (deletion routing + octree build +
phase A + phase B + accept) on a single rank for both lowerings — compile
and steady state reported separately (``_util.measure``) — and counts
materialized HBM bytes:

  reference  ``roofline.materialized_bytes`` of the optimized HLO of the
             whole update — every (Q, F) frontier temporary the restart
             loop materializes is counted trip-aware. NB on CPU XLA
             additionally *serializes* the frontier scatters into
             per-update-element while loops, so the reference count is a
             lowering-specific upper proxy (the metric's documented
             contract: relative comparisons of lowerings, not absolute
             HBM truth);
  fused      the reference total minus the roofline bytes of the standalone
             phase-B lowering, plus the traversal kernel's analytic
             streaming traffic (``bh_traverse.traverse_hbm_bytes``: tree +
             members + neuron data + queries in once, results out once,
             zero per-round temporaries). On CPU the kernel runs in
             interpret mode, whose HLO inlines the *interpreter*, so the
             TPU custom call's traffic is computed in closed form instead
             (the same accounting bench_activity uses).

Emits CSV and writes a ``repro.telemetry/v1`` report: ``--smoke`` (n=64)
to ``BENCH_connectivity_smoke.json``, otherwise ``BENCH_connectivity.json``
(n per rank in {256, 1024}) — the committed baseline
``benchmarks/check_regression.py`` gates against (the smoke file is
separate so reproducing the CI step locally cannot clobber the baseline).

The committed baseline additionally carries the smoke-scale ``n64`` case
captured under CI's gate environment (4 host devices — the byte model
depends on device count via ``q = num_ranks * cap_requests``, and the
ratio is not scale-free below n=256), so the smoke gate pairs it by exact
name at matched params. Regenerate that case with
``XLA_FLAGS=--xla_force_host_platform_device_count=4 ... --smoke`` and
copy it into the baseline; the n256/n1024 cases come from the plain
single-device run.
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks._util import ROOT, emit, measure
from repro import compat, telemetry
from repro.configs.msp_brain import BrainConfig
from repro.connectome import routing, traverse
from repro.connectome import tree as ctree
from repro.core import engine
from repro.kernels.bh_traverse import traverse_hbm_bytes
from repro.launch import roofline
from repro.sim import Simulator
from repro.sim import phases as sim_phases


def make_conn_fn(cfg, mesh):
    """Standalone connectivity update through the facade's PhaseContext +
    registry dispatch."""
    num_ranks = mesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)

    def body(st):
        ctx = sim_phases.make_context(cfg, jax.lax.axis_index("ranks"),
                                      "ranks", num_ranks)
        return sim_phases.connectivity_phase(st, ctx)

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                    out_specs=specs, check_vma=False))


def phase_b_reference_bytes(cfg, st, num_ranks):
    """Roofline bytes of the standalone jnp phase-B at the update's shapes
    (the part the fused kernel replaces)."""
    n = cfg.neurons_per_rank
    q = num_ranks * routing.cap_requests(cfg, num_ranks)
    vac = jnp.maximum(st.neurons.de_elements, 0.0)
    tree = ctree.build_local_tree(st.positions, vac, 0, cfg, num_ranks)
    stacked = traverse.stack_levels(tree.counts, tree.centroids, 0)
    kw = dict(seed=cfg.seed, sizes=stacked.sizes, theta=cfg.theta,
              sigma=cfg.sigma, frontier=cfg.frontier_cap,
              n_levels=cfg.local_levels + 1)

    def f(counts, cents, members, npos, vac, x, start, gids, valid):
        return traverse.phase_b_core(counts, cents, members, npos, vac, x,
                                     start, gids, valid, jnp.int32(0),
                                     jnp.int32(0), **kw)

    args = (stacked.counts, stacked.centroids, tree.leaf_members,
            st.positions, vac, jnp.zeros((q, 3), jnp.float32),
            jnp.zeros((q,), jnp.int32), jnp.zeros((q,), jnp.int32),
            jnp.ones((q,), bool))
    hlo = jax.jit(f).lower(*args).compile().as_text()
    return roofline.materialized_bytes(hlo), q, tree, stacked


def bench_one(n, mesh):
    base = BrainConfig(neurons_per_rank=n, local_levels=3, frontier_cap=32)
    num_ranks = mesh.shape["ranks"]

    # one plasticity round first so the edge tables/rates are representative
    st = Simulator.from_config(base, mesh=mesh).step()
    jax.block_until_ready(st.positions)

    metrics = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(base, connectivity_impl=impl)
        fn = make_conn_fn(cfg, mesh)
        with telemetry.span(f"bench.connectivity.{impl}", n=n):
            timing, _ = measure(fn, st, iters=3)
        metrics[f"{impl}_compile_ms"] = timing.compile_ms
        metrics[f"{impl}_steady_us_per_update"] = timing.steady_us
        if impl == "reference":
            hlo = fn.lower(st).compile().as_text()
            metrics["reference_hbm_bytes_per_update"] = \
                roofline.materialized_bytes(hlo)

    pb_bytes, q, tree, stacked = phase_b_reference_bytes(base, st, num_ranks)
    metrics["reference_phase_b_hbm_bytes"] = pb_bytes
    n_levels, c_max = stacked.counts.shape
    kernel_bytes = traverse_hbm_bytes(
        n_levels, c_max, tree.leaf_members.shape[0],
        tree.leaf_members.shape[1], n, q)
    metrics["fused_phase_b_hbm_bytes"] = kernel_bytes
    metrics["fused_hbm_bytes_per_update"] = \
        metrics["reference_hbm_bytes_per_update"] - pb_bytes + kernel_bytes
    metrics["hbm_bytes_ratio"] = metrics["reference_hbm_bytes_per_update"] / \
        max(metrics["fused_hbm_bytes_per_update"], 1.0)
    assert metrics["hbm_bytes_ratio"] >= 1.0, \
        f"fused must not touch MORE HBM, got {metrics['hbm_bytes_ratio']:.2f}x"
    params = {"n_per_rank": n, "s_max": base.max_synapses,
              "num_ranks": num_ranks, "phase_b_queries": q}
    return params, metrics


def main():
    smoke = "--smoke" in sys.argv
    sizes = [64] if smoke else [256, 1024]
    mesh = engine.make_brain_mesh()
    cases = {}
    for n in sizes:
        params, metrics = bench_one(n, mesh)
        cases[f"n{n}"] = telemetry.report.case(params, metrics)
        emit(f"connectivity_reference_n{n}",
             metrics["reference_steady_us_per_update"],
             f"hbm_B/update={metrics['reference_hbm_bytes_per_update']:.0f} "
             f"compile_ms={metrics['reference_compile_ms']:.0f}")
        emit(f"connectivity_fused_n{n}",
             metrics["fused_steady_us_per_update"],
             f"hbm_B/update={metrics['fused_hbm_bytes_per_update']:.0f} "
             f"({metrics['hbm_bytes_ratio']:.1f}x less) "
             f"compile_ms={metrics['fused_compile_ms']:.0f}")
    rep = telemetry.report.make_report(
        "connectivity", cases, smoke=smoke,
        mesh={"num_ranks": mesh.shape["ranks"],
              "backend": jax.default_backend()},
        spans=telemetry.export())
    out = "BENCH_connectivity_smoke.json" if smoke \
        else "BENCH_connectivity.json"
    telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
