"""Paper Fig. 11 / §V-E: total simulation time, all-old vs all-new algorithm
pairs, largest feasible local configuration.

Emits CSV and — with ``--json`` or ``--smoke`` — a ``repro.telemetry/v1``
report with the compile/steady split and the all-new run's device
counters/histograms: ``--smoke`` (small n, for CI) writes
``BENCH_fig11_smoke.json``, otherwise ``BENCH_fig11.json`` (the committed
baseline the regression gate compares against).
"""
import os
import sys

from benchmarks._util import ROOT, brain_sim_timed, emit


def main():
    smoke = "--smoke" in sys.argv
    write_json = smoke or "--json" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    n = int(args[0]) if args else (64 if smoke else 512)
    import jax
    from repro import telemetry
    r = len(jax.devices())
    levels, frontier, s_max = (3, 32, 8) if smoke else (4, 64, 32)
    metrics, sims = {}, {}
    for conn, spike, tag in (("old", "old", "old"), ("new", "new", "new")):
        with telemetry.span(f"bench.fig11.{tag}", n=n):
            timing, sims[tag] = brain_sim_timed(dict(
                neurons_per_rank=n, local_levels=levels,
                frontier_cap=frontier, max_synapses=s_max,
                connectivity_alg=conn, spike_alg=spike,
                requests_cap_factor=1), chunks=2)
        metrics[f"{tag}_compile_ms"] = timing.compile_ms
        metrics[f"{tag}_steady_us_per_chunk"] = timing.steady_us
    metrics["walltime_reduction_pct"] = 100 * (
        1 - metrics["new_steady_us_per_chunk"]
        / metrics["old_steady_us_per_chunk"])
    emit(f"fig11_total_old_r{r}_n{n}", metrics["old_steady_us_per_chunk"],
         f"compile_ms={metrics['old_compile_ms']:.0f}")
    emit(f"fig11_total_new_r{r}_n{n}", metrics["new_steady_us_per_chunk"],
         f"walltime_reduction={metrics['walltime_reduction_pct']:.1f}%")
    if write_json:
        device_metrics = sims["new"].metrics()
        # analytic bytes/FLOPs of the all-new chunk's compiled HLO — the
        # roofline source merged next to the measured counters
        roofline = telemetry.report.roofline_block(
            sims["new"].lower().compile().as_text(), r)
        params = {"num_ranks": r, "n_per_rank": n, "s_max": s_max,
                  "chunks": 3}
        rep = telemetry.report.make_report(
            "fig11", {f"r{r}_n{n}": telemetry.report.case(params, metrics)},
            smoke=smoke,
            mesh={"num_ranks": r, "backend": jax.default_backend()},
            counters=telemetry.report.counters_block(device_metrics),
            histograms=telemetry.report.histograms_block(device_metrics),
            spans=telemetry.export(),
            roofline=roofline)
        out = "BENCH_fig11_smoke.json" if smoke else "BENCH_fig11.json"
        telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
