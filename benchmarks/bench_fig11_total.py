"""Paper Fig. 11 / §V-E: total simulation time, all-old vs all-new algorithm
pairs, largest feasible local configuration."""
import sys

from benchmarks._util import brain_sim, emit


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 512
    import jax
    r = len(jax.devices())
    times = {}
    for conn, spike, tag in (("old", "old", "old"), ("new", "new", "new")):
        dt, st = brain_sim(dict(
            neurons_per_rank=n, local_levels=4, frontier_cap=64,
            max_synapses=32, connectivity_alg=conn, spike_alg=spike,
            requests_cap_factor=1), chunks=2)
        times[tag] = dt
    red = 100 * (1 - times["new"] / times["old"])
    emit(f"fig11_total_old_r{r}_n{n}", times["old"] * 1e6)
    emit(f"fig11_total_new_r{r}_n{n}", times["new"] * 1e6,
         f"walltime_reduction={red:.1f}%")


if __name__ == "__main__":
    main()
