"""Benchmark harness — one benchmark per paper table/figure (+ beyond-paper
LM benches). Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # quick pass
  PYTHONPATH=src python -m benchmarks.run --scaling  # + weak-scaling sweep
"""
import sys

from benchmarks._util import run_sub


def main() -> None:
    scaling = "--scaling" in sys.argv
    print("name,us_per_call,derived")
    # paper figures/tables (brain sim), reduced CPU scale
    rank_counts = (1, 2, 4, 8) if scaling else (4,)
    for r in rank_counts:
        sys.stdout.write(run_sub("benchmarks.bench_fig3_connectivity", r, 256))
        # old vs new spike alg + dense vs sparse rate exchange (CSV only;
        # refresh the committed BENCH_spikes.json baseline by running the
        # module directly with 4 devices: bench_fig4_spikes 1024 --json)
        sys.stdout.write(run_sub("benchmarks.bench_fig4_spikes", r, 256))
    sys.stdout.write(run_sub("benchmarks.bench_fig5_lookup", 1, 4096))
    sys.stdout.write(run_sub("benchmarks.bench_tab12_bytes", 4, 256))
    sys.stdout.write(run_sub("benchmarks.bench_fig11_total", 4, 512))
    sys.stdout.write(run_sub("benchmarks.bench_activity", 1, 256))
    # --smoke: the full n=256/1024 baseline brushes the subprocess timeout;
    # refresh BENCH_connectivity.json by running the module directly
    sys.stdout.write(run_sub("benchmarks.bench_connectivity", 1, "--smoke"))
    sys.stdout.write(run_sub("benchmarks.bench_fig89_quality", 8))
    sys.stdout.write(run_sub("benchmarks.bench_scenarios", 1))
    # beyond-paper: the technique inside the LM framework
    sys.stdout.write(run_sub("benchmarks.bench_lm_moe", 8))
    sys.stdout.write(run_sub("benchmarks.bench_decode_splitkv", 8))


if __name__ == "__main__":
    main()
