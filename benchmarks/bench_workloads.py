"""Workload quality bench: function next to speed (DESIGN.md §13).

Three cases through the ``repro.workloads`` subsystem:

  * ``engram``       train/lesion/recall pattern completion grown from an
                     empty connectome — quality metrics ``recall_overlap``
                     (gated: must not regress) and ``engram_selectivity``;
  * ``engram_conn``  the same protocol started from a generated
                     hemibrain-shaped surrogate via
                     ``Simulator.from_connectome`` (heavy-tailed degrees
                     through the full rewiring path);
  * ``assim``        the rate-assimilation loop — ``assim_final_abs_err``
                     (convergence, gated) and ``dyn_compile_count``
                     (retrace-free dynamic params: gated exactly).

Writes a ``repro.telemetry/v1`` report; ``--smoke`` to
``BENCH_workloads_smoke.json`` (CI candidate), ``--json`` to the
committed ``BENCH_workloads.json``. The committed baseline is captured
at smoke scale in the CI gate environment (4 host devices) so the smoke
run pairs with it at matched params and the quality rules apply tightly.
"""
import dataclasses
import os
import sys
import time


from benchmarks._util import ROOT, emit


def bench(n):
    import jax
    from repro import telemetry
    from repro.configs.msp_brain import SMOKE_CONFIG
    from repro.workloads import assimilate as was
    from repro.workloads import datasets as wds
    from repro.workloads import engram as weng

    r = len(jax.devices())
    cfg = dataclasses.replace(SMOKE_CONFIG, neurons_per_rank=n,
                              requests_cap_factor=1000)
    spec = weng.EngramSpec()
    cases = {}

    with telemetry.span("bench.workloads.engram", n=n):
        t0 = time.perf_counter()
        m, sim = weng.run_engram(cfg, spec=spec)
        m["engram_wall_ms"] = (time.perf_counter() - t0) * 1e3
        m["synapses_formed"] = sim.stats()["synapses_formed"]
    params = {"num_ranks": r, "n_per_rank": n,
              "chunks": spec.total_chunks}
    cases[f"engram_r{r}_n{n}"] = telemetry.report.case(params, m)
    device_metrics = sim.metrics()
    emit(f"workloads_engram_r{r}_n{n}", m["engram_wall_ms"] * 1e3,
         f"recall_overlap={m['recall_overlap']:.3f} "
         f"selectivity={m['engram_selectivity']:.3f}")

    with telemetry.span("bench.workloads.engram_conn", n=n):
        ds = wds.generate_hemibrain_surrogate(
            r * n, n, max_degree=cfg.max_synapses,
            fraction_excitatory=cfg.fraction_excitatory)
        t0 = time.perf_counter()
        mc, simc = weng.run_engram(cfg, spec=spec, dataset=ds)
        mc["engram_wall_ms"] = (time.perf_counter() - t0) * 1e3
        mc["initial_synapses"] = float(ds.num_edges)
    cases[f"engram_conn_r{r}_n{n}"] = telemetry.report.case(params, mc)
    emit(f"workloads_engram_conn_r{r}_n{n}", mc["engram_wall_ms"] * 1e3,
         f"recall_overlap={mc['recall_overlap']:.3f} "
         f"edges={ds.num_edges}")

    with telemetry.span("bench.workloads.assim", n=n):
        t0 = time.perf_counter()
        res, _ = was.run_assimilation(cfg)
        wall_ms = (time.perf_counter() - t0) * 1e3
    ma = {"assim_final_abs_err": res.final_abs_err,
          "assim_first_abs_err": float(res.abs_err[0]),
          "dyn_compile_count": float(res.compile_count),
          "assim_wall_ms": wall_ms}
    assert res.compile_count == 1, res.compile_count
    cases[f"assim_r{r}_n{n}"] = telemetry.report.case(
        {"num_ranks": r, "n_per_rank": n,
         "chunks": res.target.shape[0]}, ma)
    emit(f"workloads_assim_r{r}_n{n}", wall_ms * 1e3,
         f"final_abs_err={res.final_abs_err:.5f} "
         f"compiles={res.compile_count}")
    return cases, device_metrics


def main():
    smoke = "--smoke" in sys.argv
    write_json = smoke or "--json" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    n = int(args[0]) if args else 64
    import jax
    from repro import telemetry
    r = len(jax.devices())
    cases, device_metrics = bench(n)
    if write_json:
        out = "BENCH_workloads_smoke.json" if smoke \
            else "BENCH_workloads.json"
        quality = {f"{cname}/{k}": c["metrics"][k]
                   for cname, c in cases.items()
                   for k in ("recall_overlap", "engram_selectivity",
                             "assim_final_abs_err")
                   if k in c["metrics"]}
        rep = telemetry.report.make_report(
            "workloads", cases, smoke=smoke,
            mesh={"num_ranks": r, "backend": jax.default_backend()},
            counters=telemetry.report.counters_block(device_metrics),
            quality=quality,
            spans=telemetry.export())
        telemetry.report.write(os.path.join(ROOT, out), rep)


if __name__ == "__main__":
    main()
