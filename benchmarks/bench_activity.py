"""Activity-phase cost: reference jnp scan vs fused Pallas megakernel.

Times one rate window (Delta electrical steps, no connectivity update) of
the engine's activity phase on a single rank, and counts the HBM bytes one
*step* touches:

  reference  ``roofline.materialized_bytes`` of the optimized HLO of the
             activity window / Delta — every per-step ``(n, s_max)``
             temporary the scan materializes is counted trip-aware;
  fused      analytic streaming traffic of the single ``pallas_call``
             (``activity_fused.window_hbm_bytes``) / Delta. On CPU the
             kernel runs in interpret mode, whose HLO inlines the
             *interpreter*, so the TPU custom call's traffic (operands in
             once, state out once, zero per-step temporaries) is computed
             in closed form instead.

Emits CSV and writes ``BENCH_activity.json`` at the repo root — the
baseline the perf trajectory records against.
"""
import dataclasses
import json
import os
import sys

import jax

from benchmarks._util import ROOT, emit, time_fn
from repro import compat
from repro.configs.msp_brain import BrainConfig
from repro.core import engine
from repro.kernels.activity_fused import window_hbm_bytes
from repro.launch import roofline
from repro.sim import Simulator
from repro.sim import phases as sim_phases


def make_activity_fn(cfg, mesh):
    """Standalone activity-phase step (no connectivity update) through the
    facade's PhaseContext + registry dispatch."""
    num_ranks = mesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)

    def body(st):
        ctx = sim_phases.make_context(cfg, jax.lax.axis_index("ranks"),
                                      "ranks", num_ranks)
        return sim_phases.activity_phase(st, ctx)

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                    out_specs=specs, check_vma=False))


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 256
    base = BrainConfig(neurons_per_rank=n, local_levels=3, frontier_cap=32)
    mesh = engine.make_brain_mesh()
    num_ranks = mesh.shape["ranks"]
    delta = base.rate_period

    # one plasticity round first so the edge tables/rates are representative
    st = Simulator.from_config(base, mesh=mesh).step()
    jax.block_until_ready(st.positions)

    report = {"n_per_rank": n, "s_max": base.max_synapses,
              "num_ranks": num_ranks, "delta": delta}
    times = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(base, activity_impl=impl)
        act = make_activity_fn(cfg, mesh)
        dt, _ = time_fn(act, st, iters=3)
        times[impl] = dt
        report[f"{impl}_us_per_step"] = dt / delta * 1e6
        if impl == "reference":
            hlo = act.lower(st).compile().as_text()
            report["reference_hbm_bytes_per_step"] = \
                roofline.materialized_bytes(hlo) / delta
    report["fused_hbm_bytes_per_step"] = \
        window_hbm_bytes(n, base.max_synapses, num_ranks) / delta
    ratio = report["reference_hbm_bytes_per_step"] / \
        max(report["fused_hbm_bytes_per_step"], 1.0)
    report["hbm_bytes_ratio"] = ratio
    assert ratio >= 3.0, f"fused HBM traffic must drop >=3x, got {ratio:.2f}"

    with open(os.path.join(ROOT, "BENCH_activity.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit(f"activity_reference_n{n}", times["reference"] / delta * 1e6,
         f"hbm_B/step={report['reference_hbm_bytes_per_step']:.0f}")
    emit(f"activity_fused_n{n}", times["fused"] / delta * 1e6,
         f"hbm_B/step={report['fused_hbm_bytes_per_step']:.0f} "
         f"({ratio:.0f}x less)")


if __name__ == "__main__":
    main()
