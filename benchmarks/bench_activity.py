"""Activity-phase cost: reference jnp scan vs fused Pallas megakernel.

Times one rate window (Delta electrical steps, no connectivity update) of
the engine's activity phase on a single rank — compile and steady state
reported separately (``_util.measure``) — and counts the HBM bytes one
*step* touches:

  reference  ``roofline.materialized_bytes`` of the optimized HLO of the
             activity window / Delta — every per-step ``(n, s_max)``
             temporary the scan materializes is counted trip-aware;
  fused      analytic streaming traffic of the single ``pallas_call``
             (``activity_fused.window_hbm_bytes``) / Delta. On CPU the
             kernel runs in interpret mode, whose HLO inlines the
             *interpreter*, so the TPU custom call's traffic (operands in
             once, state out once, zero per-step temporaries) is computed
             in closed form instead.

Emits CSV and writes a ``repro.telemetry/v1`` report: ``--smoke`` (n=64)
to ``BENCH_activity_smoke.json``, otherwise ``BENCH_activity.json`` —
the committed baseline ``benchmarks/check_regression.py`` gates against
(reproducing the CI smoke step locally cannot clobber the baseline).
"""
import dataclasses
import os
import sys

import jax

from benchmarks._util import ROOT, emit, measure
from repro import compat, telemetry
from repro.configs.msp_brain import BrainConfig
from repro.core import engine
from repro.kernels.activity_fused import window_hbm_bytes
from repro.launch import roofline
from repro.sim import Simulator
from repro.sim import phases as sim_phases


def make_activity_fn(cfg, mesh):
    """Standalone activity-phase step (no connectivity update) through the
    facade's PhaseContext + registry dispatch."""
    num_ranks = mesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)

    def body(st):
        ctx = sim_phases.make_context(cfg, jax.lax.axis_index("ranks"),
                                      "ranks", num_ranks)
        return sim_phases.activity_phase(st, ctx)

    return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                    out_specs=specs, check_vma=False))


def main():
    smoke = "--smoke" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    n = int(args[0]) if args else (64 if smoke else 256)
    base = BrainConfig(neurons_per_rank=n, local_levels=3, frontier_cap=32)
    mesh = engine.make_brain_mesh()
    num_ranks = mesh.shape["ranks"]
    delta = base.rate_period

    # one plasticity round first so the edge tables/rates are representative
    st = Simulator.from_config(base, mesh=mesh).step()
    jax.block_until_ready(st.positions)

    metrics = {}
    timings = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(base, activity_impl=impl)
        act = make_activity_fn(cfg, mesh)
        with telemetry.span(f"bench.activity.{impl}", n=n):
            timing, _ = measure(act, st, iters=3)
        timings[impl] = timing
        metrics[f"{impl}_compile_ms"] = timing.compile_ms
        metrics[f"{impl}_steady_us_per_step"] = timing.steady_us / delta
        if impl == "reference":
            hlo = act.lower(st).compile().as_text()
            metrics["reference_hbm_bytes_per_step"] = \
                roofline.materialized_bytes(hlo) / delta
    metrics["fused_hbm_bytes_per_step"] = \
        window_hbm_bytes(n, base.max_synapses, num_ranks,
                         num_steps=delta) / delta
    ratio = metrics["reference_hbm_bytes_per_step"] / \
        max(metrics["fused_hbm_bytes_per_step"], 1.0)
    metrics["hbm_bytes_ratio"] = ratio
    assert ratio >= 3.0, f"fused HBM traffic must drop >=3x, got {ratio:.2f}"

    params = {"n_per_rank": n, "s_max": base.max_synapses,
              "num_ranks": num_ranks, "delta": delta}
    rep = telemetry.report.make_report(
        "activity", {f"n{n}": telemetry.report.case(params, metrics)},
        smoke=smoke, mesh={"num_ranks": num_ranks,
                           "backend": jax.default_backend()},
        spans=telemetry.export())
    out = "BENCH_activity_smoke.json" if smoke else "BENCH_activity.json"
    telemetry.report.write(os.path.join(ROOT, out), rep)
    emit(f"activity_reference_n{n}", metrics["reference_steady_us_per_step"],
         f"hbm_B/step={metrics['reference_hbm_bytes_per_step']:.0f} "
         f"compile_ms={metrics['reference_compile_ms']:.0f}")
    emit(f"activity_fused_n{n}", metrics["fused_steady_us_per_step"],
         f"hbm_B/step={metrics['fused_hbm_bytes_per_step']:.0f} "
         f"({ratio:.0f}x less) compile_ms={metrics['fused_compile_ms']:.0f}")


if __name__ == "__main__":
    main()
