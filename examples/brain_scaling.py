"""Weak-scaling comparison of the paper's old vs new algorithms over multiple
(emulated) ranks — reproduces the shape of paper Figs. 3/4 and Tables I/II at
CPU scale. Spawns subprocesses with 1..8 host devices.

  PYTHONPATH=src python examples/brain_scaling.py
"""
import os
import subprocess
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

CODE = r"""
import dataclasses, time, sys
import jax
from repro.configs.msp_brain import BrainConfig
from repro.sim import Simulator
from benchmarks._util import paper_bytes_from_stats

r = len(jax.devices())
for conn, spike in (("old", "old"), ("new", "new")):
    cfg = BrainConfig(neurons_per_rank=256, local_levels=3, frontier_cap=32,
                      max_synapses=16, connectivity_alg=conn, spike_alg=spike,
                      requests_cap_factor=1)
    sim = Simulator.from_config(cfg)
    st = sim.step()   # compile + first plasticity round
    jax.block_until_ready(st.positions)
    t0 = time.time()
    for _ in range(2):
        st = sim.step()
    jax.block_until_ready(st.positions)
    dt = (time.time() - t0) / 2
    b, s = paper_bytes_from_stats(st.stats, conn, spike, r)
    print(f"ranks={r} {conn}/{spike}: {dt*1e3:8.1f} ms/chunk  "
          f"paper-bytes={b/1e6:8.2f} MB  formed={s['synapses_formed']:.0f}",
          flush=True)
"""


def main():
    for devices in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = "src" + os.pathsep + "."
        out = subprocess.run([sys.executable, "-c", CODE], env=env,
                             capture_output=True, text=True, timeout=560)
        sys.stdout.write(out.stdout)
        if out.returncode != 0:
            sys.stderr.write(out.stderr[-800:])


if __name__ == "__main__":
    main()
