"""Run a library scenario on the MSP brain and print per-region dynamics.

  PYTHONPATH=src python examples/run_scenario.py lesion_rewiring
  PYTHONPATH=src python examples/run_scenario.py focal_stimulation --chunks 30
  PYTHONPATH=src python examples/run_scenario.py baseline_growth --smoke

Scenarios: baseline_growth | focal_stimulation | lesion_rewiring
(--smoke caps the run at 6 chunks for CI).
"""
import sys
import time

sys.path.insert(0, "src")

from repro.scenarios import library  # noqa: E402


def main(argv):
    name = argv[1] if len(argv) > 1 else "lesion_rewiring"
    scn = library.get_scenario(name)
    chunks = scn.num_chunks
    if "--chunks" in argv:
        chunks = int(argv[argv.index("--chunks") + 1])
    if "--smoke" in argv:
        chunks = min(chunks, 6)
    cfg = library.SMOKE_SCENARIO_CONFIG
    names = [r.name for r in scn.regions] + ["rest"]

    print(f"== scenario {scn.name}: {cfg.neurons_per_rank} neurons/rank, "
          f"{chunks} chunks of {cfg.rate_period} steps ==")
    for ev in scn.events:
        print(f"   event: {ev}")
    t0 = time.time()
    st, hist = library.run_scenario(scn, cfg, num_chunks=chunks)
    dt = time.time() - t0

    hdr = "  ".join(f"{n:>12s}" for n in names)
    print(f"{'step':>6s}  {hdr}   (synapses by source region | mean calcium)")
    for i in range(hist["synapses"].shape[0]):
        syn = "  ".join(f"{v:12.0f}" for v in hist["synapses"][i])
        ca = "  ".join(f"{v:.3f}" for v in hist["calcium"][i])
        print(f"{(i + 1) * cfg.rate_period:6d}  {syn}   | {ca}")
    total = int((st.out_edges >= 0).sum())
    print(f"== done in {dt:.1f}s: {total} synapses, "
          f"mean rate {float(st.neurons.rate.mean()) * 1000:.1f} Hz ==")


if __name__ == "__main__":
    main(sys.argv)
