"""Quickstart: the paper's MSP brain simulation on CPU through the
``repro.sim.Simulator`` facade, comparing the OLD (download remote
subtrees + per-step spike IDs) and NEW (location-aware requests +
Delta-periodic rates) algorithm pairs at small scale, then showing the
homeostatic loop drive calcium toward the target.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs.msp_brain import BrainConfig  # noqa: E402
from repro.sim import Simulator  # noqa: E402


def main():
    base = BrainConfig(neurons_per_rank=64, local_levels=3, frontier_cap=32,
                       max_synapses=24, fraction_excitatory=1.0,
                       requests_cap_factor=64)
    print("== algorithm comparison (1 rank, 64 neurons, 3 plasticity rounds) ==")
    for conn, spike in (("old", "old"), ("new", "new")):
        cfg = dataclasses.replace(base, connectivity_alg=conn, spike_alg=spike)
        sim = Simulator.from_config(cfg)
        t0 = time.time()
        sim.run(3)                       # ONE jitted scan over the 3 chunks
        jax.block_until_ready(sim.state.positions)
        s = sim.stats()
        print(f"  {conn}/{spike}: {time.time() - t0:5.1f}s  "
              f"synapses={s['synapses_formed']:.0f}  "
              f"tree_nodes_downloaded={s['tree_nodes_downloaded']:.0f}  "
              f"spike_ids_sent={s['spikes_sent']:.0f}")

    print("== homeostasis: calcium -> target 0.7 (paper Figs 8/9 dynamics) ==")
    sim = Simulator.from_config(base)
    for i in range(4):
        st = sim.run(10)                 # the run(10) scan compiles once
        ca = float(st.neurons.calcium.mean())
        syn = float((st.in_edges >= 0).sum()) / base.neurons_per_rank
        print(f"  step {100 * 10 * (i + 1):5d}: calcium={ca:.3f} "
              f"(target {base.target_calcium}) synapses/neuron={syn:.1f}")


if __name__ == "__main__":
    main()
