"""End-to-end driver: train a ~100M-parameter dense LM on the synthetic
pattern stream with the production machinery (sharding rules, AdamW,
checkpointing, fault-tolerant runner).

  PYTHONPATH=src python examples/train_lm.py --steps 40     # quick (CPU)
  PYTHONPATH=src python examples/train_lm.py --steps 300    # full curve
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.launch.train import build_everything  # noqa: E402
from repro.runtime.fault_tolerance import (RunnerConfig,  # noqa: E402
                                           TrainingRunner)

# ~106M params: 10L x d640 x ff2560, 32k vocab
CONFIG_100M = ModelConfig(
    name="dense-100m", family="dense", num_layers=10, d_model=640,
    num_heads=10, num_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=32_000,
    scan_layers=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    print(f"params: {CONFIG_100M.param_count() / 1e6:.0f}M")
    mesh = make_mesh((1, 1), ("data", "model"))
    api, params, opt, step, data = build_everything(
        CONFIG_100M, mesh, args.batch, args.seq, steps=args.steps)
    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=max(args.steps // 3, 20)),
        step, params, opt, data)
    if runner.try_resume():
        print(f"resumed at step {runner.step}")
    runner.run(args.steps)
    data.close()
    h = runner.history
    k = max(len(h) // 8, 1)
    print(f"loss: start={np.mean(h[:k]):.3f} -> end={np.mean(h[-k:]):.3f} "
          f"(ln V = {np.log(32000):.2f})")


if __name__ == "__main__":
    main()
