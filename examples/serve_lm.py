"""Batched serving example: prefill + KV-cache decode across architectures
(full attention, ring-window hybrid, recurrent) with one API.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402


def serve(arch, batch_size=4, prompt=24, gen=12):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(key, (batch_size, prompt), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (batch_size, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (batch_size, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    t0 = time.time()
    logits, state = api.prefill(params, batch,
                                pad_cache_to=extra + prompt + gen)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    decode = jax.jit(lambda p, s, t: api.decode_step(p, s, t))
    outs = [tok]
    for _ in range(gen - 1):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    toks = jnp.stack(outs, 1)
    print(f"{arch:22s} {batch_size}x{prompt}+{gen}: {time.time() - t0:5.1f}s  "
          f"sample={toks[0, :6].tolist()}")


def main():
    for arch in ("qwen3-14b", "recurrentgemma-2b", "xlstm-125m",
                 "whisper-base"):
        serve(arch)


if __name__ == "__main__":
    main()
