"""Multi-tenant simulation service tests (DESIGN.md §12).

In-process (single device): admission/queueing/completion, typed
rejections (overload shed, incompatible budget, fused-template config
error), per-tenant bit-identity vs solo Simulator runs, deadline
cancellation at chunk boundaries, retry with exponential backoff +
recorded spans, the stall watchdog, the degradation ladder
(shrink-then-shed), single-rank fault isolation, and the service
heartbeat.

Subprocess (4 host devices): the acceptance isolation test — B=4
co-batched tenants, one NaN-poisoned via ``chaos.poison_slot_nan``; the
poisoned slot must quarantine + roll back while every co-tenant's final
state is bit-identical to a solo run and its streamed observables are
bit-identical to an unpoisoned service run — across dense and sparse
exchange. Plus 4-rank bit-identity of the batched step itself.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro import telemetry  # noqa: E402
from repro.configs.msp_brain import BrainConfig  # noqa: E402
from repro.core import engine  # noqa: E402
from repro.runtime import chaos  # noqa: E402
from repro.service import (IncompatibleRequest,  # noqa: E402
                           RequestStatus, ServiceConfig,
                           ServiceConfigError, ServiceOverloaded,
                           SimRequest, SimulationService, SlotBatch)
from repro.sim import Simulator  # noqa: E402

SMALL = dict(neurons_per_rank=32, local_levels=3, frontier_cap=32,
             max_synapses=8, rate_period=10, requests_cap_factor=100,
             subs_cap_factor=100)


def run_py(code, devices=4, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(jax.device_get(x)),
                              np.asarray(jax.device_get(y)),
                              equal_nan=True)


@pytest.fixture(scope="module")
def small_cfg():
    return BrainConfig(**SMALL)


# one compiled slot template per width, shared by every service instance
# in this module (the step trace is identical across service restarts)
@pytest.fixture(scope="module")
def batch2(small_cfg):
    return SlotBatch(small_cfg, 2)


@pytest.fixture(scope="module")
def batch4(small_cfg):
    return SlotBatch(small_cfg, 4)


def _solo_final(cfg, seed, chunks):
    sim = Simulator(dataclasses.replace(cfg, seed=seed))
    sim.run(chunks)
    return jax.device_get(sim.state)


# ===================================================================
# admission, completion, typed rejections
# ===================================================================
def test_submit_queue_complete_and_solo_identity(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2,
                                                     queue_cap=4),
                            batch=batch2)
    hs = [svc.submit(SimRequest(seed=s, chunks=c))
          for s, c in ((3, 3), (11, 2), (5, 3))]
    assert [h.status for h in hs] == [RequestStatus.RUNNING,
                                      RequestStatus.RUNNING,
                                      RequestStatus.QUEUED]
    svc.run_until_idle()
    stats = svc.stats()
    assert stats["requests_admitted"] == 3
    assert stats["requests_completed"] == 3
    assert stats["slots_busy"] == 0 and stats["queue_depth"] == 0
    for h in hs:
        r = h.result
        assert r is not None and r.status is RequestStatus.DONE
        assert r.status.terminal
        assert r.chunks_done == h.request.chunks
        # streamed observables: one (tick, chunk, rate, calcium, live)
        # row per tick the tenant ran, chunk column ending at the budget
        assert r.observations.shape[1] == 5
        assert int(r.observations[-1, 1]) == h.request.chunks
        assert r.counters["synapses_formed"] > 0
        # per-tenant final state bit-identical to a solo run
        _leaves_equal(r.final_state,
                      _solo_final(small_cfg, h.request.seed,
                                  h.request.chunks))


def test_overload_shed_typed(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2,
                                                     queue_cap=1),
                            batch=batch2)
    svc.submit(SimRequest(seed=1, chunks=2))
    svc.submit(SimRequest(seed=2, chunks=2))
    svc.submit(SimRequest(seed=3, chunks=2))      # fills the queue
    with pytest.raises(ServiceOverloaded) as ei:
        svc.submit(SimRequest(seed=4, chunks=2))
    assert ei.value.queue_depth == 1 and ei.value.queue_cap == 1
    assert svc.stats()["requests_rejected"] == 1
    assert len(svc.queue) == 1                    # never grew past cap


def test_incompatible_budget_typed(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2),
                            batch=batch2)
    bad = chaos.overflow_slot_config(
        SimRequest(seed=1, chunks=2),
        svc.service_cfg.max_chunks_per_request)
    with pytest.raises(IncompatibleRequest):
        svc.submit(bad)
    with pytest.raises(IncompatibleRequest):
        svc.submit(SimRequest(seed=1, chunks=0))
    assert not svc.queue and svc.stats()["requests_admitted"] == 0


def test_fused_template_rejected(small_cfg):
    fused = dataclasses.replace(small_cfg, activity_impl="fused")
    with pytest.raises(ServiceConfigError):
        SlotBatch(fused, 2)
    with pytest.raises(ServiceConfigError):
        SlotBatch(small_cfg, 0)


def test_shared_batch_width_mismatch(small_cfg, batch2):
    with pytest.raises(ServiceConfigError):
        SimulationService(small_cfg, ServiceConfig(num_slots=4),
                          batch=batch2)


# ===================================================================
# deadlines
# ===================================================================
def test_deadline_cancels_at_boundary_and_frees_slot(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2,
                                                     queue_cap=4),
                            batch=batch2)
    doomed = svc.submit(SimRequest(seed=7, chunks=10_000, deadline_s=0.0))
    ok = svc.submit(SimRequest(seed=8, chunks=2))
    svc.run_until_idle()
    assert doomed.status is RequestStatus.DEADLINE_EXCEEDED
    assert doomed.result.chunks_done < doomed.request.chunks
    assert ok.result.status is RequestStatus.DONE
    stats = svc.stats()
    assert stats["deadline_cancellations"] == 1
    assert stats["slots_busy"] == 0               # the slot was freed


def test_deadline_expires_queued_request(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2,
                                                     queue_cap=4),
                            batch=batch2)
    svc.submit(SimRequest(seed=1, chunks=2))
    svc.submit(SimRequest(seed=2, chunks=2))
    queued = svc.submit(SimRequest(seed=3, chunks=2, deadline_s=0.0))
    assert queued.status is RequestStatus.QUEUED
    svc.run_until_idle()
    assert queued.status is RequestStatus.DEADLINE_EXCEEDED
    assert queued.result.chunks_done == 0
    assert svc.stats()["deadline_cancellations"] == 1


# ===================================================================
# retry / backoff / watchdog
# ===================================================================
def test_transient_fault_retries_with_backoff(small_cfg, batch2):
    telemetry.clear()
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2),
                            batch=batch2)
    svc.chaos_hooks.append(chaos.poison_slot_nan(0, after_chunk=2))
    poisoned = svc.submit(SimRequest(seed=9, chunks=4, max_retries=2))
    svc.run_until_idle()
    r = poisoned.result
    assert r.status is RequestStatus.DONE
    assert r.retries == 1 and len(r.backoffs) == 1
    b = r.backoffs[0]
    assert b.attempt == 1 and b.reason == "health"
    assert 1 <= b.delay_ticks <= 2                # base + jitter
    stats = svc.stats()
    assert stats["quarantines"] == 1 and stats["slot_rollbacks"] == 1
    spans = telemetry.spans("service.backoff")
    assert len(spans) == 1 and spans[0].attrs["attempt"] == 1
    assert telemetry.spans("service.rollback")
    # retry replays from the verified snapshot: still bit-identical
    _leaves_equal(r.final_state, _solo_final(small_cfg, 9, 4))


def test_persistent_fault_exhausts_retries(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2),
                            batch=batch2)

    def always_poison(service):   # re-poison after every step
        chaos.poison_slot_nan(0, after_chunk=0)(service)

    svc.chaos_hooks.append(always_poison)
    doomed = svc.submit(SimRequest(seed=4, chunks=4, max_retries=1))
    svc.run_until_idle(max_ticks=50)
    r = doomed.result
    assert r.status is RequestStatus.FAILED
    assert r.retries == 2                          # 1 retry + final strike
    assert [b.attempt for b in r.backoffs] == [1]
    assert svc.stats()["slot_evictions"] == 1
    assert svc.stats()["slots_busy"] == 0


def test_stall_watchdog_evicts(small_cfg, batch2):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=2,
                                                     stall_patience=2),
                            batch=batch2)
    svc.chaos_hooks.append(chaos.stall_slot(0, ticks=50))
    stuck = svc.submit(SimRequest(seed=6, chunks=30, max_retries=0))
    ok = svc.submit(SimRequest(seed=2, chunks=3))
    svc.run_until_idle(max_ticks=30)
    assert stuck.result.status is RequestStatus.STALLED
    assert stuck.result.backoffs == []
    assert ok.result.status is RequestStatus.DONE
    stats = svc.stats()
    assert stats["stall_evictions"] == 1 and stats["slots_busy"] == 0


# ===================================================================
# degradation ladder
# ===================================================================
def test_degradation_shrinks_then_sheds(small_cfg, batch2):
    svc = SimulationService(
        small_cfg,
        ServiceConfig(num_slots=2, queue_cap=1, chunks_per_tick=4,
                      min_chunks_per_tick=1, overload_patience=1),
        batch=batch2)
    low = svc.submit(SimRequest(seed=1, chunks=200, priority=0))
    high = svc.submit(SimRequest(seed=2, chunks=8, priority=5))
    waiting = svc.submit(SimRequest(seed=3, chunks=2))   # queue full
    svc.run_until_idle(max_ticks=60)
    # ladder rung 1: chunk size halved to the floor before any shedding
    assert svc.chunks_per_tick == 1
    # rung 2: the LOWEST-priority tenant was shed, the high one finished
    assert low.result.status is RequestStatus.SHED
    assert high.result.status is RequestStatus.DONE
    assert waiting.result.status is RequestStatus.DONE
    stats = svc.stats()
    assert stats["requests_shed"] == 1
    assert stats["degrade_events"] >= 3
    shrinks = [e for e in svc.events if e["event"] == "degrade"
               and e["action"] == "shrink_chunks_per_tick"]
    sheds = [e for e in svc.events if e["event"] == "shed"]
    assert shrinks and sheds
    assert max(e["tick"] for e in shrinks) < min(e["tick"]
                                                 for e in sheds)


# ===================================================================
# fault isolation (single-rank; the 4-rank acceptance run is below)
# ===================================================================
def test_poisoned_slot_isolated_single_rank(small_cfg, batch4):
    svc = SimulationService(small_cfg, ServiceConfig(num_slots=4),
                            batch=batch4)
    svc.chaos_hooks.append(
        chaos.poison_slot_nan(1, field="calcium", after_chunk=1))
    seeds = (3, 11, 5, 7)
    hs = [svc.submit(SimRequest(seed=s, chunks=3)) for s in seeds]
    svc.run_until_idle()
    assert svc.stats()["quarantines"] >= 1
    assert hs[1].retries >= 1
    for h in hs:                    # poisoned slot recovered, all DONE,
        r = h.result                # every lane bit-identical to solo
        assert r.status is RequestStatus.DONE
        _leaves_equal(r.final_state,
                      _solo_final(small_cfg, h.request.seed, 3))
    # co-tenant OBSERVABLES also match an unpoisoned service run
    clean = SimulationService(small_cfg, ServiceConfig(num_slots=4),
                              batch=batch4)
    ch = [clean.submit(SimRequest(seed=s, chunks=3)) for s in seeds]
    clean.run_until_idle()
    for i in (0, 2, 3):
        np.testing.assert_array_equal(hs[i].result.observations,
                                      ch[i].result.observations)


# ===================================================================
# heartbeat
# ===================================================================
def test_service_heartbeat(tmp_path, small_cfg, batch2):
    hb = str(tmp_path / "hb.json")
    svc = SimulationService(small_cfg,
                            ServiceConfig(num_slots=2,
                                          heartbeat_path=hb),
                            batch=batch2)
    svc.submit(SimRequest(seed=1, chunks=2))
    svc.run_until_idle()
    with open(hb) as f:
        d = json.load(f)
    assert d["tick"] == svc.tick_count and "t" in d
    assert d["lifecycle"]["requests_completed"] == 1


# ===================================================================
# 4-rank acceptance: isolation across exchange layouts (subprocess)
# ===================================================================
@pytest.mark.parametrize("exchange", ["dense", "sparse"])
def test_isolation_4rank(exchange):
    """B=4 tenants on a 4-rank mesh, slot 1 NaN-poisoned: the poisoned
    slot quarantines + rolls back; every tenant (poisoned one included,
    post-recovery) ends bit-identical to a solo run; co-tenant
    observables are bit-identical to an unpoisoned service run."""
    out = run_py(f"""
        import dataclasses, jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        from repro.runtime import chaos
        from repro.service import (ServiceConfig, SimRequest,
                                   SimulationService, SlotBatch,
                                   RequestStatus)
        from repro.sim import Simulator

        cfg = BrainConfig(**{SMALL!r}, rate_exchange={exchange!r})
        mesh = engine.make_brain_mesh()
        assert mesh.shape["ranks"] == 4
        seeds, chunks = (3, 11, 5, 7), 3
        batch = SlotBatch(cfg, 4, mesh=mesh)

        svc = SimulationService(cfg, ServiceConfig(num_slots=4),
                                mesh=mesh, batch=batch)
        svc.chaos_hooks.append(chaos.poison_slot_nan(1, after_chunk=1))
        hs = [svc.submit(SimRequest(seed=s, chunks=chunks))
              for s in seeds]
        svc.run_until_idle()
        st = svc.stats()
        assert st["quarantines"] >= 1 and st["slot_rollbacks"] >= 1, st
        assert hs[1].retries >= 1

        clean = SimulationService(cfg, ServiceConfig(num_slots=4),
                                  mesh=mesh, batch=batch)
        ch = [clean.submit(SimRequest(seed=s, chunks=chunks))
              for s in seeds]
        clean.run_until_idle()

        for i, h in enumerate(hs):
            assert h.result.status is RequestStatus.DONE, (i, h)
            sim = Simulator(dataclasses.replace(cfg, seed=h.request.seed),
                            mesh=mesh)
            sim.run(chunks)
            la = jax.tree.leaves(h.result.final_state)
            lb = jax.tree.leaves(jax.device_get(sim.state))
            assert len(la) == len(lb)
            for x, y in zip(la, lb):
                assert np.array_equal(np.asarray(jax.device_get(x)),
                                      np.asarray(y), equal_nan=True), i
            if i != 1:      # co-tenant observables untouched by the fault
                np.testing.assert_array_equal(
                    h.observations, ch[i].observations)
        print("ISOLATION-OK")
    """)
    assert "ISOLATION-OK" in out
