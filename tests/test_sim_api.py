"""The repro.sim facade: eager config validation through the phase
registry, Simulator driving (fused multi-chunk scan == sequential chunk
dispatch, bitwise), scenario-aware lowering, explicit state sharding
specs, and checkpoint round-trips."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.msp_brain import BrainConfig
from repro.core import engine
from repro.scenarios import library, observables
from repro.sim import Simulator, registry

SMALL = BrainConfig(neurons_per_rank=32, local_levels=3, frontier_cap=32,
                    max_synapses=8, rate_period=10, requests_cap_factor=100,
                    subs_cap_factor=100)


def _assert_states_equal(a, b, msg=""):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


# ---------------------------------------------------------------- registry
@pytest.mark.parametrize("field,allowed_one", [
    ("activity_impl", "reference"),
    ("connectivity_impl", "reference"),
    ("connectivity_alg", "new"),
    ("spike_alg", "new"),
    ("rate_exchange", "dense"),
])
def test_bad_variant_name_raises_at_construction(field, allowed_one):
    """Every variant field validates eagerly, naming the field and the
    allowed set — never mid-trace."""
    with pytest.raises(ValueError) as ei:
        BrainConfig(**{field: "definitely-bogus"})
    assert field in str(ei.value)
    assert allowed_one in str(ei.value)


def test_illegal_combination_raises_at_construction():
    with pytest.raises(ValueError, match="spike_alg"):
        BrainConfig(activity_impl="fused", spike_alg="old")
    # replace() re-runs __post_init__, so mutation can't sneak one in
    with pytest.raises(ValueError, match="spike_alg"):
        dataclasses.replace(SMALL, activity_impl="fused", spike_alg="old")


def test_registry_resolve_unknown_name_lists_allowed():
    with pytest.raises(ValueError, match="'reference', 'fused'"):
        registry.resolve("activity", "bogus")


def test_registry_all_declared_names_registered():
    """Every declared (domain, name) pair resolves to a callable — or, for
    domains whose implementation is a bundle (e.g. ``apply`` ->
    ``ApplyImpl``), to a NamedTuple whose fields are all callable."""
    registry.ensure_loaded()
    for domain in registry.CONFIG_FIELDS:
        for name in registry.allowed(domain):
            impl = registry.resolve(domain, name)
            parts = tuple(impl) if isinstance(impl, tuple) else (impl,)
            assert parts and all(callable(p) for p in parts), (domain, name)


def test_register_phase_refuses_undeclared_name():
    with pytest.raises(ValueError, match="not declared"):
        registry.register_phase("activity", "undeclared-impl")


# ---------------------------------------------------------------- sharding
def test_state_specs_explicit_per_field():
    for rex in ("dense", "sparse"):
        cfg = dataclasses.replace(SMALL, rate_exchange=rex)
        shapes = jax.eval_shape(lambda c=cfg: engine.init_state(c, 0, 1))
        specs = engine.state_specs(shapes)
        assert specs.out_edges == P("ranks", None)
        assert specs.neurons.v == P("ranks")
        assert specs.chunk == P()
        if rex == "dense":
            assert specs.rates_table == P()     # replicated gather result
            assert specs.subs is None
        else:
            assert specs.rates_table is None
            assert specs.subs == P("ranks")
            assert specs.rate_slots == P("ranks", None)
        # the spec tree must exactly match the state tree
        jax.tree.map(lambda s, l: None, specs, shapes)


# ---------------------------------------------------------------- driving
def test_run_scan_equals_sequential_chunks():
    """run(k) — ONE jitted lax.scan — is bit-identical to k sequential
    build_sim chunk dispatches."""
    st_scan = Simulator.from_config(SMALL).run(3)
    init_fn, chunk = engine.build_sim(SMALL, engine.make_brain_mesh())
    st = init_fn()
    for _ in range(3):
        st = chunk(st)
    _assert_states_equal(st_scan, st, "scan != sequential")


def test_step_then_run_continues_the_same_stream():
    """Mixing step() and run() follows the same chunk-keyed stream."""
    a = Simulator.from_config(SMALL)
    a.step()
    a.run(2)
    b_state = Simulator.from_config(SMALL).run(3)
    _assert_states_equal(a.state, b_state)


def test_stats_are_summed_plain_floats():
    sim = Simulator.from_config(SMALL)
    sim.run(2)
    s = sim.stats()
    # device counters + the host-side runner lifecycle counters
    from repro import telemetry
    assert set(s) == set(engine.STAT_KEYS) | set(telemetry.LIFECYCLE_KEYS)
    assert all(isinstance(v, float) for v in s.values())
    assert s["synapses_formed"] > 0
    assert s["rollbacks"] == 0.0


def test_run_with_recorder_matches_library_history():
    scn = library.get_scenario("baseline_growth")
    sim = Simulator.from_config(SMALL, scenario=scn)
    rec = observables.init_recorder(3, 1)
    _, rec = sim.run(3, recorder=rec)
    hist = observables.flush(rec)
    _, hist2 = library.run_scenario(scn, SMALL, num_chunks=3)
    for k in ("calcium", "rate", "synapses"):
        np.testing.assert_array_equal(hist[k], hist2[k], err_msg=k)


# ---------------------------------------------------------------- lowering
def test_lower_is_scenario_aware():
    """The dry-run path lowers the trace that will actually run: a
    stimulation protocol must change the lowered module (the old
    ``lower_sim_step`` dropped its scenario)."""
    scn = library.get_scenario("focal_stimulation")
    plain = Simulator.from_config(SMALL).lower().as_text()
    with_scn = Simulator.from_config(SMALL, scenario=scn).lower().as_text()
    assert plain != with_scn
    routed = engine.lower_sim_step(SMALL, engine.make_brain_mesh(),
                                   scenario=scn).as_text()
    assert routed == with_scn


# ---------------------------------------------------------------- persist
@pytest.mark.parametrize("rex", ["dense", "sparse"])
def test_checkpoint_roundtrip_bit_identical(tmp_path, rex):
    """save -> restore -> run(k) == uninterrupted run(n+k), bitwise, in
    both rate-exchange layouts (all randomness is keyed by counters
    carried in the state)."""
    cfg = dataclasses.replace(SMALL, rate_exchange=rex)
    a = Simulator.from_config(cfg)
    a.run(2)
    saved = a.save(str(tmp_path))
    assert saved == 2
    a.run(2)                                # uninterrupted: 4 chunks total
    b = Simulator.from_config(cfg)
    assert b.restore(str(tmp_path)) == 2
    b.run(2)                                # resumed: 2 + 2 chunks
    _assert_states_equal(a.state, b.state, f"round-trip diverged ({rex})")


def test_restore_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Simulator.from_config(SMALL).restore(str(tmp_path / "nope"))
