"""Sparse subscription-based rate exchange (DESIGN.md §7): registry/remap
construction against numpy oracles, dense-vs-sparse reconstruction parity,
engine-level plumbing, overflow accounting, and the lookup_spikes binary
search property-tested against a dense membership oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.msp_brain import BrainConfig
from repro.connectome import routing
from repro.core import engine, spikes
from repro.kernels.activity_fused import reconstruct_remote_spikes

INT_MAX = np.iinfo(np.int32).max


def _rand_edges(rng, n, s_max, num_ranks, p_empty=0.3):
    e = rng.integers(0, num_ranks * n, size=(n, s_max), dtype=np.int32)
    e[rng.random((n, s_max)) < p_empty] = -1
    return e


# ---------------------------------------------------------------- registry
def test_build_subscriptions_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    n, s_max, num_ranks, rank = 64, 8, 4, 1
    edges = _rand_edges(rng, n, s_max, num_ranks)
    want = np.unique(edges[(edges >= 0) & (edges // n != rank)])
    cap = routing.cap_subs(
        BrainConfig(neurons_per_rank=n, max_synapses=s_max,
                    subs_cap_factor=1000), num_ranks)
    assert cap >= want.size
    subs, slots, ovf = jax.jit(
        spikes.build_subscriptions, static_argnums=(1, 2, 3))(
        jnp.asarray(edges), rank, n, cap)
    subs, slots = np.asarray(subs), np.asarray(slots)
    assert float(ovf) == 0.0
    # sorted unique remote gids, NO_SUB-padded
    np.testing.assert_array_equal(subs[:want.size], want)
    assert (subs[want.size:] == INT_MAX).all()
    # remap: every remote edge points at its gid's slot, others at -1
    for i in range(n):
        for j in range(s_max):
            src = edges[i, j]
            if src >= 0 and src // n != rank:
                assert subs[slots[i, j]] == src
            else:
                assert slots[i, j] == -1


def test_build_subscriptions_all_local_or_empty():
    n = 16
    edges = jnp.asarray([[0, 5, -1, 15]] * n, jnp.int32)   # rank 0's own gids
    subs, slots, ovf = spikes.build_subscriptions(edges, 0, n, 8)
    assert (np.asarray(subs) == INT_MAX).all()
    assert (np.asarray(slots) == -1).all()
    assert float(ovf) == 0.0


def test_build_subscriptions_overflow_counted():
    """More unique remote sources than subs_cap: the smallest gids keep
    their slots, the rest are dropped (slot -1) and counted."""
    n, cap = 8, 4
    edges = jnp.asarray([np.arange(n, 2 * n, dtype=np.int32)], jnp.int32)
    edges = jnp.broadcast_to(edges, (n, n))                # 8 unique remotes
    subs, slots, ovf = spikes.build_subscriptions(edges, 0, n, cap)
    assert float(ovf) == float(n - cap)
    np.testing.assert_array_equal(np.asarray(subs),
                                  np.arange(n, n + cap, dtype=np.int32))
    slots = np.asarray(slots)
    assert (slots[:, :cap] == np.arange(cap)).all()
    assert (slots[:, cap:] == -1).all()


def test_cap_subs_ceiling():
    cfg = BrainConfig(neurons_per_rank=64, max_synapses=8,
                      subs_cap_factor=10 ** 6)
    # head-room factor saturates at min(n*s_max, (R-1)*n)
    assert routing.cap_subs(cfg, 4) == min(64 * 8, 3 * 64)
    assert routing.cap_subs(cfg, 2) == min(64 * 8, 64)
    small = dataclasses.replace(cfg, subs_cap_factor=1)
    assert 32 <= routing.cap_subs(small, 4) <= 3 * 64


# ---------------------------------------------------------------- parity
def test_reconstruct_sparse_equals_dense():
    """Given a registry consistent with the dense table, the compact-buffer
    reconstruction draws bit-identical remote spikes (same edge-keyed
    Bernoulli stream, same rates)."""
    rng = np.random.default_rng(3)
    n, s_max, num_ranks, rank = 48, 8, 4, 2
    edges = jnp.asarray(_rand_edges(rng, n, s_max, num_ranks))
    table = jnp.asarray(rng.random((num_ranks, n), dtype=np.float32) * 0.3)
    subs, slots, ovf = spikes.build_subscriptions(edges, rank, n, 256)
    assert float(ovf) == 0.0
    safe = jnp.where(subs == spikes.NO_SUB, 0, subs)
    remote_rates = jnp.where(subs == spikes.NO_SUB, 0.0,
                             table[safe // n, safe % n])
    for gstep in (0, 7, 123):
        dense = reconstruct_remote_spikes(0, jnp.int32(gstep), table, edges,
                                          rank, n)
        sparse = reconstruct_remote_spikes(0, jnp.int32(gstep), remote_rates,
                                           edges, rank, n, rate_slots=slots)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse))
    assert np.asarray(dense).sum() > 0, "no remote spikes drawn at all"


# ---------------------------------------------------------------- engine
def test_engine_sparse_equals_dense_single_rank():
    """Plumbing check on one rank (the cross-rank bit-identity sweep —
    3 library scenarios x both lowerings x 4 ranks — runs in
    tests/test_multidevice.py)."""
    base = BrainConfig(neurons_per_rank=48, local_levels=3, frontier_cap=32,
                       max_synapses=8, rate_period=25)
    mesh = engine.make_brain_mesh()
    res = {}
    for rex in ("dense", "sparse"):
        cfg = dataclasses.replace(base, rate_exchange=rex)
        init_fn, chunk = engine.build_sim(cfg, mesh)
        stt = init_fn()
        for _ in range(3):
            stt = chunk(stt)
        res[rex] = stt
    a, b = res["dense"], res["sparse"]
    for f in ("v", "u", "calcium", "rate", "spike_count"):
        np.testing.assert_array_equal(np.asarray(getattr(a.neurons, f)),
                                      np.asarray(getattr(b.neurons, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(a.in_edges),
                                  np.asarray(b.in_edges))
    # layout-dependent state: dense holds the table, sparse the registry
    assert a.subs is None and a.rates_table is not None
    assert b.rates_table is None and b.subs is not None
    # single rank has no remote sources: nothing subscribed, nothing pushed
    assert float(b.stats["rates_sent"].sum()) == 0.0
    assert (np.asarray(b.subs) == INT_MAX).all()


def test_unknown_rate_exchange_raises():
    # unknown variant names fail eagerly at config construction
    with pytest.raises(ValueError, match="rate_exchange"):
        BrainConfig(rate_exchange="banana")


def test_window_hbm_bytes_sparse_model():
    """The megakernel's analytic traffic model: sparse swaps the (R, n)
    rates operand for the (subs_cap,) buffer + (n, S) slot remap — a win
    once R*n outgrows subs_cap + n*s_max."""
    from repro.kernels.activity_fused import window_hbm_bytes
    n, s_max, r, cap = 1024, 32, 64, 512
    dense = window_hbm_bytes(n, s_max, r)
    sparse = window_hbm_bytes(n, s_max, r, subs_cap=cap)
    assert dense - sparse == r * n * 4 - (cap * 4 + n * s_max * 4)
    assert sparse < dense
    # small meshes go the other way: the slot table outweighs a tiny table
    assert window_hbm_bytes(n, s_max, 2, subs_cap=cap) > \
        window_hbm_bytes(n, s_max, 2)


# ---------------------------------------------------------------- lookup
def _lookup_case(rng, num_ranks, n, s_max):
    """Build (all_ids, in_edges, spiked) exactly like the old algorithm's
    send side: per-rank sorted spiked gids, INT_MAX pad."""
    spiked = rng.random((num_ranks, n)) < rng.random((num_ranks, 1))
    gids = np.arange(num_ranks * n, dtype=np.int32).reshape(num_ranks, n)
    all_ids = np.where(spiked, gids, INT_MAX).astype(np.int32)
    all_ids.sort(axis=1)
    edges = _rand_edges(rng, n, s_max, num_ranks)
    return all_ids, edges, spiked


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
       st.integers(2, 40), st.integers(1, 9))
def test_lookup_spikes_matches_membership_oracle(seed, num_ranks, n, s_max):
    """The vectorized binary search == dense membership: an in-edge hits iff
    its source gid is in the sender rank's spiked set. Covers all-padded
    rows (ranks that spiked nowhere) by construction."""
    rng = np.random.default_rng(seed)
    all_ids, edges, spiked = _lookup_case(rng, num_ranks, n, s_max)
    got = np.asarray(spikes.lookup_spikes(jnp.asarray(all_ids),
                                          jnp.asarray(edges), n))
    flat = spiked.reshape(-1)
    want = (edges >= 0) & flat[np.clip(edges, 0, num_ranks * n - 1)]
    np.testing.assert_array_equal(got, want)


def test_lookup_spikes_all_padded_rows():
    """No rank spiked: every row is pure INT_MAX pad, nothing may hit."""
    n, s_max, num_ranks = 16, 4, 3
    all_ids = np.full((num_ranks, n), INT_MAX, np.int32)
    edges = _rand_edges(np.random.default_rng(1), n, s_max, num_ranks,
                        p_empty=0.2)
    got = np.asarray(spikes.lookup_spikes(jnp.asarray(all_ids),
                                          jnp.asarray(edges), n))
    assert not got.any()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5), st.integers(2, 40))
def test_exchange_spiked_ids_sorted_duplicate_free(seed, num_ranks, n):
    """Send-side invariant the binary search relies on: each row is sorted
    ascending and duplicate-free apart from the INT_MAX pad tail."""
    rng = np.random.default_rng(seed)
    spiked = jnp.asarray(rng.random(n) < 0.4)
    ids, count = spikes.exchange_spiked_ids(spiked, 0, n, None, 1)
    row = np.asarray(ids[0])
    assert (np.diff(row) >= 0).all()
    live = row[row != INT_MAX]
    assert live.size == int(count[0]) == int(np.asarray(spiked).sum())
    assert np.unique(live).size == live.size
