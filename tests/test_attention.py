"""Chunked attention + decode attention vs the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.models.attention import (chunked_attention, combine_partial,
                                    decode_attention, finalize_partial)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 16])
def test_chunked_vs_ref(hq, hkv, window):
    k = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(k, 1), (2, hq, 64, 32))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (2, hkv, 64, 32))
    v = jax.random.normal(jax.random.fold_in(k, 3), (2, hkv, 64, 32))
    o = chunked_attention(q, kk, v, causal=True, window=window,
                          q_chunk=16, kv_chunk=16)
    o_ref = ref.attention_ref(q, kk, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([17, 33, 48, 96]), st.sampled_from([8, 16, 32]))
def test_chunked_odd_seq_lengths(s, chunk):
    """_fit chunking handles non-power-of-two sequence lengths."""
    k = jax.random.key(1)
    q = jax.random.normal(jax.random.fold_in(k, 1), (1, 2, s, 16))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (1, 2, s, 16))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, 2, s, 16))
    o = chunked_attention(q, kk, v, causal=True, q_chunk=chunk, kv_chunk=chunk)
    o_ref = ref.attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=3e-5, atol=3e-5)


def test_decode_attention_matches_full_row():
    """decode at position p == row p of full causal attention."""
    k = jax.random.key(2)
    b, hq, hkv, s, d = 2, 4, 2, 32, 16
    q_all = jax.random.normal(jax.random.fold_in(k, 1), (b, hq, s, d))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (b, hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(k, 3), (b, hkv, s, d))
    full = ref.attention_ref(q_all, kk, v, causal=True)
    p = 20
    o, m, l = decode_attention(q_all[:, :, p, :], kk, v,
                               jnp.arange(s), p + 1)
    o = finalize_partial(o, m, l)
    np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, :, p]),
                               rtol=2e-5, atol=2e-5)


def test_split_kv_combine_equals_single_shard():
    """Partial-softmax combine over KV splits == direct attention (the
    move-compute decode path's math)."""
    k = jax.random.key(3)
    b, hq, hkv, s, d = 1, 2, 2, 64, 16
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, hq, d))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (b, hkv, s, d))
    v = jax.random.normal(jax.random.fold_in(k, 3), (b, hkv, s, d))
    cache_len = 50
    o_ref_, m_, l_ = decode_attention(q, kk, v, jnp.arange(s), cache_len)
    o_ref_ = finalize_partial(o_ref_, m_, l_)
    # simulate 4 shards, combine manually with the same math
    parts = []
    for i in range(4):
        sl = slice(i * 16, (i + 1) * 16)
        o, m, l = decode_attention(q, kk[:, :, sl], v[:, :, sl],
                                   jnp.arange(s)[sl], cache_len)
        parts.append((o, m, l))
    m_g = jnp.max(jnp.stack([p[1] for p in parts]), 0)
    o_sum = sum(p[0] * jnp.exp(p[1] - m_g)[..., None] for p in parts)
    l_sum = sum(p[2] * jnp.exp(p[1] - m_g) for p in parts)
    o_comb = o_sum / jnp.maximum(l_sum, 1e-30)[..., None]
    np.testing.assert_allclose(np.asarray(o_comb), np.asarray(o_ref_),
                               rtol=2e-5, atol=2e-5)


def test_ring_buffer_decode_window():
    """Ring-buffer cache slot/position math for local attention decode."""
    from repro.configs.base import ModelConfig
    from repro.models.decode import _ring_positions
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=8,
                      num_heads=1, num_kv_heads=1, head_dim=8, d_ff=8,
                      vocab_size=16, attn_window=4)
    pos = jnp.asarray(6)  # positions 3,4,5,6 live in the ring
    kv_pos = _ring_positions(cfg, pos, 4)
    assert sorted(np.asarray(kv_pos).tolist()) == [3, 4, 5, 6]
