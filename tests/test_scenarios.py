"""Scenario subsystem: population-table invariants, region connectome vs a
NumPy reference, protocol compilation, lesion-mask correctness in the full
engine, and the paper's old==new bit-identity under a stimulation protocol."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.msp_brain import BrainConfig
from repro.core import engine
from repro.scenarios import (Lesion, Recover, Region, Scenario, Stimulate,
                             alive_mask, assign_regions, build_table,
                             default_populations, population,
                             region_connectome, region_mask, stim_drive)
from repro.scenarios import library, observables
from repro.scenarios import populations as pops


# ---------------------------------------------------------------- populations
def test_population_table_invariants():
    cfg = BrainConfig()
    n = 100
    specs = (population("a", 0.6, "RS"),
             population("b", 0.25, "CH", target_calcium=0.4),
             population("c", 0.15, "FS", is_excitatory=False,
                        synapse_weight=42.0, element_growth_rate=5e-3))
    t = build_table(cfg, specs, n)
    ids = np.asarray(t.pop_id)
    # contiguous blocks covering [0, n), sizes by cumulative floor
    assert ids.shape == (n,)
    assert (np.sort(ids) == ids).all()
    assert np.bincount(ids).tolist() == [60, 25, 15]
    # per-population values land on the right rows
    np.testing.assert_allclose(np.asarray(t.izh_c)[ids == 1], -50.0)   # CH
    np.testing.assert_allclose(np.asarray(t.izh_a)[ids == 2], 0.1)     # FS
    np.testing.assert_allclose(np.asarray(t.target_calcium)[ids == 1], 0.4)
    np.testing.assert_allclose(np.asarray(t.target_calcium)[ids == 0],
                               cfg.target_calcium)
    np.testing.assert_allclose(np.asarray(t.growth_rate)[ids == 2], 5e-3)
    # inhibitory population: negative signed weight
    np.testing.assert_allclose(np.asarray(t.synapse_weight)[ids == 2], -42.0)
    np.testing.assert_allclose(np.asarray(t.synapse_weight)[ids == 0],
                               cfg.synapse_weight)
    assert not np.asarray(t.is_excitatory)[ids == 2].any()
    assert np.asarray(t.is_excitatory)[ids < 2].all()


def test_population_default_matches_legacy_split():
    """The default table reproduces the seed's excitatory/inhibitory layout
    exactly: boundary at int(n * fraction_excitatory), signed cfg weight."""
    cfg = BrainConfig(fraction_excitatory=0.8)
    n = 53
    t = build_table(cfg, default_populations(cfg), n)
    legacy_exc = np.arange(n) < int(n * cfg.fraction_excitatory)
    np.testing.assert_array_equal(np.asarray(t.is_excitatory), legacy_exc)
    np.testing.assert_allclose(
        np.asarray(t.synapse_weight),
        np.where(legacy_exc, cfg.synapse_weight, -cfg.synapse_weight))


def test_population_fractions_must_sum_to_one():
    cfg = BrainConfig()
    with pytest.raises(ValueError):
        build_table(cfg, (population("a", 0.5),), 10)


# ---------------------------------------------------------------- regions
def test_region_assignment_first_match_and_rest():
    regions = (Region("x", (0.0, 0.0, 0.0), (0.5, 1.0, 1.0)),
               Region("y", (0.0, 0.0, 0.0), (1.0, 0.5, 1.0)))
    pos = jnp.asarray([[0.2, 0.2, 0.2],    # in both -> first match (0)
                       [0.7, 0.2, 0.2],    # only y -> 1
                       [0.7, 0.7, 0.7]])   # neither -> rest (2)
    np.testing.assert_array_equal(np.asarray(assign_regions(pos, regions)),
                                  [0, 1, 2])
    assert bool(region_mask(pos, regions[0])[0])


def test_region_connectome_matches_numpy_reference():
    rng = np.random.default_rng(0)
    n_glob, s = 40, 6
    regions = (Region("a", hi=(0.5, 1.0, 1.0)),
               Region("b", lo=(0.5, 0.0, 0.0)))
    pos = rng.random((n_glob, 3), np.float32)
    edges = rng.integers(-1, n_glob, (n_glob, s)).astype(np.int32)
    rid = np.asarray(assign_regions(jnp.asarray(pos), regions))
    nb = len(regions) + 1
    got = np.asarray(region_connectome(jnp.asarray(edges), jnp.asarray(rid),
                                       jnp.asarray(rid), nb))
    want = np.zeros((nb, nb))
    for i in range(n_glob):
        for t in edges[i]:
            if t >= 0:
                want[rid[i], rid[t]] += 1
    np.testing.assert_allclose(got, want)
    assert got.sum() == (edges >= 0).sum()


# ---------------------------------------------------------------- protocol
def test_protocol_drive_and_alive_windows():
    regions = (Region("z", hi=(0.5, 1.0, 1.0)),)
    pos = jnp.asarray([[0.2, 0.5, 0.5], [0.8, 0.5, 0.5]])
    ev = (Stimulate("z", amplitude=2.5, t0=10, t1=20),
          Lesion("z", t=30), Recover("z", t=50))
    for step, want in [(9, [0, 0]), (10, [2.5, 0]), (19, [2.5, 0]),
                       (20, [0, 0])]:
        np.testing.assert_allclose(
            np.asarray(stim_drive(ev, regions, pos, jnp.asarray(step))),
            want)
    for step, want in [(29, [1, 1]), (30, [0, 1]), (49, [0, 1]),
                       (50, [1, 1])]:
        np.testing.assert_array_equal(
            np.asarray(alive_mask(ev, regions, pos, jnp.asarray(step))),
            np.asarray(want, bool))
    # no lesion events -> None fast path
    assert alive_mask(ev[:1], regions, pos, jnp.asarray(0)) is None


def test_protocol_unknown_region_raises():
    with pytest.raises(KeyError):
        stim_drive((Stimulate("nope", 1.0, 0, 1),), (), jnp.zeros((1, 3)),
                   jnp.asarray(0))


# ---------------------------------------------------------------- engine
SMALL = dataclasses.replace(library.SMOKE_SCENARIO_CONFIG,
                            neurons_per_rank=48, max_synapses=8)


def test_default_scenario_is_bitwise_legacy():
    """build_sim(scenario=None) and an empty Scenario trace to the same
    numbers — the subsystem is a strict superset of the seed simulation."""
    mesh = engine.make_brain_mesh()
    results = []
    for scn in (None, Scenario(name="empty")):
        init_fn, chunk = engine.build_sim(SMALL, mesh, scenario=scn)
        st = init_fn()
        for _ in range(2):
            st = chunk(st)
        results.append(st)
    a, b = results
    np.testing.assert_array_equal(np.asarray(a.out_edges),
                                  np.asarray(b.out_edges))
    np.testing.assert_array_equal(np.asarray(a.neurons.calcium),
                                  np.asarray(b.neurons.calcium))
    np.testing.assert_array_equal(np.asarray(a.neurons.v),
                                  np.asarray(b.neurons.v))


def test_lesion_kills_activity_and_synapses():
    """After a lesion: dead neurons have zero rate, zero elements, no edges
    in either direction, and never spike again; survivors keep running."""
    cfg = SMALL
    region = Region("core", hi=(0.5, 1.0, 1.0))
    scn = Scenario(name="lesion-test", regions=(region,),
                   events=(Lesion("core", t=cfg.rate_period),))
    mesh = engine.make_brain_mesh()
    init_fn, chunk = engine.build_sim(cfg, mesh, scenario=scn)
    st = init_fn()
    for _ in range(4):   # lesion lands at the end of chunk 0
        st = chunk(st)
    dead = np.asarray(region_mask(st.positions, region))
    assert dead.any() and not dead.all()
    rate = np.asarray(st.neurons.rate)
    assert (rate[dead] == 0).all()
    assert rate[~dead].sum() > 0
    assert (np.asarray(st.neurons.ax_elements)[dead] == 0).all()
    assert (np.asarray(st.neurons.de_elements)[dead] == 0).all()
    # no edges from or to dead neurons anywhere in the tables
    out_e, in_e = np.asarray(st.out_edges), np.asarray(st.in_edges)
    assert (out_e[dead] < 0).all(), "dead neurons still own out-edges"
    assert (in_e[dead] < 0).all(), "dead neurons still own in-edges"
    dead_gids = set(np.flatnonzero(dead))
    live_out = out_e[~dead]
    live_in = in_e[~dead]
    assert not (np.isin(live_out[live_out >= 0], list(dead_gids))).any(), \
        "survivors still point at dead targets"
    assert not (np.isin(live_in[live_in >= 0], list(dead_gids))).any(), \
        "survivors still point at dead sources"
    # membrane frozen at reset potential -> no spikes counted post-lesion
    assert (np.asarray(st.neurons.spike_count)[dead] == 0).all()


def test_old_new_connectivity_identical_under_stimulation():
    """THE paper invariant survives protocols: both connectivity algorithms
    form bit-identical synapses while a region is being stimulated."""
    scn = Scenario(
        name="stim-eq",
        regions=(Region("focus", hi=(0.5, 0.5, 1.0)),),
        events=(Stimulate("focus", amplitude=4.0, t0=50, t1=250),))
    base = dataclasses.replace(SMALL, spike_alg="old")
    res = {}
    for alg in ("old", "new"):
        cfg = dataclasses.replace(base, connectivity_alg=alg)
        init_fn, chunk = engine.build_sim(cfg, engine.make_brain_mesh(),
                                          scenario=scn)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                    np.sort(np.asarray(st.in_edges), 1),
                    float(st.stats["synapses_formed"].sum()))
    assert res["old"][2] == res["new"][2] > 0
    np.testing.assert_array_equal(res["old"][0], res["new"][0])
    np.testing.assert_array_equal(res["old"][1], res["new"][1])


def test_stimulation_raises_focus_activity():
    """Stimulated region fires faster than the rest while the pulse is on."""
    region = Region("focus", hi=(0.5, 1.0, 1.0))
    scn = Scenario(name="stim", regions=(region,),
                   events=(Stimulate("focus", amplitude=6.0, t0=0, t1=400),))
    init_fn, chunk = engine.build_sim(SMALL, engine.make_brain_mesh(),
                                      scenario=scn)
    st = init_fn()
    for _ in range(2):
        st = chunk(st)
    inside = np.asarray(region_mask(st.positions, region))
    rate = np.asarray(st.neurons.rate)
    assert inside.any() and (~inside).any()
    assert rate[inside].mean() > rate[~inside].mean() + 1e-4


# ---------------------------------------------------------------- observables
def test_recorder_ring_and_flush():
    regions = (Region("a", hi=(0.5, 1.0, 1.0)),)
    rec = observables.init_recorder(cap=3, nb=2)
    n = 8
    pos = jnp.linspace(0.0, 0.99, n)[:, None] * jnp.ones((1, 3))
    edges = jnp.full((n, 2), -1, jnp.int32)
    edges = edges.at[0, 0].set(7)   # region a -> rest
    for i in range(5):
        rec = observables.record(rec, pos, jnp.full((n,), float(i)),
                                 jnp.zeros((n,)), edges, regions)
    out = observables.flush(rec)
    assert out["num_recorded"] == 5
    # ring keeps the LAST 3 chunks, oldest first
    np.testing.assert_allclose(out["calcium"][:, 0], [2.0, 3.0, 4.0])
    np.testing.assert_allclose(out["synapses"][:, 0], 1.0)   # src region a
    np.testing.assert_allclose(out["connectome"][-1, 0, 1], 1.0)


def test_library_scenarios_construct():
    for name in library.SCENARIOS:
        scn = library.get_scenario(name)
        assert scn.name == name
    with pytest.raises(KeyError):
        library.get_scenario("nope")
