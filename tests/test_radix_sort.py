"""The on-device sort/apply kernels (PR: whole-chunk device residency):
``radix_argsort`` vs ``jnp.argsort(stable=True)`` on adversarial inputs, a
Morton-code known-answer test, and the fused ``morton_sort`` /
``synapse_apply`` / ``route_build`` kernels vs the exact jnp reference
expressions they replace — all in interpret mode (CPU CI)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.msp_brain import BrainConfig
from repro.connectome import routing
from repro.connectome import synapses as syn
from repro.connectome import tree as ctree
from repro.core import morton
from repro.kernels import ops as kops
from repro.kernels.radix_sort import bucket_ranks, stable_ranks


def _assert_matches_argsort(keys):
    k = jnp.asarray(keys, jnp.int32)
    s, order = kops.radix_argsort(k, interpret=True)
    ref = jnp.argsort(k, stable=True)
    np.testing.assert_array_equal(np.asarray(order), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(k[ref]))


# ------------------------------------------------------------- radix sort
@pytest.mark.parametrize("name,keys", [
    ("all_equal", np.full(257, 123)),
    ("pre_sorted", np.arange(300)),
    ("reversed", np.arange(300)[::-1].copy()),
    ("single", np.array([7])),
    ("two_buckets", np.array([1, 0] * 100)),
    ("max_range", np.array([2**30 - 1, 0, 2**30 - 1, 5])),
])
def test_radix_argsort_adversarial(name, keys):
    """Stable-argsort bit-identity on the classic adversarial layouts."""
    _assert_matches_argsort(keys)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**30 - 1), min_size=1, max_size=600))
def test_radix_argsort_matches_argsort_random(keys):
    _assert_matches_argsort(np.array(keys))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=400),
       st.integers(10, 300))
def test_stable_ranks_match_argsort_and_positions_within(ids, nb):
    """The kernel-side rank primitives == their host-shaped counterparts:
    ``stable_ranks`` is the inverse of the stable argsort permutation,
    ``bucket_ranks`` is ``positions_within``."""
    k = jnp.asarray(ids, jnp.int32)
    order = jnp.argsort(k, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(k.shape[0]))
    np.testing.assert_array_equal(np.asarray(stable_ranks(k, nb)),
                                  np.asarray(inv))
    np.testing.assert_array_equal(np.asarray(bucket_ranks(k, nb)),
                                  np.asarray(ctree.positions_within(k, nb)))


# ------------------------------------------------------------ morton KAT
def test_morton_code_known_answers():
    """Known-answer interleave: cell (i, j, k) at level L encodes to
    sum_t i_t<<3t | j_t<<(3t+1) | k_t<<(3t+2)."""
    # level 1: (i, j, k) = (1, 0, 1) -> 1 + 0 + 4 = 5
    pos = jnp.array([[0.6, 0.4, 0.7]])
    np.testing.assert_array_equal(np.asarray(morton.morton_encode(pos, 1)),
                                  [5])
    # level 3: (i, j, k) = (3, 5, 6); bits i=011, j=101, k=110 ->
    # t0: 1+2+0=3; t1: 8+0+32=40; t2: 0+128+256=384; total 427
    pos = jnp.array([[(3 + 0.5) / 8, (5 + 0.5) / 8, (6 + 0.5) / 8]])
    np.testing.assert_array_equal(np.asarray(morton.morton_encode(pos, 3)),
                                  [427])
    # corners of the unit cube at any level
    np.testing.assert_array_equal(
        np.asarray(morton.morton_encode(jnp.zeros((1, 3)), 4)), [0])
    np.testing.assert_array_equal(
        np.asarray(morton.morton_encode(jnp.ones((1, 3)) * 0.999, 4)),
        [8**4 - 1])


def test_morton_sort_kernel_matches_reference_path():
    """(rel, slot) from the kernel == the reference morton_encode +
    positions_within pair, including out-of-block clipping."""
    rng = np.random.default_rng(3)
    pos = jnp.asarray(rng.random((257, 3)), jnp.float32)
    for num_ranks, rank in [(1, 0), (4, 2)]:
        b = morton.branch_level(num_ranks)
        c_per = morton.cells_per_rank(num_ranks)
        lloc = 3
        leaf_level, n_leaf = b + lloc, c_per * 8**lloc
        base = rank * c_per * 8**lloc
        rel_ref = jnp.clip(morton.morton_encode(pos, leaf_level) - base,
                           0, n_leaf - 1)
        slot_ref = ctree.positions_within(rel_ref, n_leaf)
        rel, slot = kops.morton_sort(pos, base, leaf_level=leaf_level,
                                     n_leaf=n_leaf, interpret=True)
        np.testing.assert_array_equal(np.asarray(rel), np.asarray(rel_ref))
        np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_ref))


def test_tree_impl_fused_builds_identical_tree():
    """build_local_tree_fused == build_local_tree leaf-for-leaf (counts,
    centroids, membership table, base cell)."""
    rng = np.random.default_rng(11)
    cfg = BrainConfig(neurons_per_rank=96, local_levels=3, frontier_cap=32,
                      max_synapses=8)
    pos = jnp.asarray(rng.random((96, 3)), jnp.float32)
    w = jnp.asarray(rng.random(96) * 2, jnp.float32)
    ref = ctree.build_local_tree(pos, w, 0, cfg, 1)
    fus = ctree.build_local_tree_fused(pos, w, 0, cfg, 1, interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(fus)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------- synapse apply
def _random_tables(rng, n=48, s_max=8, qm=16, qr=24):
    edges = syn.compact(jnp.asarray(
        rng.integers(-1, n * 2, (n, s_max)), jnp.int32))
    msg_lid = jnp.asarray(rng.integers(0, n, qm), jnp.int32)
    msg_gid = jnp.asarray(rng.integers(0, n * 2, qm), jnp.int32)
    msg_valid = jnp.asarray(rng.random(qm) < 0.7)
    req_lid = jnp.asarray(rng.integers(0, n, qr), jnp.int32)
    req_src = jnp.asarray(rng.integers(0, n * 2, qr), jnp.int32)
    req_valid = jnp.asarray(rng.random(qr) < 0.8)
    vac = jnp.asarray(rng.random(n) * 3, jnp.float32)
    return edges, msg_lid, msg_gid, msg_valid, req_lid, req_src, req_valid, \
        vac


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_synapse_apply_kernel_matches_reference_sequence(seed):
    """One kernel pass == remove_edges_by_messages -> compact ->
    accept_core, bit-for-bit, with both stages live at once."""
    rng = np.random.default_rng(seed)
    (edges, mlid, mgid, mval, rlid, rsrc, rval, vac) = _random_tables(rng)
    key = jax.random.key(seed % 1000)
    prio = syn.request_priority(key, rlid, rsrc, rval)

    ref = syn.remove_edges_by_messages(edges, mlid, mgid, mval)
    ref = syn.compact(ref)
    acc_ref, ref = syn.accept_core(rlid, rsrc, rval, vac, ref, prio)

    out, acc = kops.synapse_apply(edges, mlid, mgid, mval, rlid, rsrc, rval,
                                  prio, vac, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc_ref))


def test_apply_impl_fused_stage_identities():
    """The fused deletion/accept entry points (each disabling the other
    stage) == the reference ApplyImpl callables."""
    from repro.sim import registry
    rng = np.random.default_rng(5)
    (edges, mlid, mgid, mval, rlid, rsrc, rval, vac) = _random_tables(rng)
    key = jax.random.key(9)
    ref = registry.resolve("apply", "reference")
    fus = registry.resolve("apply", "fused")
    np.testing.assert_array_equal(
        np.asarray(ref.deletion(edges, mlid, mgid, mval)),
        np.asarray(fus.deletion(edges, mlid, mgid, mval, interpret=True)))
    a0, n0 = ref.accept(rlid, rsrc, rval, vac, edges, key)
    a1, n1 = fus.accept(rlid, rsrc, rval, vac, edges, key, interpret=True)
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_array_equal(np.asarray(n0), np.asarray(n1))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_route_build_kernel_matches_route_deletions(seed):
    """The fused routing-buffer build == the pre-collective half of
    route_deletions (buffer and dropped count)."""
    rng = np.random.default_rng(seed)
    n, s_max, num_ranks = 40, 8, 4
    cfg = dataclasses.replace(
        BrainConfig(neurons_per_rank=n, local_levels=2, frontier_cap=32,
                    max_synapses=s_max))
    edges = jnp.asarray(rng.integers(-1, n * num_ranks, (n, s_max)),
                        jnp.int32)
    kill = (edges >= 0) & jnp.asarray(rng.random((n, s_max)) < 0.5)
    gcol = jnp.arange(n, dtype=jnp.int32)[:, None]
    flat_other = jnp.where(kill, edges, -1).reshape(-1)
    flat_mine = jnp.broadcast_to(gcol, kill.shape).reshape(-1)
    cap = routing.cap_deletions(cfg, False)
    buf_ref, drop_ref = routing.route_build_core(
        flat_other, flat_mine, n, num_ranks, cap, ctree.positions_within)
    buf, drop = kops.route_build(flat_other, flat_mine, n=n,
                                 num_ranks=num_ranks, cap=cap,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(buf_ref))
    assert float(drop[0]) == float(drop_ref)
