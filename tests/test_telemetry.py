"""repro.telemetry (DESIGN.md §9): Metrics pytree mechanics, the
bit-identity contract across variant lowerings (single-rank here, 4-rank
mesh via subprocess), span nesting, report schema round-trip /
normalization of the pre-schema layouts, and the regression gate's rule
taxonomy on synthetic baselines."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.configs.msp_brain import BrainConfig
from repro.sim import Simulator
from repro.telemetry import metrics as tm

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SMALL = BrainConfig(neurons_per_rank=32, local_levels=3, frontier_cap=32,
                    max_synapses=8, rate_period=10, requests_cap_factor=100,
                    subs_cap_factor=100)

# counters whose value depends on the exchange *layout* (dense ships the
# whole table, sparse ships subscriptions + requests) — everything else
# is physics and must be bit-identical across every variant axis
EXCHANGE_LAYOUT_KEYS = ("rates_sent", "subscription_requests",
                        "subscription_overflow", "request_overflow")
PHYSICS_KEYS = tuple(k for k in tm.COUNTER_KEYS
                     if k not in EXCHANGE_LAYOUT_KEYS)


# ---------------------------------------------------------------- metrics
def test_init_metrics_shapes_and_specs():
    m = tm.init_metrics(history=16)
    assert set(m.counters) == set(tm.COUNTER_KEYS)
    for k in tm.COUNTER_KEYS:
        assert m.counters[k].shape == (1,)
        assert m.per_chunk[k].shape == (1, 16)
    for k, b in tm.HIST_BUCKETS.items():
        assert m.hists[k].shape == (1, b)
    specs = tm.metrics_specs(m)
    # the spec tree matches the metrics tree leaf-for-leaf
    jax.tree.map(lambda s, l: None, specs, m,
                 is_leaf=lambda x: x is None)


def test_metrics_dict_compat_reads():
    m = tm.init_metrics().count("synapses_formed", 3.0)
    assert "synapses_formed" in m
    assert float(m["synapses_formed"].sum()) == 3.0
    assert set(m.keys()) == set(tm.COUNTER_KEYS)
    assert dict(m.items())["synapses_formed"] is m.counters["synapses_formed"]


def test_count_is_immutable_and_accumulates():
    m0 = tm.init_metrics()
    m1 = m0.count("bh_requests", jnp.float32(2.0)).count("bh_requests", 5)
    assert float(m0["bh_requests"][0]) == 0.0
    assert float(m1["bh_requests"][0]) == 7.0


def test_observe_scatter_adds_with_weights():
    m = tm.init_metrics()
    m = m.observe("frontier_depth", jnp.array([0, 0, 3, 7]))
    m = m.observe("frontier_depth", jnp.array([3]), jnp.array([0.0]))
    h = np.asarray(m.hists["frontier_depth"])[0]
    np.testing.assert_array_equal(h, [2, 0, 0, 1, 0, 0, 0, 1])


def test_record_chunk_ring_slots_and_deltas():
    m = tm.init_metrics(history=4)
    start = m.counters
    m = m.count("synapses_formed", 5.0)
    m = m.record_chunk(start, jnp.int32(0))
    start2 = m.counters
    m = m.count("synapses_formed", 2.0)
    m = m.record_chunk(start2, jnp.int32(5))    # slot 5 % 4 == 1
    ring = np.asarray(m.per_chunk["synapses_formed"])[0]
    np.testing.assert_array_equal(ring, [5.0, 2.0, 0.0, 0.0])


def test_metrics_pytree_roundtrip_with_stable_keys():
    m = tm.init_metrics(history=8).count("rates_sent", 1.0)
    leaves, treedef = jax.tree.flatten(m)
    m2 = jax.tree.unflatten(treedef, leaves)
    assert isinstance(m2, tm.Metrics)
    assert float(m2["rates_sent"][0]) == 1.0
    # key-path flatten exposes DictKey(.key) paths — the checkpoint
    # manager's stable leaf-naming contract
    kl, _ = jax.tree_util.tree_flatten_with_path(m)
    names = {"/".join(str(k.key) for k in path) for path, _ in kl}
    assert "counters/rates_sent" in names
    assert "hists/frontier_depth" in names


# ---------------------------------------------------------------- identity
def _counters(sim):
    return {k: np.asarray(v) for k, v in sim.metrics().counters.items()}


def _run(cfg):
    sim = Simulator(cfg)
    sim.run(2)
    return sim


def test_counters_bit_identical_reference_vs_fused_activity():
    a = _run(dataclasses.replace(SMALL, activity_impl="reference"))
    b = _run(dataclasses.replace(SMALL, activity_impl="fused"))
    ca, cb = _counters(a), _counters(b)
    for k in tm.COUNTER_KEYS:
        np.testing.assert_array_equal(ca[k], cb[k], err_msg=k)
    # the whole tree — per-chunk rings and histograms included
    for tree in ("per_chunk", "hists"):
        ta = getattr(a.metrics(), tree)
        tb = getattr(b.metrics(), tree)
        for k in ta:
            np.testing.assert_array_equal(np.asarray(ta[k]),
                                          np.asarray(tb[k]),
                                          err_msg=f"{tree}/{k}")
    assert ca["activity_spikes"].sum() > 0
    # each rank counts its own steps: 2 chunks x rate_period per rank,
    # regardless of how many host devices the suite runs under
    np.testing.assert_array_equal(
        ca["activity_steps"],
        np.full_like(ca["activity_steps"], 2 * SMALL.rate_period))


def test_counters_bit_identical_reference_vs_fused_connectivity():
    a = _run(dataclasses.replace(SMALL, connectivity_impl="reference"))
    b = _run(dataclasses.replace(SMALL, connectivity_impl="fused"))
    ca, cb = _counters(a), _counters(b)
    for k in tm.COUNTER_KEYS:
        np.testing.assert_array_equal(ca[k], cb[k], err_msg=k)
    ha = np.asarray(a.metrics().hists["frontier_depth"])
    hb = np.asarray(b.metrics().hists["frontier_depth"])
    np.testing.assert_array_equal(ha, hb)
    assert ca["bh_restarts"].sum() > 0, "traversal depth never recorded"


def test_physics_counters_identical_dense_vs_sparse():
    a = _run(dataclasses.replace(SMALL, rate_exchange="dense"))
    b = _run(dataclasses.replace(SMALL, rate_exchange="sparse"))
    ca, cb = _counters(a), _counters(b)
    for k in PHYSICS_KEYS:
        np.testing.assert_array_equal(ca[k], cb[k], err_msg=k)
    # layout-dependent histogram: only the sparse run populates occupancy
    assert float(np.asarray(a.metrics().hists["subs_occupancy"]).sum()) == 0


def test_per_chunk_rings_sum_to_counters():
    sim = _run(SMALL)
    m = sim.metrics()
    for k in tm.COUNTER_KEYS:
        total = float(np.asarray(m.counters[k]).sum())
        ring = float(np.asarray(m.per_chunk[k]).sum())
        np.testing.assert_allclose(ring, total, err_msg=k)


def test_counters_bit_identical_on_four_rank_mesh():
    """The full contract on a real mesh: physics counters identical
    across activity lowerings AND exchange layouts, per-rank resolution
    preserved (4 distinct per-rank entries, no premature sum)."""
    code = """
        import dataclasses
        import numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.sim import Simulator
        from repro.telemetry import metrics as tm
        EXCH = ("rates_sent", "subscription_requests",
                "subscription_overflow", "request_overflow")
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=10,
                           requests_cap_factor=1000, subs_cap_factor=1000)
        runs = {}
        for impl in ("reference", "fused"):
            for rex in ("dense", "sparse"):
                cfg = dataclasses.replace(base, activity_impl=impl,
                                          rate_exchange=rex)
                sim = Simulator(cfg)
                sim.run(2)
                runs[(impl, rex)] = sim
        ref = runs[("reference", "dense")]
        per_rank = ref.stats(reduce=False)
        assert per_rank["synapses_formed"].shape == (4,), \\
            per_rank["synapses_formed"].shape
        assert float(per_rank["synapses_formed"].sum()) > 0
        base_c = {k: np.asarray(v) for k, v in ref.metrics().counters.items()}
        for key, sim in runs.items():
            c = {k: np.asarray(v) for k, v in sim.metrics().counters.items()}
            for name in tm.COUNTER_KEYS:
                if name in EXCH and key[1] != "dense":
                    continue
                assert np.array_equal(base_c[name], c[name]), (key, name)
        # sparse ships strictly fewer rate records than the dense table
        dense_sent = float(base_c["rates_sent"].sum())
        sparse_sent = float(np.asarray(
            runs[("fused", "sparse")].metrics()["rates_sent"]).sum())
        assert 0 < sparse_sent < dense_sent, (dense_sent, sparse_sent)
        print("MESH-IDENTICAL", dense_sent / sparse_sent)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=560,
                          env=env)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MESH-IDENTICAL" in proc.stdout


# ---------------------------------------------------------------- spans
def test_span_nesting_depth_and_parent():
    telemetry.clear()
    with telemetry.span("outer", tag=1):
        with telemetry.span("inner"):
            pass
    inner, outer = telemetry.spans("inner")[-1], telemetry.spans("outer")[-1]
    assert inner.depth == 1 and inner.parent == "outer"
    assert outer.depth == 0 and outer.parent is None
    assert outer.attrs == {"tag": 1}
    assert outer.duration_ms >= inner.duration_ms >= 0.0
    # export() is JSON-shaped
    rec = [s for s in telemetry.export() if s["name"] == "inner"][-1]
    assert rec["parent"] == "outer" and rec["depth"] == 1


def test_simulator_records_spans():
    telemetry.clear()
    sim = Simulator(SMALL)
    sim.run(1)
    names = [s.name for s in telemetry.spans()]
    for expected in ("sim.construct", "sim.init", "sim.run"):
        assert expected in names, names
    run_span = telemetry.spans("sim.run")[-1]
    assert run_span.attrs.get("chunks") == 1


def test_profile_none_is_noop():
    with telemetry.profile(None):
        pass                                    # must not touch the profiler
    telemetry.clear()


# ---------------------------------------------------------------- report
def test_report_roundtrip_v1(tmp_path):
    m = tm.init_metrics().count("rates_sent", 4.0)
    rep = telemetry.report.make_report(
        "activity", {"n32": telemetry.report.case(
            {"n_per_rank": 32, "num_ranks": 1},
            {"fused_compile_ms": 10.0, "hbm_bytes_ratio": 25.0})},
        smoke=True, mesh={"num_ranks": 1, "backend": "cpu"},
        counters=telemetry.report.counters_block(m),
        histograms=telemetry.report.histograms_block(m),
        spans=telemetry.export())
    path = str(tmp_path / "r.json")
    telemetry.report.write(path, rep)
    back = telemetry.report.load(path)
    assert back == rep
    norm = telemetry.report.normalize(back)
    assert norm["bench"] == "activity" and norm["smoke"] is True
    assert norm["cases"]["n32"]["metrics"]["hbm_bytes_ratio"] == 25.0
    assert back["counters"]["total"]["rates_sent"] == 4.0
    assert back["counters"]["per_rank"]["rates_sent"] == [4.0]


def test_roofline_block_from_compiled_hlo():
    """The analytic third source: roofline_block parses a real compiled
    module into the schema's JSON shape."""
    hlo = jax.jit(lambda x: jnp.dot(x, x)).lower(
        jnp.ones((8, 8), jnp.float32)).compile().as_text()
    blk = telemetry.report.roofline_block(hlo, 1)
    assert blk["dot_flops"] >= 2 * 8 * 8 * 8
    assert blk["materialized_hbm_bytes"] > 0
    assert blk["terms"]["dominant"] in ("compute", "memory", "collective")
    import json
    json.dumps(blk)                             # JSON-serializable


def test_normalize_old_flat_single_case():
    old = {"n_per_rank": 256, "num_ranks": 1, "smoke": False,
           "fused_us_per_step": 100.0, "hbm_bytes_ratio": 25.4}
    norm = telemetry.report.normalize(old, bench="activity")
    assert list(norm["cases"]) == ["n256"]
    c = norm["cases"]["n256"]
    assert c["params"]["n_per_rank"] == 256
    assert c["metrics"]["hbm_bytes_ratio"] == 25.4
    assert "n_per_rank" not in c["metrics"]


def test_normalize_old_multi_case_layout():
    old = {"smoke": False,
           "n256": {"n_per_rank": 256, "hbm_bytes_ratio": 49.6},
           "n1024": {"n_per_rank": 1024, "hbm_bytes_ratio": 49.9}}
    norm = telemetry.report.normalize(old, bench="connectivity")
    assert set(norm["cases"]) == {"n256", "n1024"}
    assert norm["cases"]["n1024"]["metrics"]["hbm_bytes_ratio"] == 49.9


def test_committed_baselines_normalize():
    """Every committed BENCH_*.json stays readable by the gate."""
    root = os.path.join(os.path.dirname(__file__), "..")
    found = 0
    for fam, fname in (("activity", "BENCH_activity.json"),
                       ("connectivity", "BENCH_connectivity.json"),
                       ("spikes", "BENCH_spikes.json"),
                       ("fig11", "BENCH_fig11.json")):
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            continue
        found += 1
        norm = telemetry.report.normalize(telemetry.report.load(path),
                                          bench=fam)
        assert norm["cases"], fname
        for case in norm["cases"].values():
            assert "params" in case and "metrics" in case
            assert case["metrics"], fname
    assert found >= 2, "no committed baselines found at the repo root"


# ---------------------------------------------------------------- gate
def _gate():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks import check_regression
    finally:
        sys.path.pop(0)
    return check_regression


def _report(cases):
    return {"bench": "x", "smoke": False, "cases": cases}


def test_gate_identical_reports_pass():
    cr = _gate()
    cases = {"n64": {"params": {"n_per_rank": 64, "num_ranks": 1},
                     "metrics": {"hbm_bytes_ratio": 20.0,
                                 "fused_compile_ms": 100.0}}}
    findings = cr.compare("x", _report(cases), _report(cases))
    assert findings and all(f.ok for f in findings)


def test_gate_fails_on_perturbed_ratio():
    """The demonstrable-failure acceptance check: halving a paper-claim
    ratio beyond the 50% slack is flagged as a regression."""
    cr = _gate()
    base = {"n256": {"params": {"n_per_rank": 256, "num_ranks": 1},
                     "metrics": {"hbm_bytes_ratio": 25.0}}}
    cand = {"n64": {"params": {"n_per_rank": 64, "num_ranks": 1},
                    "metrics": {"hbm_bytes_ratio": 10.0}}}
    findings = cr.compare("activity", _report(base), _report(cand))
    bad = [f for f in findings if not f.ok]
    assert len(bad) == 1 and bad[0].metric == "hbm_bytes_ratio"
    # within slack: 25.0 -> 13.0 is fine (tol 0.5)
    cand["n64"]["metrics"]["hbm_bytes_ratio"] = 13.0
    findings = cr.compare("activity", _report(base), _report(cand))
    assert all(f.ok for f in findings)


def test_gate_time_rules_need_matching_params():
    cr = _gate()
    base = {"n256": {"params": {"n_per_rank": 256, "num_ranks": 1},
                     "metrics": {"fused_compile_ms": 100.0,
                                 "fused_steady_us_per_step": 50.0}}}
    # smoke at a smaller size: time metrics must NOT be compared
    small = {"n64": {"params": {"n_per_rank": 64, "num_ranks": 1},
                     "metrics": {"fused_compile_ms": 900.0,
                                 "fused_steady_us_per_step": 900.0}}}
    assert cr.compare("activity", _report(base), _report(small)) == []
    # same shape params: a 4x compile blowup exceeds the 2.0 slack
    matched = {"n256": {"params": {"n_per_rank": 256, "num_ranks": 1},
                        "metrics": {"fused_compile_ms": 400.0,
                                    "fused_steady_us_per_step": 60.0}}}
    findings = cr.compare("activity", _report(base), _report(matched))
    verdict = {f.metric: f.ok for f in findings}
    assert verdict == {"fused_compile_ms": False,
                       "fused_steady_us_per_step": True}


def test_gate_byte_counters_are_tight():
    cr = _gate()
    base = {"r4": {"params": {"n_per_rank": 64, "num_ranks": 4},
                   "metrics": {"sparse_rate_bytes_per_delta": 1000.0}}}
    cand = {"r4": {"params": {"n_per_rank": 64, "num_ranks": 4},
                   "metrics": {"sparse_rate_bytes_per_delta": 1500.0}}}
    findings = cr.compare("spikes", _report(base), _report(cand))
    assert [f.ok for f in findings] == [False]


def test_gate_pairs_with_smallest_n_baseline():
    cr = _gate()
    base = {"n1024": {"params": {"n_per_rank": 1024}, "metrics": {}},
            "n256": {"params": {"n_per_rank": 256},
                     "metrics": {"hbm_bytes_ratio": 49.6}}}
    cand = {"n64": {"params": {"n_per_rank": 64},
                    "metrics": {"hbm_bytes_ratio": 48.0}}}
    findings = cr.compare("connectivity", _report(base), _report(cand))
    assert len(findings) == 1
    assert findings[0].case == "n256->n64" and findings[0].ok


def test_gate_unknown_metrics_are_informational():
    cr = _gate()
    cases_b = {"n64": {"params": {"n_per_rank": 64},
                       "metrics": {"subs_per_rank_mean": 10.0}}}
    cases_c = {"n64": {"params": {"n_per_rank": 64},
                       "metrics": {"subs_per_rank_mean": 99.0}}}
    assert cr.compare("spikes", _report(cases_b), _report(cases_c)) == []
