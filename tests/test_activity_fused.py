"""Fused activity megakernel: counter-hash PRNG properties, kernel-vs-oracle
bit-identity (interpret mode), engine reference==fused bit-identity, the
old==new connectivity invariant under the fused path for the library
scenarios, and the HBM-byte reduction claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.msp_brain import BrainConfig
from repro.core import engine
from repro.kernels import hash as chash
from repro.kernels import ref
from repro.kernels.activity_fused import (activity_window, window_hbm_bytes)
from repro.scenarios import Lesion, Recover, Scenario, Stimulate, library
from repro.scenarios.populations import build_table, population


# ---------------------------------------------------------------- hash
def test_hash_deterministic_and_distinct():
    e = jnp.arange(4096, dtype=jnp.int32)
    a = chash.uniform(7, chash.NOISE_DOMAIN, 3, e)
    b = chash.uniform(7, chash.NOISE_DOMAIN, 3, e)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different step / entity / domain / seed all decorrelate
    for other in (chash.uniform(7, chash.NOISE_DOMAIN, 4, e),
                  chash.uniform(7, chash.SPIKE_DOMAIN, 3, e),
                  chash.uniform(8, chash.NOISE_DOMAIN, 3, e)):
        assert float((np.asarray(a) == np.asarray(other)).mean()) < 0.01


def test_hash_statistics():
    e = jnp.arange(1 << 16, dtype=jnp.int32)
    u = np.asarray(chash.uniform(0, chash.SPIKE_DOMAIN, 11, e))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 5e-3
    z = np.asarray(chash.normal(0, chash.NOISE_DOMAIN, 11, e))
    assert abs(z.mean()) < 2e-2 and abs(z.std() - 1.0) < 2e-2
    assert np.isfinite(z).all()


def test_hash_matches_known_threefry_vectors():
    """Threefry-2x32, 20 rounds: reference vectors from the Random123
    distribution (key = counter = 0, and the all-ones pattern)."""
    x0, x1 = chash.threefry2x32(0, 0, 0, 0)
    assert (int(x0), int(x1)) == (0x6B200159, 0x99BA4EFE)
    ones = 0xFFFFFFFF
    x0, x1 = chash.threefry2x32(ones, ones, ones, ones)
    assert (int(x0), int(x1)) == (0x1CB996FC, 0xBB002BE7)


# ---------------------------------------------------------------- kernel
def _rand_inputs(n, s_max, num_ranks, key=0):
    k = jax.random.key(key)
    fi = lambda i: jax.random.fold_in(k, i)   # noqa: E731
    state = (jax.random.normal(fi(1), (n,)) * 5 - 60,
             jax.random.normal(fi(2), (n,)) * 2 - 13,
             jax.random.uniform(fi(3), (n,)),
             jax.random.uniform(fi(4), (n,)) * 2,
             jax.random.uniform(fi(5), (n,)) * 2,
             jax.random.bernoulli(fi(6), 0.15, (n,)),
             jnp.zeros((n,)))
    edges = jax.random.randint(fi(7), (n, s_max), -1,
                               num_ranks * n).astype(jnp.int32)
    w = jnp.where(jnp.arange(n) < int(0.8 * n), 15.0, -15.0)
    rates = jax.random.uniform(fi(8), (num_ranks, n)) * 0.2
    return state, edges, w.astype(jnp.float32), rates


def _izh(cfg, n, hetero):
    if not hetero:
        return tuple(jnp.full((n,), x, jnp.float32) for x in
                     (cfg.izh_a, cfg.izh_b, cfg.izh_c, cfg.izh_d,
                      cfg.element_growth_rate, cfg.target_calcium))
    t = build_table(cfg, (population("rs", 0.5, "RS"),
                          population("ch", 0.25, "CH", target_calcium=0.4),
                          population("fs", 0.25, "FS",
                                     is_excitatory=False)), n)
    return (t.izh_a, t.izh_b, t.izh_c, t.izh_d, t.growth_rate,
            t.target_calcium)


@pytest.mark.parametrize("hetero", [False, True])
@pytest.mark.parametrize("protocol", ["none", "stim", "stim+lesion"])
def test_fused_bit_identical_to_oracle(hetero, protocol):
    """The pallas megakernel (interpret) == the jnp scan oracle, bit for
    bit, across populations and protocol tables."""
    cfg = BrainConfig()
    n, s_max, R, T = 96, 8, 2, 40
    state, edges, w, rates = _rand_inputs(n, s_max, R)
    stim = lesions = None
    if "stim" in protocol:
        stim = (jnp.stack([(jnp.arange(n) < n // 2).astype(jnp.float32)]),
                ((4.0, 5, 30),))
    if "lesion" in protocol:
        lesions = (jnp.stack([jnp.arange(n) >= 3 * n // 4]), ((12, 25),))
    kw = dict(seed=cfg.seed, num_steps=T, izh=_izh(cfg, n, hetero),
              ca_consts=(cfg.calcium_decay, cfg.calcium_beta),
              stim=stim, lesions=lesions)
    chunk, rank = jnp.int32(2), jnp.int32(1)
    got, got_spk = jax.jit(lambda st: activity_window(
        st, edges, w, rates, 5.0, 1.0, chunk, rank, interpret=True,
        **kw))(state)
    want, want_spk = jax.jit(lambda st: ref.activity_window_ref(
        st, edges, w, rates, 5.0, 1.0, chunk, rank, **kw))(state)
    for name, a, b in zip(("v", "u", "ca", "ax", "de", "spiked", "count"),
                          got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    # the telemetry per-step spike counts match too — same reduction
    np.testing.assert_array_equal(np.asarray(got_spk), np.asarray(want_spk),
                                  err_msg="spikes_per_step")
    assert got_spk.shape == (T,)
    assert float(got[6].sum()) > 0, "window produced no spikes at all"
    if lesions is not None:
        # lesion window [12, 25) closed before T=40: elements regrow after
        assert float(got[3][3 * n // 4:].min()) > 0.0


def test_fused_window_equals_per_step_calls():
    """Delta-resident state is exactly iterated one-step calls: running the
    kernel with num_steps=T equals T kernel launches of num_steps=1 with
    the counter advanced — the stage-1/stage-2 equivalence."""
    cfg = BrainConfig()
    n, s_max, R, T = 64, 8, 2, 12
    state, edges, w, rates = _rand_inputs(n, s_max, R, key=9)
    kw = dict(izh=_izh(cfg, n, False),
              ca_consts=(cfg.calcium_decay, cfg.calcium_beta))
    win, win_spk = jax.jit(lambda st: activity_window(
        st, edges, w, rates, 5.0, 1.0, jnp.int32(0), jnp.int32(0),
        seed=0, num_steps=T, interpret=True, **kw))(state)
    # per-step launches: chunk=0 is baked into gstep = 0*1 + t ... so use
    # chunk=t with num_steps=1 => gstep = t, matching the window's stream
    step1 = jax.jit(lambda st, t: activity_window(
        st, edges, w, rates, 5.0, 1.0, t, jnp.int32(0),
        seed=0, num_steps=1, interpret=True, **kw))
    st = state
    spk = []
    for t in range(T):
        st, spk_t = step1(st, jnp.int32(t))
        spk.append(np.asarray(spk_t)[0])
    for a, b in zip(win, st):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(win_spk), np.asarray(spk))


# ---------------------------------------------------------------- engine
SMALL = dataclasses.replace(library.SMOKE_SCENARIO_CONFIG,
                            neurons_per_rank=48, max_synapses=8,
                            rate_period=25)


def _scaled(scn: Scenario, div=20) -> Scenario:
    """Library scenario with event times divided so they land inside a
    short (rate_period=25, 3-chunk) test run."""
    evs = []
    for e in scn.events:
        if isinstance(e, Stimulate):
            evs.append(dataclasses.replace(e, t0=e.t0 // div,
                                           t1=max(e.t1 // div, e.t0 // div + 10)))
        elif isinstance(e, (Lesion, Recover)):
            evs.append(dataclasses.replace(e, t=e.t // div))
    return dataclasses.replace(scn, events=tuple(evs))


def test_engine_fused_equals_reference():
    """activity_impl='fused' is bit-identical to 'reference' through the
    full jitted sim (state AND the edge tables the state drives)."""
    mesh = engine.make_brain_mesh()
    res = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(SMALL, activity_impl=impl)
        init_fn, chunk = engine.build_sim(cfg, mesh)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[impl] = st
    a, b = res["reference"], res["fused"]
    for f in ("v", "u", "calcium", "ax_elements", "de_elements", "rate",
              "spike_count"):
        np.testing.assert_array_equal(np.asarray(getattr(a.neurons, f)),
                                      np.asarray(getattr(b.neurons, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(a.out_edges),
                                  np.asarray(b.out_edges))
    np.testing.assert_array_equal(np.asarray(a.in_edges),
                                  np.asarray(b.in_edges))


def test_fused_requires_new_spike_alg():
    # illegal combinations now fail eagerly, at config construction
    # (BrainConfig.__post_init__ -> sim.registry), never mid-trace
    with pytest.raises(ValueError, match="spike_alg"):
        dataclasses.replace(SMALL, activity_impl="fused", spike_alg="old")


@pytest.mark.parametrize("name", sorted(library.SCENARIOS))
def test_fused_old_new_connectivity_identical(name):
    """THE paper invariant under the megakernel: with activity_impl='fused'
    both connectivity algorithms still commit bit-identical edge tables,
    for every library scenario (populations, stimulation, lesion)."""
    scn = _scaled(library.get_scenario(name))
    mesh = engine.make_brain_mesh()
    res = {}
    for alg in ("old", "new"):
        cfg = dataclasses.replace(SMALL, activity_impl="fused",
                                  connectivity_alg=alg)
        init_fn, chunk = engine.build_sim(cfg, mesh, scenario=scn)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                    np.sort(np.asarray(st.in_edges), 1),
                    float(st.stats["synapses_formed"].sum()))
    assert res["old"][2] == res["new"][2] > 0
    np.testing.assert_array_equal(res["old"][0], res["new"][0])
    np.testing.assert_array_equal(res["old"][1], res["new"][1])


# ---------------------------------------------------------------- bytes
def test_fused_hbm_bytes_drop_3x():
    """Roofline-counted HBM bytes of one activity step: the fused window's
    streaming traffic must be >= 3x below the reference lowering's
    materialized buffers (acceptance criterion; bench_activity records the
    absolute numbers)."""
    from repro import compat
    from repro.launch import roofline
    cfg = dataclasses.replace(SMALL, rate_period=100)
    mesh = engine.make_brain_mesh()
    num_ranks = mesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: engine.init_state(cfg, 0, num_ranks))
    specs = engine.state_specs(shapes)

    def body(st):
        rank = jax.lax.axis_index("ranks")
        return engine.activity_phase(st, cfg, rank, "ranks", num_ranks)

    act = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(specs,),
                                   out_specs=specs, check_vma=False))
    init_fn, _ = engine.build_sim(cfg, mesh)
    hlo = act.lower(init_fn()).compile().as_text()
    ref_bytes = roofline.materialized_bytes(hlo) / cfg.rate_period
    fused_bytes = window_hbm_bytes(cfg.neurons_per_rank, cfg.max_synapses,
                                   num_ranks) / cfg.rate_period
    assert ref_bytes / fused_bytes >= 3.0, (ref_bytes, fused_bytes)
