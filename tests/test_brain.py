"""Brain-sim core: Morton/octree invariants (hypothesis property tests), BH
search sanity, single-rank MSP dynamics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.msp_brain import BrainConfig
from repro.core import barnes_hut as bh
from repro.core import connectivity as conn
from repro.core import engine, morton, octree


# ---------------------------------------------------------------- morton
@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.0, 0.999), min_size=3, max_size=3),
       st.integers(1, 8))
def test_morton_roundtrip_center(pos, level):
    p = jnp.asarray([pos])
    code = morton.morton_encode(p, level)
    center = morton.morton_cell_center(code, level)
    # the center must lie in the same cell
    assert int(morton.morton_encode(center, level)[0]) == int(code[0])
    # and within half a cell of the point per axis
    assert np.all(np.abs(np.asarray(center - p)) <= morton.cell_size(level))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 512))
def test_branch_level_consistency(r):
    b = morton.branch_level(r)
    assert 8 ** b >= r
    if r > 1:
        assert 8 ** (b - 1) < r or b == 1
    if r & (r - 1) == 0:  # powers of two: paper's 1/2/4 consecutive cells
        assert morton.cells_per_rank(r) in (1, 2, 4, 8)


# ---------------------------------------------------------------- octree
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 64))
def test_octree_aggregation_conserves_mass(seed, n):
    cfg = BrainConfig(neurons_per_rank=n, local_levels=3)
    key = jax.random.key(seed)
    pos = jax.random.uniform(key, (n, 3), minval=0.0, maxval=0.999)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) * 2
    tree = octree.build_local_tree(pos, w, 0, cfg, num_ranks=1)
    total = float(jnp.sum(w))
    for lvl, c in enumerate(tree.counts):
        np.testing.assert_allclose(float(jnp.sum(c)), total, rtol=1e-5,
                                   err_msg=f"level {lvl}")
    # centroid sums also conserved
    zsum = np.asarray(jnp.sum(pos * w[:, None], axis=0))
    for z in tree.centroids:
        np.testing.assert_allclose(np.asarray(jnp.sum(z, 0)), zsum, rtol=1e-4)


def test_octree_parent_equals_child_sum():
    cfg = BrainConfig(neurons_per_rank=128, local_levels=3)
    pos = jax.random.uniform(jax.random.key(0), (128, 3), maxval=0.999)
    w = jnp.ones((128,))
    tree = octree.build_local_tree(pos, w, 0, cfg, num_ranks=1)
    for k in range(len(tree.counts) - 1):
        parent = np.asarray(tree.counts[k])
        child = np.asarray(tree.counts[k + 1]).reshape(-1, 8).sum(1)
        np.testing.assert_allclose(parent, child, rtol=1e-6)


def test_leaf_members_point_to_correct_cells():
    cfg = BrainConfig(neurons_per_rank=64, local_levels=2)
    pos = jax.random.uniform(jax.random.key(1), (64, 3), maxval=0.999)
    tree = octree.build_local_tree(pos, jnp.ones(64), 0, cfg, num_ranks=1)
    members = np.asarray(tree.leaf_members)
    codes = np.asarray(morton.morton_encode(pos, cfg.local_levels))
    for cell in range(members.shape[0]):
        for m in members[cell]:
            if m >= 0:
                assert codes[m] == cell


# ---------------------------------------------------------------- BH search
def test_bh_search_prefers_nearby_mass():
    """With a heavy nearby cluster and a light far one, most samples land
    near the searcher."""
    cfg = BrainConfig(neurons_per_rank=64, local_levels=3, frontier_cap=64)
    near = jax.random.uniform(jax.random.key(2), (56, 3)) * 0.2 + 0.05
    far = jax.random.uniform(jax.random.key(3), (8, 3)) * 0.2 + 0.75
    pos = jnp.concatenate([near, far])
    tree = octree.build_local_tree(pos, jnp.ones(64), 0, cfg, num_ranks=1)
    stacked = bh.stack_levels(tree.counts, tree.centroids, 0)
    q = 64
    x = jnp.tile(jnp.array([[0.1, 0.1, 0.1]]), (q, 1))
    cell, valid, overflow, depth = bh.bh_search(
        stacked, x, jnp.arange(q, dtype=jnp.int32),
        jnp.zeros((q,), jnp.int32), seed=4, chunk=jnp.int32(0),
        theta=cfg.theta, sigma=cfg.sigma, frontier=cfg.frontier_cap,
        n_levels=cfg.local_levels + 1)
    assert bool(jnp.all(valid))
    # every settled query ran at least one expand/sample round
    assert bool(jnp.all(depth >= 1))
    centers = morton.morton_cell_center(cell, cfg.local_levels)
    d = jnp.linalg.norm(centers - x, axis=-1)
    assert float((d < 0.4).mean()) > 0.8, float((d < 0.4).mean())


def test_bh_theta_zero_like_behavior_is_exact_leafs():
    """Small theta forces descent to leaf cells (few approximations)."""
    cfg = BrainConfig(neurons_per_rank=32, local_levels=2, frontier_cap=64)
    pos = jax.random.uniform(jax.random.key(5), (32, 3), maxval=0.999)
    tree = octree.build_local_tree(pos, jnp.ones(32), 0, cfg, num_ranks=1)
    stacked = bh.stack_levels(tree.counts, tree.centroids, 0)
    cell, valid, _, _ = bh.bh_search(
        stacked, pos, jnp.arange(32, dtype=jnp.int32),
        jnp.zeros((32,), jnp.int32), seed=6, chunk=jnp.int32(0), theta=0.05,
        sigma=cfg.sigma, frontier=64, n_levels=cfg.local_levels + 1)
    # all returned nodes are leaf-level cells with actual neurons
    counts_leaf = np.asarray(tree.counts[-1])
    for c, v in zip(np.asarray(cell), np.asarray(valid)):
        if v:
            assert counts_leaf[c] > 0


# ---------------------------------------------------------------- dynamics
def test_single_rank_simulation_grows_towards_target():
    cfg = BrainConfig(neurons_per_rank=48, local_levels=3, frontier_cap=32,
                      max_synapses=24, fraction_excitatory=1.0)
    mesh = engine.make_brain_mesh()
    init_fn, chunk = engine.build_sim(cfg, mesh)
    st = init_fn()
    ca0 = float(st.neurons.calcium.mean())
    for _ in range(10):
        st = chunk(st)
    ca1 = float(st.neurons.calcium.mean())
    formed = float(st.stats["synapses_formed"].sum())
    assert ca1 > ca0 + 0.01, (ca0, ca1)
    assert formed > 0
    # in/out bookkeeping is globally consistent on one rank
    assert int((st.out_edges >= 0).sum()) == int((st.in_edges >= 0).sum())
    # no NaNs anywhere
    for leaf in jax.tree.leaves(st.neurons._asdict()):
        if leaf.dtype.kind == "f":
            assert bool(jnp.all(jnp.isfinite(leaf)))


def test_rate_window_refresh():
    from repro.core.neuron import init_neurons, refresh_rate
    cfg = BrainConfig()
    st = init_neurons(jax.random.key(0), cfg, 8)
    st = st._replace(spike_count=jnp.full((8,), 25.0))
    st = refresh_rate(st, cfg)
    np.testing.assert_allclose(np.asarray(st.rate), 0.25)
    assert float(st.spike_count.sum()) == 0.0


# ---------------------------------------------------------------- synapses
def test_accept_requests_respects_capacity():
    n, s_max = 4, 8
    in_edges = jnp.full((n, s_max), -1, jnp.int32)
    # 6 requests all to target 0, which has 2 vacant elements
    tgt = jnp.zeros((6,), jnp.int32)
    src = jnp.arange(100, 106, dtype=jnp.int32)
    valid = jnp.ones((6,), bool)
    vac = jnp.array([2.0, 0.0, 0.0, 0.0])
    acc, new_in = conn.accept_requests(tgt, src, valid, vac, in_edges,
                                       jax.random.key(0))
    assert int(acc.sum()) == 2
    assert int((new_in[0] >= 0).sum()) == 2
    assert int((new_in[1:] >= 0).sum()) == 0


def test_retract_and_remove_messages():
    edges = jnp.array([[5, 7, -1, -1], [3, -1, -1, -1]], jnp.int32)
    gids = jnp.array([0, 1], jnp.int32)
    new, kill = conn.retract_synapses(jax.random.key(1), edges,
                                      jnp.array([1, 0]), gids)
    assert int(kill.sum()) == 1
    assert int((new[0] >= 0).sum()) == 1
    # removal messages
    e2 = conn.remove_edges_by_messages(
        edges, jnp.array([0]), jnp.array([7]), jnp.array([True]))
    assert 7 not in np.asarray(e2[0])
    assert 5 in np.asarray(e2[0])


def test_compact():
    e = jnp.array([[-1, 3, -1, 9]], jnp.int32)
    c = conn.compact(e)
    np.testing.assert_array_equal(np.asarray(c[0]), [3, 9, -1, -1])
