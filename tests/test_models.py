"""Per-architecture smoke tests: reduced same-family config, one forward/train
step on CPU, output shapes + no NaNs; prefill/decode consistency vs the full
forward (the serving path must agree with the training path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import SHAPES, applicable_shapes
from repro.models import build_model, input_specs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = api.loss(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert 1.0 < float(loss) < 20.0
    grads = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat), arch
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
               for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_match_forward(arch):
    """logits from (prefill + decode_step) == logits from the full forward."""
    # ample MoE capacity: capacity buckets quantize with sequence length, so
    # exact-consistency tests must avoid routing drops
    cfg = get_smoke_config(arch).replace(dtype="float32", capacity_factor=8.0)
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    t = 12
    batch_full = make_batch(cfg, batch=2, seq=t + 1)
    tokens = batch_full["tokens"]

    if cfg.family == "audio":
        from repro.models import encdec
        full_logits, _ = encdec.forward(params, cfg, batch_full["frames"],
                                        tokens)
    else:
        from repro.models import transformer
        extra = batch_full.get("patch_embeds")
        full_logits, _ = transformer.forward(params, cfg, tokens,
                                             extra_embeds=extra)
    n_extra = 0 if cfg.family != "vlm" else cfg.num_patches

    batch_prompt = dict(batch_full)
    batch_prompt["tokens"] = tokens[:, :t]
    # vlm caches cover the patch positions too
    p_logits, state = api.prefill(params, batch_prompt,
                                  pad_cache_to=n_extra + t + 4)
    np.testing.assert_allclose(
        np.asarray(p_logits, np.float32),
        np.asarray(full_logits[:, n_extra + t - 1], np.float32),
        rtol=2e-3, atol=2e-3, err_msg=f"{arch}: prefill != forward")

    d_logits, state = api.decode_step(params, state, tokens[:, t])
    np.testing.assert_allclose(
        np.asarray(d_logits, np.float32),
        np.asarray(full_logits[:, n_extra + t], np.float32),
        rtol=2e-3, atol=2e-3, err_msg=f"{arch}: decode != forward")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_and_specs(arch):
    """Full configs: analytic param counts are plausible and input_specs are
    well-formed for every applicable shape (no allocation)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 50e6, (arch, n)
    assert cfg.active_param_count() <= n
    for shape in applicable_shapes(cfg):
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        for v in specs.values():
            assert all(d > 0 for d in v.shape)


def test_param_count_sanity_known_archs():
    assert 6.5e9 < get_config("qwen2-7b").param_count() < 8.5e9
    assert 13e9 < get_config("qwen3-14b").param_count() < 16e9
    assert 13e9 < get_config("starcoder2-15b").param_count() < 17e9
    arctic = get_config("arctic-480b")
    assert 4.3e11 < arctic.param_count() < 5.4e11
    assert arctic.active_param_count() < 3.5e10
    moon = get_config("moonshot-v1-16b-a3b")
    assert moon.active_param_count() < 0.35 * moon.param_count()
    assert 0.9e8 < get_config("xlstm-125m").param_count() < 3e8


def test_long_context_rules():
    from repro.configs.base import supports_long_context
    assert supports_long_context(get_config("xlstm-125m"))
    assert supports_long_context(get_config("recurrentgemma-2b"))
    for a in ("qwen2-7b", "arctic-480b", "llava-next-34b", "whisper-base"):
        assert not supports_long_context(get_config(a)), a
