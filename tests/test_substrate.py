"""Optimizer, checkpointing, data pipeline, fault-tolerant runner."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, TokenPipeline, _batch_for_step
from repro.optim.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, lr_at)


# ---------------------------------------------------------------- optimizer
def test_adamw_matches_numpy_reference():
    cfg = OptimizerConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.0, grad_clip=0.0, warmup_steps=0,
                          total_steps=10, min_lr_frac=1.0)
    p = {"w": jnp.ones((4, 4)) * 2.0}
    g = {"w": jnp.full((4, 4), 0.5)}
    st = init_opt_state(p, cfg)
    p1, st1, _ = adamw_update(p, g, st, cfg)
    # numpy reference (bias-corrected adam)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    u = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), 2.0 - 1e-2 * u, rtol=1e-5)
    assert int(st1["step"]) == 1


def test_grad_clip_and_warmup():
    cfg = OptimizerConfig(lr=1.0, grad_clip=1.0, warmup_steps=10,
                          total_steps=100)
    assert float(lr_at(cfg, jnp.asarray(0))) < 0.2
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=0.05)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.full((3,), 100.0)}
    st = init_opt_state(p, cfg)
    p1, _, stats = adamw_update(p, g, st, cfg)
    assert float(stats["grad_norm"]) > 100
    assert bool(jnp.all(jnp.isfinite(p1["w"])))


def test_bf16_opt_state_roundtrip():
    cfg = OptimizerConfig(state_dtype="bfloat16")
    p = {"w": jnp.ones((8,))}
    st = init_opt_state(p, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    _, st1, _ = adamw_update(p, {"w": jnp.ones((8,))}, st, cfg)
    assert st1["v"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    ckpt.save(str(tmp_path), 7, tree)
    out, manifest = ckpt.restore(str(tmp_path), 7, tree)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    assert out["a"].dtype == jnp.bfloat16


def test_checkpoint_keep_k_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.gc_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert not os.path.exists(tmp_path / "step_1")
    assert os.path.exists(tmp_path / "step_3")


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.arange(4.0)}
    c.save(3, tree)
    c.wait()
    step, out, _ = c.restore_latest(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(4.0))


# ---------------------------------------------------------------- data
def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=8, seed=5)
    full = _batch_for_step(cfg, 3, np.arange(8))
    sh0 = _batch_for_step(cfg, 3, np.arange(8)[0::2])
    sh1 = _batch_for_step(cfg, 3, np.arange(8)[1::2])
    np.testing.assert_array_equal(full[0::2], sh0)
    np.testing.assert_array_equal(full[1::2], sh1)
    assert full.min() >= 0 and full.max() < 97


def test_data_pipeline_resume():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4)
    p1 = TokenPipeline(cfg)
    b0 = next(p1)
    b1 = next(p1)
    state = p1.state()
    p1.close()
    p2 = TokenPipeline(cfg, start_step=state["step"])
    b2 = next(p2)
    p2.close()
    p3 = TokenPipeline(cfg)
    c0, c1, c2 = next(p3), next(p3), next(p3)
    p3.close()
    np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                  np.asarray(c2["tokens"]))


def test_data_is_learnable_structure():
    """Markov stream: next token is predictable => CE can go below ln(V)."""
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=4, noise_p=0.0)
    b = _batch_for_step(cfg, 0, np.arange(4))
    # deterministic transition given (row, t, prev)
    b2 = _batch_for_step(cfg, 0, np.arange(4))
    np.testing.assert_array_equal(b, b2)


# ---------------------------------------------------------------- runner
def test_runner_nan_rollback(tmp_path):
    from repro.runtime.fault_tolerance import RunnerConfig, TrainingRunner

    def step_fn(params, opt, batch):
        loss = jnp.sum(batch["x"]) * 0.0 + params["w"][0]
        params = {"w": params["w"] - 0.1}
        return params, opt, {"loss": loss + batch["x"][0]}

    class It:
        def __init__(self):
            self.i = 0

        def __next__(self):
            self.i += 1
            return {"x": jnp.ones((2,))}

    it = It()
    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_rollbacks=3),
        step_fn, {"w": jnp.ones((1,))}, {"dummy": jnp.zeros(())}, it)

    def poison(step, batch):
        if it.i == 6:  # poison one specific BATCH (consumed on rollback)
            return {"x": jnp.full((2,), jnp.nan)}
        return batch

    status = runner.run(8, poison_hook=poison)
    assert status == "done"
    assert runner.rollbacks == 1
    assert runner.step == 8


def test_runner_preemption(tmp_path):
    from repro.runtime.fault_tolerance import RunnerConfig, TrainingRunner

    def step_fn(params, opt, batch):
        return params, opt, {"loss": jnp.zeros(())}

    class It:
        def __next__(self):
            return {"x": jnp.ones((1,))}

    runner = TrainingRunner(RunnerConfig(ckpt_dir=str(tmp_path)),
                            step_fn, {"w": jnp.ones((1,))}, {}, It())
    runner.run(3)
    runner.preempt()
    assert runner.run(10) == "preempted"
    r2 = TrainingRunner(RunnerConfig(ckpt_dir=str(tmp_path)),
                        step_fn, {"w": jnp.zeros((1,))}, {}, It())
    assert r2.try_resume()
    assert r2.step == 3
