"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.msp_brain import BrainConfig
from repro.kernels import ops, ref


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 128, 64),    # MHA
    (2, 4, 2, 256, 64),    # GQA 2:1
    (1, 8, 1, 256, 128),   # MQA
    (1, 2, 1, 384, 32),    # seq not multiple of 256
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, hq, hkv, s, d, dtype):
    k = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(k, 1), (b, hq, s, d)).astype(dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 2), (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(k, 3), (b, hkv, s, d)).astype(dtype)
    o = ops.flash_attention(q, kk, v, causal=True, interpret=True)
    o_ref = ref.attention_ref(q, kk, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_local_window(window):
    k = jax.random.key(1)
    q = jax.random.normal(jax.random.fold_in(k, 1), (1, 2, 256, 64))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (1, 1, 256, 64))
    v = jax.random.normal(jax.random.fold_in(k, 3), (1, 1, 256, 64))
    o = ops.flash_attention(q, kk, v, causal=True, window=window,
                            interpret=True)
    o_ref = ref.attention_ref(q, kk, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_chunked_jax_attention():
    """The production pure-JAX chunked attention and the Pallas kernel agree."""
    from repro.models.attention import chunked_attention
    k = jax.random.key(2)
    q = jax.random.normal(jax.random.fold_in(k, 1), (2, 4, 256, 64))
    kk = jax.random.normal(jax.random.fold_in(k, 2), (2, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(k, 3), (2, 2, 256, 64))
    o1 = ops.flash_attention(q, kk, v, causal=True, interpret=True)
    o2 = chunked_attention(q, kk, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)


# (300, 257): above the 256 block and not a multiple — exercises the pad-up
# path (the old code shrank the block toward 1 for primes)
@pytest.mark.parametrize("n,m", [(64, 64), (128, 192), (100, 60), (300, 257)])
@pytest.mark.parametrize("sigma", [0.1, 0.25, 0.75])
def test_bh_gauss(n, m, sigma):
    k = jax.random.key(3)
    x = jax.random.uniform(jax.random.fold_in(k, 1), (n, 3))
    y = jax.random.uniform(jax.random.fold_in(k, 2), (m, 3))
    w = jax.random.uniform(jax.random.fold_in(k, 3), (m,)) * 3
    p, rs = ops.gauss_probs(x, y, w, sigma=sigma, interpret=True)
    pr, rr = ref.bh_gauss_ref(x, y, w, sigma=sigma)
    # |x|^2+|y|^2-2xy cancellation is amplified by exp(-d2/sigma^2) at small
    # sigma (documented caveat of the MXU-identity form)
    tol = 1e-5 if sigma >= 0.25 else 2e-3
    np.testing.assert_allclose(np.asarray(p), np.asarray(pr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(rs), np.asarray(rr),
                               rtol=max(tol, 1e-4), atol=max(tol, 1e-4))


@pytest.mark.parametrize("n,block", [(131, 64), (1031, 1024)])
def test_neuron_step_pads_non_divisible_n(n, block):
    """n not divisible by the block is padded up and sliced, instead of
    shrinking the block to a divisor (prime n used to degrade to block=1)."""
    from repro.kernels.neuron_step import neuron_step
    cfg = BrainConfig()
    k = jax.random.key(11)
    v = jax.random.normal(jax.random.fold_in(k, 1), (n,)) * 5 - 60
    u = jax.random.normal(jax.random.fold_in(k, 2), (n,)) * 2 - 13
    ca = jax.random.uniform(jax.random.fold_in(k, 3), (n,))
    ax = jax.random.uniform(jax.random.fold_in(k, 4), (n,)) * 2
    de = jax.random.uniform(jax.random.fold_in(k, 5), (n,)) * 2
    inp = jax.random.normal(jax.random.fold_in(k, 6), (n,)) * 5
    outs = neuron_step(v, u, ca, ax, de, inp, cfg, block=block,
                       interpret=True)
    refs = ref.neuron_step_ref(v, u, ca, ax, de, inp, cfg)
    for name, a, b in zip(["v", "u", "ca", "ax", "de"], outs, refs):
        assert a.shape == (n,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-3, err_msg=name)


@pytest.mark.parametrize("n", [64, 1000, 4096])
def test_neuron_step(n):
    cfg = BrainConfig()
    k = jax.random.key(4)
    v = jax.random.normal(jax.random.fold_in(k, 1), (n,)) * 5 - 60
    u = jax.random.normal(jax.random.fold_in(k, 2), (n,)) * 2 - 13
    ca = jax.random.uniform(jax.random.fold_in(k, 3), (n,))
    ax = jax.random.uniform(jax.random.fold_in(k, 4), (n,)) * 2
    de = jax.random.uniform(jax.random.fold_in(k, 5), (n,)) * 2
    inp = jax.random.normal(jax.random.fold_in(k, 6), (n,)) * 5
    outs = ops.fused_neuron_step(v, u, ca, ax, de, inp, cfg, interpret=True)
    refs = ref.neuron_step_ref(v, u, ca, ax, de, inp, cfg)
    # v/u can amplify 1-ulp differences near the spike threshold
    names = ["v", "u", "ca", "ax", "de", "spiked"]
    tols = {"v": 1e-3, "u": 1e-3, "ca": 1e-5, "ax": 1e-5, "de": 1e-5}
    for name, a, b in zip(names, outs, refs):
        if name == "spiked":
            assert (np.asarray(a) != np.asarray(b)).mean() < 0.01
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=tols[name], atol=tols[name],
                                       err_msg=name)


def test_neuron_step_heterogeneous_populations():
    """Per-neuron parameter arrays (mixed Izhikevich types) through the fused
    kernel match the oracle — and differ from the homogeneous run."""
    from repro.core.neuron import NeuronParams
    from repro.scenarios.populations import build_table, population
    cfg = BrainConfig()
    n = 256
    t = build_table(cfg, (population("rs", 0.5, "RS"),
                          population("ch", 0.25, "CH", target_calcium=0.4),
                          population("fs", 0.25, "FS",
                                     is_excitatory=False)), n)
    params = NeuronParams(t.izh_a, t.izh_b, t.izh_c, t.izh_d,
                          t.growth_rate, t.target_calcium)
    k = jax.random.key(7)
    v = jax.random.normal(jax.random.fold_in(k, 1), (n,)) * 5 - 60
    u = jax.random.normal(jax.random.fold_in(k, 2), (n,)) * 2 - 13
    ca = jax.random.uniform(jax.random.fold_in(k, 3), (n,))
    ax = jax.random.uniform(jax.random.fold_in(k, 4), (n,)) * 2
    de = jax.random.uniform(jax.random.fold_in(k, 5), (n,)) * 2
    inp = jax.random.normal(jax.random.fold_in(k, 6), (n,)) * 5
    outs = ops.fused_neuron_step(v, u, ca, ax, de, inp, cfg, params=params,
                                 interpret=True)
    refs = ref.neuron_step_ref(v, u, ca, ax, de, inp, cfg, params=params)
    homog = ref.neuron_step_ref(v, u, ca, ax, de, inp, cfg)
    for name, a, b in zip(["v", "u", "ca", "ax", "de", "spiked"], outs, refs):
        if name == "spiked":
            assert (np.asarray(a) != np.asarray(b)).mean() < 0.01
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-3, err_msg=name)
    # the FS block (a=0.1) really takes a different trajectory
    assert not np.allclose(np.asarray(outs[1])[192:], np.asarray(homog[1])[192:])


def test_kernel_engine_integration():
    """bh_gauss is the oracle for the brain sim's leaf-level probabilities."""
    from repro.core.barnes_hut import _gauss
    x = jnp.array([[0.1, 0.2, 0.3]])
    y = jnp.array([[0.15, 0.2, 0.3], [0.9, 0.9, 0.9]])
    w = jnp.array([2.0, 1.0])
    p, _ = ops.gauss_probs(x, y, w, sigma=0.25, interpret=True)
    d2 = jnp.sum((x[:, None] - y[None]) ** 2, -1)
    expected = w * _gauss(d2, 0.25)
    np.testing.assert_allclose(np.asarray(p), np.asarray(expected), rtol=1e-5)
