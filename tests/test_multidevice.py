"""Multi-device semantics, via subprocesses with 8 host devices (the XLA
device-count flag must be set before jax initializes, so these cannot run
in-process with the rest of the suite)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_py(code, devices=8, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_brain_old_new_connectivity_identical():
    """THE paper claim: the location-aware algorithm forms exactly the same
    synapses as the RMA-download baseline (we get bit-identical, the paper
    argues qualitative equivalence)."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        base = BrainConfig(neurons_per_rank=64, local_levels=3,
                           frontier_cap=32, max_synapses=16,
                           spike_alg='old', requests_cap_factor=1000)
        mesh = engine.make_brain_mesh()
        res = {}
        for alg in ['old', 'new']:
            cfg = dataclasses.replace(base, connectivity_alg=alg)
            init_fn, chunk = engine.build_sim(cfg, mesh)
            st = init_fn()
            for _ in range(3):
                st = chunk(st)
            res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                        np.sort(np.asarray(st.in_edges), 1),
                        float(st.stats['synapses_formed'].sum()),
                        float(st.stats['tree_nodes_downloaded'].sum()))
        assert np.array_equal(res['old'][0], res['new'][0]), 'out differ'
        assert np.array_equal(res['old'][1], res['new'][1]), 'in differ'
        assert res['old'][2] == res['new'][2] and res['old'][2] > 0
        assert res['old'][3] > 0 and res['new'][3] == 0  # comm asymmetry
        print('IDENTICAL', res['old'][2])
    """)
    assert "IDENTICAL" in out


def test_brain_edge_symmetry_across_ranks():
    """Every out-edge has the matching in-edge on the partner rank."""
    out = run_py("""
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        cfg = BrainConfig(neurons_per_rank=64, local_levels=3,
                          frontier_cap=32, max_synapses=16,
                          requests_cap_factor=1000)
        mesh = engine.make_brain_mesh()
        init_fn, chunk = engine.build_sim(cfg, mesh)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        out_e = np.asarray(st.out_edges); in_e = np.asarray(st.in_edges)
        n_total = out_e.shape[0]
        pairs_out = set()
        for src in range(n_total):
            for t in out_e[src]:
                if t >= 0: pairs_out.add((src, int(t)))
        pairs_in = set()
        for tgt in range(n_total):
            for s in in_e[tgt]:
                if s >= 0: pairs_in.add((int(s), tgt))
        assert pairs_out == pairs_in, (len(pairs_out), len(pairs_in),
                                       list(pairs_out ^ pairs_in)[:5])
        assert len(pairs_out) > 0
        print('SYMMETRIC', len(pairs_out))
    """)
    assert "SYMMETRIC" in out


def test_fused_activity_identical_across_ranks():
    """The fused megakernel == the reference scan bit-for-bit on a real
    multi-rank mesh (remote PRNG spikes, rates table, all-gathered
    connectivity all in play)."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=25,
                           requests_cap_factor=1000)
        res = {}
        for impl in ['reference', 'fused']:
            cfg = dataclasses.replace(base, activity_impl=impl)
            init_fn, chunk = engine.build_sim(cfg, engine.make_brain_mesh())
            st = init_fn()
            for _ in range(2):
                st = chunk(st)
            res[impl] = st
        a, b = res['reference'], res['fused']
        assert np.array_equal(np.asarray(a.neurons.v),
                              np.asarray(b.neurons.v)), 'v differs'
        assert np.array_equal(np.asarray(a.neurons.calcium),
                              np.asarray(b.neurons.calcium)), 'ca differs'
        assert np.array_equal(np.asarray(a.out_edges),
                              np.asarray(b.out_edges)), 'edges differ'
        assert np.array_equal(np.asarray(a.rates_table),
                              np.asarray(b.rates_table)), 'rates differ'
        print('FUSED==REF', float(a.neurons.calcium.mean()))
    """, devices=4)
    assert "FUSED==REF" in out


def test_sparse_rate_exchange_identical_across_ranks():
    """Sparse subscription-based rate exchange == dense (R, n) all-gather,
    bit for bit, on a 4-rank mesh for BOTH activity lowerings — the
    demand-driven push ships the exact same f32 rates the dense table
    holds, and the Bernoulli stream is keyed by the edge id, independent of
    the exchange layout (DESIGN.md §7). Also asserts the exchange-volume
    win the accounting reports."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=25,
                           requests_cap_factor=1000, subs_cap_factor=1000)
        for impl in ['reference', 'fused']:
            res = {}
            for rex in ['dense', 'sparse']:
                cfg = dataclasses.replace(base, rate_exchange=rex,
                                          activity_impl=impl)
                init_fn, chunk = engine.build_sim(cfg,
                                                  engine.make_brain_mesh())
                st = init_fn()
                for _ in range(3):
                    st = chunk(st)
                res[rex] = st
            a, b = res['dense'], res['sparse']
            for f in ('v', 'u', 'calcium', 'rate', 'spike_count'):
                assert np.array_equal(np.asarray(getattr(a.neurons, f)),
                                      np.asarray(getattr(b.neurons, f))), \\
                    (impl, f)
            assert np.array_equal(np.asarray(a.in_edges),
                                  np.asarray(b.in_edges)), impl
            assert np.array_equal(np.asarray(a.out_edges),
                                  np.asarray(b.out_edges)), impl
            dense_sent = float(a.stats['rates_sent'].sum())
            sparse_sent = float(b.stats['rates_sent'].sum())
            assert float(b.stats['subscription_overflow'].sum()) == 0.0
            assert 0 < sparse_sent < dense_sent, (dense_sent, sparse_sent)
        print('SPARSE==DENSE', dense_sent / sparse_sent)
    """, devices=4)
    assert "SPARSE==DENSE" in out


def test_sparse_rate_exchange_scenarios_identical():
    """The sparse == dense contract under all 3 library scenarios
    (populations, stimulation, lesion protocols) on a 4-rank mesh: the
    registry rebuild sees lesion-retracted edge tables and dead neurons
    advertising zero rates, and must still reproduce the dense state
    exactly."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        from repro.scenarios import Lesion, Recover, Stimulate, library
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=25,
                           requests_cap_factor=1000, subs_cap_factor=1000,
                           activity_impl='fused')
        def scaled(scn, div=20):
            evs = []
            for e in scn.events:
                if isinstance(e, Stimulate):
                    evs.append(dataclasses.replace(
                        e, t0=e.t0 // div,
                        t1=max(e.t1 // div, e.t0 // div + 10)))
                elif isinstance(e, (Lesion, Recover)):
                    evs.append(dataclasses.replace(e, t=e.t // div))
            return dataclasses.replace(scn, events=tuple(evs))
        for name in sorted(library.SCENARIOS):
            scn = scaled(library.get_scenario(name))
            res = {}
            for rex in ['dense', 'sparse']:
                cfg = dataclasses.replace(base, rate_exchange=rex)
                init_fn, chunk = engine.build_sim(
                    cfg, engine.make_brain_mesh(), scenario=scn)
                st = init_fn()
                for _ in range(3):
                    st = chunk(st)
                res[rex] = st
            a, b = res['dense'], res['sparse']
            for f in ('v', 'u', 'calcium', 'rate'):
                assert np.array_equal(np.asarray(getattr(a.neurons, f)),
                                      np.asarray(getattr(b.neurons, f))), \\
                    (name, f)
            assert np.array_equal(np.asarray(a.in_edges),
                                  np.asarray(b.in_edges)), name
            assert np.array_equal(np.asarray(a.out_edges),
                                  np.asarray(b.out_edges)), name
        print('SCENARIOS SPARSE==DENSE')
    """, devices=4)
    assert "SCENARIOS SPARSE==DENSE" in out


_RUN_SCAN_CODE = """
    import dataclasses
    import jax, numpy as np
    from repro.configs.msp_brain import BrainConfig
    from repro.core import engine
    from repro.scenarios import Lesion, Recover, Stimulate, library
    from repro.sim import Simulator
    base = BrainConfig(neurons_per_rank=32, local_levels=3,
                       frontier_cap=32, max_synapses=8, rate_period=10,
                       requests_cap_factor=1000, subs_cap_factor=1000,
                       rate_exchange={rex!r})
    def scaled(scn, div=50):
        evs = []
        for e in scn.events:
            if isinstance(e, Stimulate):
                evs.append(dataclasses.replace(
                    e, t0=e.t0 // div, t1=max(e.t1 // div, e.t0 // div + 5)))
            elif isinstance(e, (Lesion, Recover)):
                evs.append(dataclasses.replace(e, t=e.t // div))
        return dataclasses.replace(scn, events=tuple(evs))
    for name in sorted(library.SCENARIOS):
        scn = scaled(library.get_scenario(name))
        for impl in ['reference', 'fused']:
            cfg = dataclasses.replace(base, activity_impl=impl)
            st_scan = Simulator.from_config(cfg, scenario=scn).run(2)
            init_fn, chunk = engine.build_sim(cfg, engine.make_brain_mesh(),
                                              scenario=scn)
            st = init_fn()
            for _ in range(2):
                st = chunk(st)
            for a, b in zip(jax.tree.leaves(st_scan), jax.tree.leaves(st)):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                    (name, impl)
    print('RUN==SEQ')
"""


def test_simulator_run_scan_bit_identical_dense():
    """The facade's fused multi-chunk scan (Simulator.run(k)) == k
    sequential build_sim chunk dispatches, bit for bit, on a 4-rank mesh —
    every library scenario x both activity lowerings, dense exchange."""
    out = run_py(_RUN_SCAN_CODE.format(rex="dense"), devices=4)
    assert "RUN==SEQ" in out


def test_simulator_run_scan_bit_identical_sparse():
    """Same contract under the sparse subscription-based exchange."""
    out = run_py(_RUN_SCAN_CODE.format(rex="sparse"), devices=4)
    assert "RUN==SEQ" in out


def test_fused_connectivity_identical_across_ranks():
    """The Pallas traversal kernel == the reference phase-B bit-for-bit on a
    real multi-rank mesh (42B request routing, nonzero gid_base, gathered
    global tree on the old path all in play)."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=25,
                           requests_cap_factor=1000)
        res = {}
        for impl in ['reference', 'fused']:
            cfg = dataclasses.replace(base, connectivity_impl=impl)
            init_fn, chunk = engine.build_sim(cfg, engine.make_brain_mesh())
            st = init_fn()
            for _ in range(2):
                st = chunk(st)
            res[impl] = st
        a, b = res['reference'], res['fused']
        assert np.array_equal(np.asarray(a.out_edges),
                              np.asarray(b.out_edges)), 'out differs'
        assert np.array_equal(np.asarray(a.in_edges),
                              np.asarray(b.in_edges)), 'in differs'
        formed = float(a.stats['synapses_formed'].sum())
        assert formed > 0
        # old alg + fused impl: the gathered global tree path
        cfg = dataclasses.replace(base, connectivity_impl='fused',
                                  connectivity_alg='old')
        init_fn, chunk = engine.build_sim(cfg, engine.make_brain_mesh())
        st = init_fn()
        for _ in range(2):
            st = chunk(st)
        assert np.array_equal(np.sort(np.asarray(st.out_edges), 1),
                              np.sort(np.asarray(b.out_edges), 1)), 'old!=new'
        print('KERNEL==REF', formed)
    """, devices=4)
    assert "KERNEL==REF" in out


def test_fused_tree_apply_identical_across_ranks():
    """The radix-sort tree build + fused synapse-apply kernels == the jnp
    reference bit-for-bit on a real 4-rank mesh, under a lesion scenario so
    the deletion-routing buffer (route_build kernel) actually crosses the
    all-to-all with live messages."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        from repro.scenarios import Lesion, Recover, Stimulate, library
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=25,
                           requests_cap_factor=1000)
        def scaled(scn, div=20):
            evs = []
            for e in scn.events:
                if isinstance(e, Stimulate):
                    evs.append(dataclasses.replace(
                        e, t0=e.t0 // div,
                        t1=max(e.t1 // div, e.t0 // div + 10)))
                elif isinstance(e, (Lesion, Recover)):
                    evs.append(dataclasses.replace(e, t=e.t // div))
            return dataclasses.replace(scn, events=tuple(evs))
        scn = scaled(library.get_scenario('lesion_rewiring'))
        res = {}
        for impl in ['reference', 'fused']:
            cfg = dataclasses.replace(base, tree_impl=impl, apply_impl=impl)
            init_fn, chunk = engine.build_sim(cfg, engine.make_brain_mesh(),
                                              scenario=scn)
            st = init_fn()
            for _ in range(3):
                st = chunk(st)
            res[impl] = st
        a, b = res['reference'], res['fused']
        assert np.array_equal(np.asarray(a.out_edges),
                              np.asarray(b.out_edges)), 'out differs'
        assert np.array_equal(np.asarray(a.in_edges),
                              np.asarray(b.in_edges)), 'in differs'
        for f in ('v', 'calcium', 'rate'):
            assert np.array_equal(np.asarray(getattr(a.neurons, f)),
                                  np.asarray(getattr(b.neurons, f))), f
        formed = float(a.stats['synapses_formed'].sum())
        deleted = float(a.stats['synapses_deleted'].sum())
        assert formed > 0 and deleted > 0, (formed, deleted)
        print('TREEAPPLY==REF', formed, deleted)
    """, devices=4)
    assert "TREEAPPLY==REF" in out


def test_fused_tree_apply_old_new_scenarios_across_ranks():
    """The paper's old==new invariant survives the fused tree/apply kernels
    on a 4-rank mesh for every library scenario x dense/sparse rate
    exchange — the acceptance matrix of the whole-chunk-residency PR."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        from repro.scenarios import Lesion, Recover, Stimulate, library
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=25,
                           requests_cap_factor=1000, subs_cap_factor=1000,
                           tree_impl='fused', apply_impl='fused')
        def scaled(scn, div=20):
            evs = []
            for e in scn.events:
                if isinstance(e, Stimulate):
                    evs.append(dataclasses.replace(
                        e, t0=e.t0 // div,
                        t1=max(e.t1 // div, e.t0 // div + 10)))
                elif isinstance(e, (Lesion, Recover)):
                    evs.append(dataclasses.replace(e, t=e.t // div))
            return dataclasses.replace(scn, events=tuple(evs))
        for name in sorted(library.SCENARIOS):
            scn = scaled(library.get_scenario(name))
            for rex in ['dense', 'sparse']:
                res = {}
                for alg in ['old', 'new']:
                    cfg = dataclasses.replace(base, rate_exchange=rex,
                                              connectivity_alg=alg)
                    init_fn, chunk = engine.build_sim(
                        cfg, engine.make_brain_mesh(), scenario=scn)
                    st = init_fn()
                    for _ in range(2):
                        st = chunk(st)
                    res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                                np.sort(np.asarray(st.in_edges), 1),
                                float(st.stats['synapses_formed'].sum()))
                assert res['old'][2] == res['new'][2] > 0, (name, rex)
                assert np.array_equal(res['old'][0], res['new'][0]), \\
                    (name, rex, 'out')
                assert np.array_equal(res['old'][1], res['new'][1]), \\
                    (name, rex, 'in')
        print('OLD==NEW FUSED TREEAPPLY')
    """, devices=4)
    assert "OLD==NEW FUSED TREEAPPLY" in out


def test_spike_vs_rate_statistics():
    """New spike algorithm preserves mean activity (paper Fig 8/9)."""
    out = run_py("""
        import dataclasses
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.core import engine
        base = BrainConfig(neurons_per_rank=32, local_levels=3,
                           frontier_cap=32, max_synapses=24,
                           fraction_excitatory=1.0, requests_cap_factor=1000)
        cal = {}
        for alg in ['old', 'new']:
            cfg = dataclasses.replace(base, spike_alg=alg)
            mesh = engine.make_brain_mesh()
            init_fn, chunk = engine.build_sim(cfg, mesh)
            st = init_fn()
            for _ in range(30):
                st = chunk(st)
            cal[alg] = float(np.mean(np.asarray(st.neurons.calcium)))
        rel = abs(cal['old'] - cal['new']) / max(cal['old'], 1e-9)
        assert rel < 0.25, cal
        print('CLOSE', cal)
    """)
    assert "CLOSE" in out


def test_moe_strategies_agree_on_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.parallel import sharding as shd
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ('data', 'model'))
        cfg0 = get_smoke_config('arctic-480b').replace(
            scan_layers=True, capacity_factor=4.0)
        params = build_model(cfg0).init(jax.random.key(0))
        batch = {'tokens': jax.random.randint(jax.random.key(1), (8, 32),
                                              0, 512)}
        outs = {}
        for strat in ['local', 'move_compute', 'move_data']:
            cfg = cfg0.replace(parallel=cfg0.parallel.replace(
                moe_strategy=strat))
            api = build_model(cfg)
            def step(p, b):
                with shd.use_mesh(mesh):
                    return api.loss(p, b, mesh)[0]
            outs[strat] = float(jax.jit(step)(params, batch))
        assert abs(outs['local'] - outs['move_compute']) < 3e-2, outs
        assert abs(outs['local'] - outs['move_data']) < 3e-2, outs
        print('AGREE', outs)
    """)
    assert "AGREE" in out


def test_periodic_sync_equals_direct_when_delta_1():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.optim.periodic import (init_accumulator, init_error,
                                          make_periodic_steps)
        from repro.optim.optimizer import (OptimizerConfig, adamw_update,
                                           init_opt_state)
        from repro.parallel import sharding as shd
        from repro.compat import make_mesh
        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        cfg = get_smoke_config('qwen2-7b').replace(dtype='float32')
        api = build_model(cfg)
        params = api.init(jax.random.key(0))
        opt_cfg = OptimizerConfig(grad_clip=0.0, warmup_steps=0)
        opt = init_opt_state(params, opt_cfg)
        batch = {'tokens': jax.random.randint(jax.random.key(1), (8, 32),
                                              0, 512)}
        # direct: plain global grad + update
        def lf(p):
            with shd.use_mesh(mesh):
                return api.loss(p, batch, mesh)[0]
        g = jax.jit(jax.grad(lf))(params)
        p_ref, _, _ = adamw_update(params, g, opt, opt_cfg)
        # periodic with Delta=1: accum once then sync
        accum, sync = make_periodic_steps(api, mesh, opt_cfg)
        acc = init_accumulator(params, mesh)
        err = init_error(params, mesh)
        acc, m = accum(params, acc, batch)
        p_new, opt2, acc, err, stats = sync(params, opt, acc, err)
        d = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p_ref),
                                jax.tree.leaves(p_new)))
        assert d < 2e-5, d
        print('EQUAL', d)
    """)
    assert "EQUAL" in out


def test_pipeline_parallel_equals_sequential():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply
        from repro.compat import make_mesh
        mesh = make_mesh((4,), ('stage',))
        L, d = 8, 16
        ks = jax.random.split(jax.random.key(0), L)
        w = jax.vmap(lambda k: jax.random.normal(k, (d, d)) * 0.2)(ks)
        def layer_fn(lp, x):  # lp: pytree slice for one layer
            return jnp.tanh(x @ lp['w'])
        xs = jax.random.normal(jax.random.key(1), (6, 3, d))  # (M, mb, d)
        # sequential reference
        def seq(x):
            for i in range(L):
                x = layer_fn({'w': w[i]}, x)
            return x
        ref = jax.vmap(seq)(xs)
        out = pipeline_apply(layer_fn, {'w': w}, xs, mesh, axis='stage')
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print('PIPE OK')
    """)
    assert "PIPE OK" in out


def test_elastic_remesh_restore():
    """Checkpoint on 8 devices -> restore + train on 4 devices."""
    out = run_py("""
        import os, tempfile
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.checkpoint.manager import save
        from repro.runtime.elastic import make_elastic_mesh, remesh_restore
        from repro.optim.optimizer import OptimizerConfig, init_opt_state
        from repro.launch.steps import make_train_step, opt_config_for
        from repro.parallel import sharding as shd

        cfg = get_smoke_config('qwen2-7b')
        api = build_model(cfg)
        params = api.init(jax.random.key(0))
        opt = init_opt_state(params, opt_config_for(cfg))
        d = tempfile.mkdtemp()
        save(d, 5, {'params': params, 'opt': opt})
        # new, smaller mesh from 4 surviving devices
        mesh = make_elastic_mesh(jax.devices()[:4])
        assert dict(mesh.shape) == {'data': 2, 'model': 2}, mesh.shape
        step, tree, shards = remesh_restore(d, {'params': params, 'opt': opt},
                                            mesh)
        assert step == 5
        train = jax.jit(make_train_step(api, mesh, opt_config_for(cfg)))
        batch = {'tokens': jax.random.randint(jax.random.key(1), (4, 32),
                                              0, 512)}
        p2, o2, m = train(tree['params'], tree['opt'], batch)
        assert bool(jnp.isfinite(m['loss'])), m
        print('ELASTIC OK', float(m['loss']))
    """)
    assert "ELASTIC OK" in out


def test_int8_compressed_sync_close_to_exact():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.parallel.compress import allreduce_int8
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        mesh = make_mesh((8,), ('pod',))
        x = jax.random.normal(jax.random.key(0), (8, 128))
        def body(xl):
            red, err = allreduce_int8(xl[0], jnp.zeros_like(xl[0]), 'pod')
            return red[None], err[None]
        red, err = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P('pod'),),
            out_specs=(P('pod'), P('pod')), check_vma=False))(x)
        exact = jnp.mean(x, 0)
        rel = float(jnp.abs(red[0] - exact).max() /
                    jnp.abs(exact).max())
        assert rel < 0.05, rel
        print('INT8 OK', rel)
    """)
    assert "INT8 OK" in out


def test_from_connectome_old_new_identical_4ranks():
    """ISSUE 10 acceptance: growth from a generated hemibrain-shaped
    surrogate holds the old==new connectivity bit-identity on a 4-rank
    mesh — both algorithms rewire the loaded connectome identically."""
    out = run_py("""
        import dataclasses
        import numpy as np
        from repro.configs.msp_brain import SMOKE_CONFIG
        from repro.sim.api import Simulator
        from repro.workloads import datasets as wds
        base = dataclasses.replace(SMOKE_CONFIG, spike_alg='old',
                                   requests_cap_factor=1000)
        ds = wds.generate_hemibrain_surrogate(
            4 * 64, 64, max_degree=base.max_synapses,
            fraction_excitatory=base.fraction_excitatory)
        res = {}
        for alg in ['old', 'new']:
            cfg = dataclasses.replace(base, connectivity_alg=alg)
            sim = Simulator.from_connectome(cfg, ds)
            for _ in range(3):
                st = sim.step()
            res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                        np.sort(np.asarray(st.in_edges), 1),
                        float(st.stats['synapses_formed'].sum()))
        assert np.array_equal(res['old'][0], res['new'][0]), 'out differ'
        assert np.array_equal(res['old'][1], res['new'][1]), 'in differ'
        assert res['old'][2] == res['new'][2]
        print('CONN IDENTICAL', res['old'][2])
    """, devices=4)
    assert "CONN IDENTICAL" in out


def test_from_connectome_sparse_dense_identical_4ranks():
    """ISSUE 10 acceptance: on a loaded surrogate the sparse exchange
    (subscription registry sized from the MEASURED unique-remote-source
    count) stays bit-identical to the dense all-gather on 4 ranks."""
    out = run_py("""
        import dataclasses
        import numpy as np
        from repro.configs.msp_brain import SMOKE_CONFIG
        from repro.sim.api import Simulator
        from repro.workloads import datasets as wds
        base = dataclasses.replace(SMOKE_CONFIG, requests_cap_factor=1000)
        ds = wds.generate_hemibrain_surrogate(
            4 * 64, 64, max_degree=base.max_synapses,
            fraction_excitatory=base.fraction_excitatory)
        res = {}
        for layout in ['dense', 'sparse']:
            cfg = dataclasses.replace(base, rate_exchange=layout)
            sim = Simulator.from_connectome(cfg, ds)
            for _ in range(3):
                st = sim.step()
            res[layout] = (np.asarray(st.neurons.rate),
                           np.asarray(st.neurons.calcium),
                           np.sort(np.asarray(st.out_edges), 1))
        a, b = res['dense'], res['sparse']
        assert np.array_equal(a[0], b[0]), 'rates differ'
        assert np.array_equal(a[1], b[1]), 'calcium differ'
        assert np.array_equal(a[2], b[2]), 'edges differ'
        print('SPARSE==DENSE OK', float(a[0].sum()))
    """, devices=4)
    assert "SPARSE==DENSE OK" in out
