"""repro.workloads: dataset round-trip, generator invariants,
``from_connectome`` bit-identity, engram recall determinism across
layouts/lowerings, retrace-free rate assimilation, and the measured
subscription-cap sizing (DESIGN.md §13). Multi-rank bit-identity lives
in tests/test_multidevice.py."""
import dataclasses

import numpy as np
import pytest

from repro.configs.msp_brain import SMOKE_CONFIG
from repro.connectome import routing
from repro.workloads import datasets as wds

CFG = dataclasses.replace(SMOKE_CONFIG, requests_cap_factor=1000)
N = CFG.neurons_per_rank   # single-rank in-process suite: N == n


def _surrogate(**kw):
    args = dict(num_neurons=N, block=N, max_degree=CFG.max_synapses,
                fraction_excitatory=CFG.fraction_excitatory)
    args.update(kw)
    return wds.generate_hemibrain_surrogate(**args)


# ------------------------------------------------------------- datasets
def test_generator_deterministic_and_valid():
    a, b = _surrogate(), _surrogate()
    wds.validate(a)
    for fa, fb in zip(a, b):
        if isinstance(fa, np.ndarray):
            np.testing.assert_array_equal(fa, fb)
        else:
            assert fa == fb
    assert _surrogate(seed=1).num_edges != a.num_edges or not \
        np.array_equal(_surrogate(seed=1).positions, a.positions)


def test_generator_invariants():
    ds = _surrogate(num_neurons=8 * N, avg_degree=4.0, degree_sigma=1.0)
    out_deg, in_deg = ds.out_degrees(), ds.in_degrees()
    # degrees respect the cap on both sides
    assert out_deg.max() <= CFG.max_synapses
    assert in_deg.max() <= CFG.max_synapses
    # log-normal heavy tail: the max out-degree well clear of the median
    assert out_deg.max() >= 2 * np.median(out_deg)
    # excitation is periodic per rank block (the replicated-derivation
    # population invariant), gid == global row
    exc = ds.is_excitatory.reshape(-1, N)
    np.testing.assert_array_equal(exc, np.broadcast_to(exc[0], exc.shape))
    assert exc[0, :int(N * CFG.fraction_excitatory)].all()
    assert not exc[0, int(N * CFG.fraction_excitatory):].any()
    # every neuron sits inside its region's box
    box = ds.region_boxes[ds.region_ids]
    assert (ds.positions >= box[:, 0]).all() and \
        (ds.positions < box[:, 1]).all()
    # canonical (pre, post) edge order
    order = np.lexsort((ds.edges[:, 1], ds.edges[:, 0]))
    np.testing.assert_array_equal(order, np.arange(ds.num_edges))
    # locality bias: most edges stay in-region
    rsrc = ds.region_ids[ds.edges[:, 0]]
    rtgt = ds.region_ids[ds.edges[:, 1]]
    assert (rsrc == rtgt).mean() > 0.5


def test_dataset_roundtrip_bit_identical_state(tmp_path):
    from repro.sim.api import Simulator
    ds = _surrogate()
    path = str(tmp_path / "surrogate.npz")
    wds.save(path, ds)
    ds2 = wds.load(path)
    for fa, fb in zip(ds, ds2):
        if isinstance(fa, np.ndarray):
            np.testing.assert_array_equal(fa, fb)
        else:
            assert fa == fb
    st1 = Simulator.from_connectome(CFG, ds).state
    st2 = Simulator.from_connectome(CFG, ds2).state
    for a, b in ((st1.out_edges, st2.out_edges),
                 (st1.in_edges, st2.in_edges),
                 (st1.positions, st2.positions),
                 (st1.neurons.ax_elements, st2.neurons.ax_elements),
                 (st1.neurons.is_excitatory, st2.neurons.is_excitatory)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_rejects_future_format(tmp_path):
    ds = _surrogate()
    path = str(tmp_path / "surrogate.npz")
    wds.save(path, ds)
    with np.load(path, allow_pickle=False) as z:
        fields = dict(z)
    fields["format_version"] = np.int64(wds.FORMAT_VERSION + 1)
    np.savez_compressed(path, **fields)
    with pytest.raises(ValueError, match="format_version"):
        wds.load(path)


def test_from_connectome_checks_layout_and_degrees():
    from repro.sim.api import Simulator
    with pytest.raises(ValueError, match="population table"):
        Simulator.from_connectome(
            CFG, _surrogate(fraction_excitatory=0.5))
    with pytest.raises(ValueError, match="max_synapses"):
        wds.edge_tables(_surrogate(), CFG.max_synapses // 2)
    with pytest.raises(ValueError, match="gid == global row"):
        Simulator.from_connectome(
            CFG, _surrogate(num_neurons=2 * N, block=N))


def test_from_connectome_matches_dataset():
    from repro.sim.api import Simulator
    ds = _surrogate()
    sim = Simulator.from_connectome(CFG, ds)
    st = sim.state
    out_e, in_e = wds.edge_tables(ds, CFG.max_synapses)
    np.testing.assert_array_equal(np.asarray(st.out_edges), out_e)
    np.testing.assert_array_equal(np.asarray(st.in_edges), in_e)
    np.testing.assert_array_equal(np.asarray(st.positions), ds.positions)
    # wired degrees are covered by the element counts, vacancy on top
    ax = np.asarray(st.neurons.ax_elements)
    assert (ax >= ds.out_degrees() + CFG.initial_vacant_low - 1e-5).all()


def test_from_connectome_old_new_connectivity_identical():
    """The paper claim holds when growth starts from a loaded connectome:
    both connectivity algorithms rewire it identically."""
    from repro.sim.api import Simulator
    ds = _surrogate()
    base = dataclasses.replace(CFG, spike_alg="old")
    res = {}
    for alg in ("old", "new"):
        cfg = dataclasses.replace(base, connectivity_alg=alg)
        sim = Simulator.from_connectome(cfg, ds)
        for _ in range(3):
            st = sim.step()
        res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                    np.sort(np.asarray(st.in_edges), 1))
    np.testing.assert_array_equal(res["old"][0], res["new"][0])
    np.testing.assert_array_equal(res["old"][1], res["new"][1])


# --------------------------------------------------------------- engram
def _engram_metrics(**cfg_kw):
    from repro.workloads import engram as weng
    cfg = dataclasses.replace(CFG, **cfg_kw)
    spec = weng.EngramSpec(train_chunks=2, rest_chunks=1, recall_chunks=1)
    m, _ = weng.run_engram(cfg, spec=spec)
    return m


def test_engram_recall_deterministic_across_lowerings():
    """recall_overlap is a function of the protocol, not of the layout or
    lowering: dense == sparse exchange and reference == fused activity,
    bit-identically."""
    ref = _engram_metrics()
    for kw in ({"rate_exchange": "sparse"},
               {"activity_impl": "fused"},
               {"rate_exchange": "sparse", "activity_impl": "fused"}):
        m = _engram_metrics(**kw)
        assert m == ref, (kw, m, ref)
    assert 0.0 <= ref["recall_overlap"] <= 1.0
    assert ref["target_neurons"] > 0 and ref["cue_neurons"] > 0


def test_engram_from_connectome_runs():
    from repro.workloads import engram as weng
    spec = weng.EngramSpec(train_chunks=2, rest_chunks=1, recall_chunks=1)
    m, sim = weng.run_engram(CFG, spec=spec, dataset=_surrogate())
    assert 0.0 <= m["recall_overlap"] <= 1.0
    assert sim.stats()["synapses_formed"] >= 0.0


# ----------------------------------------------------------- assimilate
def test_assimilation_converges_without_retrace():
    from repro.workloads import assimilate as was
    res, sim = was.run_assimilation(CFG, chunks=10, target_rate=0.02)
    assert res.compile_count == 1, "dynamic params must not retrace"
    assert res.abs_err[-1] < res.abs_err[0], \
        (res.abs_err[0], res.abs_err[-1])
    assert res.abs_err[-1] < 0.01
    # the controller holds only the controlled bucket; the free rest
    # bucket keeps its NaN target untouched
    assert np.isnan(res.target[:, 1]).all()


def test_assimilation_drop_region_recovery():
    from repro.runtime import chaos
    from repro.workloads import assimilate as was
    hook = chaos.drop_region_input("driven", chunks=2, after_chunk=4)
    res, _ = was.run_assimilation(CFG, chunks=14, hooks=[hook])
    assert res.compile_count == 1
    # the drop zeroes the region's drive: rate collapses in the window...
    dropped = res.measured[4:6, 0]
    assert (dropped < res.measured[3, 0] * 0.5).all(), res.measured[:, 0]
    # ...and the applied drive actually cancelled the background
    np.testing.assert_allclose(res.drive[4:6, 0], -CFG.background_mean)
    # controller winds back up after the window
    assert res.abs_err[-1] < res.abs_err[5], res.abs_err


def test_step_with_matches_step_at_zero_drive():
    """DynamicParams(0) through step_with is bit-identical to the static
    step() trace — the dynamic path adds an input surface, not dynamics."""
    import jax
    from repro.sim import phases as sim_phases
    from repro.sim.api import Simulator
    from repro.workloads import assimilate as was
    scn = was.default_scenario()
    a = Simulator.from_config(CFG, scenario=scn)
    b = Simulator.from_config(CFG, scenario=scn)
    sa = a.step()
    dyn = sim_phases.DynamicParams.zeros(2)
    sb = b.step_with(dyn)
    np.testing.assert_array_equal(np.asarray(sa.neurons.rate),
                                  np.asarray(sb.neurons.rate))
    np.testing.assert_array_equal(np.asarray(sa.out_edges),
                                  np.asarray(sb.out_edges))
    sb = b.step_with(dyn)
    assert b.dyn_compile_count() == 1
    np.testing.assert_array_equal(np.asarray(a.step().out_edges),
                                  np.asarray(sb.out_edges))


# ------------------------------------------------------------- cap_subs
def test_cap_subs_measured_base():
    cfg = dataclasses.replace(SMOKE_CONFIG, max_synapses=8,
                              subs_cap_factor=2)
    # default: n // R head-room (floor 32), times the factor, ceil to 8
    assert routing.subs_base(cfg, 4) == max(64 // 4, 32)
    assert routing.cap_subs(cfg, 4) == 32 * 2
    # measured base replaces the synthetic default
    meas = dataclasses.replace(cfg, subs_cap_base=41)
    assert routing.subs_base(meas, 4) == 41
    assert routing.cap_subs(meas, 4) == min(64 * 8, 3 * 64, -(-41 * 2 // 8) * 8)
    # floor at 32, ceiling at (R-1)*n regardless of the measurement
    tiny = dataclasses.replace(cfg, subs_cap_base=1)
    assert routing.subs_base(tiny, 4) == 32
    huge = dataclasses.replace(cfg, subs_cap_base=10_000)
    assert routing.cap_subs(huge, 4) == 3 * 64


def test_from_connectome_bakes_measured_base():
    from repro.sim.api import Simulator
    ds = _surrogate()
    cfg = dataclasses.replace(CFG, rate_exchange="sparse")
    sim = Simulator.from_connectome(cfg, ds)
    assert sim.cfg.subs_cap_base == wds.max_unique_remote_sources(ds, N)
    assert sim.ckpt_metadata()["subs_cap_base"] == sim.cfg.subs_cap_base
    # single rank: no remote sources at all
    assert sim.cfg.subs_cap_base == 0
    assert float(sim.step().stats["subscription_overflow"].sum()) == 0.0
