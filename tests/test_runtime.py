"""Fault-tolerance runtime tests (DESIGN.md §10).

In-process (single device): atomic heartbeat, TrainingRunner resume/
rollback/preemption, checkpoint checksum verification + typed corruption
errors, elastic.remesh_restore (LM path), and the SimulationRunner's
recovery paths driven by the runtime.chaos injectors.

Subprocess (4 host devices, same pattern as tests/test_multidevice.py):
kill-and-resume bit-identity across exchange layouts and activity
lowerings, elastic brain restore R=4 -> R=2, and the overflow
degradation ladder.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import manager  # noqa: E402
from repro.configs.msp_brain import BrainConfig  # noqa: E402
from repro.runtime import chaos, elastic, fault_tolerance as ft  # noqa: E402
from repro.runtime.sim_runner import (SimRunnerConfig,  # noqa: E402
                                      SimulationRunner)
from repro.sim import Simulator  # noqa: E402

SMALL = dict(neurons_per_rank=32, local_levels=3, frontier_cap=32,
             max_synapses=8, rate_period=10, requests_cap_factor=100,
             subs_cap_factor=100)


def run_py(code, devices=4, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(jax.device_get(x)),
                              np.asarray(jax.device_get(y)))


# ===================================================================
# heartbeat
# ===================================================================
def test_heartbeat_write_is_atomic(tmp_path):
    """write_heartbeat never leaves a torn file or a stray temp."""
    hb = str(tmp_path / "hb.json")
    for step in range(5):
        ft.write_heartbeat(hb, {"step": step})
        with open(hb) as f:          # always a complete JSON document
            d = json.load(f)
        assert d["step"] == step and "t" in d
    assert os.listdir(tmp_path) == ["hb.json"]   # no tmp residue


# ===================================================================
# TrainingRunner (the seed's LM-path runner)
# ===================================================================
def _toy_runner(tmp_path, **kw):
    def step_fn(params, opt, batch):
        params = {"w": params["w"] + batch.sum()}
        return params, opt + 1, {"loss": batch.sum()}

    def data():
        while True:
            yield jnp.ones((2,))

    cfg = ft.RunnerConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                          keep=3, **kw)
    return ft.TrainingRunner(cfg, step_fn, {"w": jnp.zeros(())},
                             jnp.zeros((), jnp.int32), data())


def test_training_runner_resume(tmp_path):
    r = _toy_runner(tmp_path)
    assert r.run(5) == "done"
    r2 = _toy_runner(tmp_path)
    assert r2.try_resume()
    assert r2.step == 5
    assert float(r2.params["w"]) == 10.0        # 5 steps x batch.sum()==2


def test_training_runner_nan_rollback(tmp_path):
    r = _toy_runner(tmp_path)
    fired = []

    def poison(step, batch):
        # once: a step-keyed trigger would re-fire on the post-rollback
        # replay of the same step and exhaust max_rollbacks
        if step == 3 and not fired:
            fired.append(step)
            return batch * jnp.nan
        return batch

    assert r.run(6, poison_hook=poison) == "done"
    assert r.rollbacks == 1
    assert float(r.params["w"]) == 12.0         # poisoned window skipped


def test_training_runner_preempt(tmp_path):
    r = _toy_runner(tmp_path)
    orig = r._heartbeat

    def hb_and_preempt():
        orig()
        if r.step == 3:
            r.preempt()

    r._heartbeat = hb_and_preempt
    assert r.run(10) == "preempted"
    r2 = _toy_runner(tmp_path)
    assert r2.try_resume() and r2.step == 3


def test_elastic_remesh_restore_lm(tmp_path):
    """The seed LM path: restore onto a fresh (1,1) mesh."""
    params = {"tok_embed": jnp.ones((8, 4))}
    opt = {"m": {"tok_embed": jnp.zeros((8, 4))},
           "v": {"tok_embed": jnp.zeros((8, 4))}, "step": jnp.zeros(())}
    manager.save(str(tmp_path), 7, {"params": params, "opt": opt})
    mesh = elastic.make_elastic_mesh(jax.devices()[:1])
    step, tree, _ = elastic.remesh_restore(
        str(tmp_path), {"params": params, "opt": opt}, mesh)
    assert step == 7
    _leaves_equal(tree["params"], params)


# ===================================================================
# checkpoint verification
# ===================================================================
def _save_steps(tmp_path, steps):
    for s in steps:
        manager.save(str(tmp_path), s, {"a": jnp.arange(4.0) + s,
                                        "b": jnp.ones((2, 2)) * s})
    return {"a": jnp.zeros(4), "b": jnp.zeros((2, 2))}


@pytest.mark.parametrize("mode", ["flip", "truncate", "manifest"])
def test_corrupt_checkpoint_raises_typed(tmp_path, mode):
    target = _save_steps(tmp_path, [1, 2])
    chaos.corrupt_checkpoint(str(tmp_path), step=2, mode=mode)
    with pytest.raises(manager.CorruptCheckpointError):
        manager.restore(str(tmp_path), 2, target)
    # restore_latest walks past the corrupt newest step
    ck = manager.AsyncCheckpointer(str(tmp_path))
    step, tree, _ = ck.restore_latest(target)
    assert step == 1
    assert np.array_equal(np.asarray(tree["a"]), np.arange(4.0) + 1)


def test_load_arrays_roundtrip_and_verify(tmp_path):
    _save_steps(tmp_path, [3])
    arrays, manifest = manager.load_arrays(str(tmp_path), 3)
    assert np.array_equal(arrays["a"], np.arange(4.0) + 3)
    assert all("crc32" in v for v in manifest["leaves"].values())
    chaos.corrupt_checkpoint(str(tmp_path), step=3, mode="flip")
    with pytest.raises(manager.CorruptCheckpointError):
        manager.load_arrays(str(tmp_path), 3)


# ===================================================================
# SimulationRunner, single rank
# ===================================================================
@pytest.fixture(scope="module")
def small_cfg():
    return BrainConfig(**SMALL)


@pytest.fixture(scope="module")
def ref_state(small_cfg):
    """Final state of an uninterrupted 6-chunk run (the bit-identity
    reference for every recovery test below)."""
    sim = Simulator(small_cfg)
    sim.run(6)
    return sim.state


def test_runner_matches_plain_run(tmp_path, small_cfg, ref_state):
    r = SimulationRunner(SimRunnerConfig(str(tmp_path / "ck"),
                                         ckpt_every=2), cfg=small_cfg)
    assert r.run(6) == "done"
    _leaves_equal(r.sim.state, ref_state)
    s = r.sim.stats()
    assert s["checkpoint_saves"] >= 3 and s["rollbacks"] == 0
    # health gauges: clean verdict, live-entry census populated
    h = r.sim.health()
    assert h["health_flags"] == 0
    assert h["out_edges_live"] == h["in_edges_live"] > 0


def test_runner_nan_rollback_recovers_bit_identical(tmp_path, small_cfg,
                                                    ref_state):
    r = SimulationRunner(SimRunnerConfig(str(tmp_path / "ck"),
                                         ckpt_every=2), cfg=small_cfg)
    r.chaos_hooks.append(chaos.poison_nan_once(field="v", after_chunk=3))
    assert r.run(6) == "done"
    assert r.sim.lifecycle["rollbacks"] >= 1
    _leaves_equal(r.sim.state, ref_state)


def test_runner_probe_flags_poisoned_state(small_cfg):
    from repro.telemetry import metrics as tm
    sim = Simulator(small_cfg)
    sim.run(1)
    assert sim.probe_health() == 0
    st = sim.state
    arr = np.array(jax.device_get(st.neurons.calcium))
    arr[0] = np.inf
    sim._state = st._replace(neurons=st.neurons._replace(
        calcium=jax.device_put(arr, st.neurons.calcium.sharding)))
    assert sim.probe_health() & tm.HEALTH_NONFINITE


def test_runner_preempt_resume_bit_identical(tmp_path, small_cfg,
                                             ref_state):
    ck = str(tmp_path / "ck")
    r = SimulationRunner(SimRunnerConfig(ck, ckpt_every=2), cfg=small_cfg)
    r.chaos_hooks.append(chaos.preempt_after(4))
    assert r.run(6) == "preempted"
    r2 = SimulationRunner(SimRunnerConfig(ck, ckpt_every=2), cfg=small_cfg)
    cur = int(jax.device_get(r2.sim.state.chunk))
    assert cur == 4 and r2.sim.lifecycle["restarts"] == 1
    assert r2.run(6 - cur) == "done"
    _leaves_equal(r2.sim.state, ref_state)


def test_runner_resume_skips_corrupt_newest(tmp_path, small_cfg):
    ck = str(tmp_path / "ck")
    r = SimulationRunner(SimRunnerConfig(ck, ckpt_every=2), cfg=small_cfg)
    assert r.run(4) == "done"
    newest = chaos.corrupt_checkpoint(ck, mode="truncate")
    r2 = SimulationRunner(SimRunnerConfig(ck, ckpt_every=2), cfg=small_cfg)
    assert int(jax.device_get(r2.sim.state.chunk)) < newest


# ===================================================================
# multi-rank, via subprocess (4 host devices)
# ===================================================================
_VARIANTS = [("dense", "reference"), ("sparse", "reference"),
             ("dense", "fused"), ("sparse", "fused")]


@pytest.mark.parametrize("exchange,activity", _VARIANTS)
def test_kill_resume_bit_identical_4rank(exchange, activity):
    """Kill after 2 of 4 chunks + resume in a fresh process-level runner
    == uninterrupted run: every BrainState leaf and physics counter."""
    out = run_py(f"""
        import dataclasses, tempfile, os
        import jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.runtime import chaos
        from repro.runtime.sim_runner import (SimRunnerConfig,
                                              SimulationRunner)
        from repro.sim import Simulator
        cfg = BrainConfig(neurons_per_rank=64, local_levels=3,
                          frontier_cap=32, max_synapses=8, rate_period=10,
                          requests_cap_factor=100, subs_cap_factor=100,
                          rate_exchange={exchange!r},
                          activity_impl={activity!r})
        ref = Simulator(cfg); ref.run(4)
        with tempfile.TemporaryDirectory() as d:
            ck = os.path.join(d, 'ck')
            r = SimulationRunner(SimRunnerConfig(ck, ckpt_every=1),
                                 cfg=cfg)
            r.chaos_hooks.append(chaos.preempt_after(2))
            assert r.run(4) == 'preempted'
            r2 = SimulationRunner(SimRunnerConfig(ck, ckpt_every=1),
                                  cfg=cfg)
            cur = int(jax.device_get(r2.sim.state.chunk))
            assert cur == 2, cur
            assert r2.run(4 - cur) == 'done'
            for a, b in zip(jax.tree.leaves(ref.state),
                            jax.tree.leaves(r2.sim.state)):
                assert np.array_equal(np.asarray(jax.device_get(a)),
                                      np.asarray(jax.device_get(b)))
            sa, sb = ref.stats(), r2.sim.stats()
            from repro import telemetry
            for k in telemetry.COUNTER_KEYS:
                assert sa[k] == sb[k], (k, sa[k], sb[k])
        print('KILL_RESUME_OK')
    """)
    assert "KILL_RESUME_OK" in out


def test_elastic_shrink_4_to_2_old_new_identical():
    """A checkpoint written at R=4 resumes on R=2: the subscription
    registry is rebuilt for the new rank count, and the old==new
    connectivity bit-identity is preserved on the shrunken mesh."""
    out = run_py("""
        import dataclasses, tempfile, os
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs.msp_brain import BrainConfig
        from repro.runtime import elastic
        from repro.sim import Simulator
        base = BrainConfig(neurons_per_rank=64, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=10,
                           spike_alg='old', requests_cap_factor=1000)
        sim4 = Simulator(base)
        sim4.run(2)
        with tempfile.TemporaryDirectory() as d:
            sim4.save(d)
            mesh2 = Mesh(np.array(jax.devices()[:2]), ('ranks',))
            res = {}
            for alg in ['old', 'new']:
                cfg2 = dataclasses.replace(
                    base, neurons_per_rank=128, connectivity_alg=alg)
                sim2, step = elastic.remesh_restore_brain(
                    d, cfg2, mesh=mesh2)
                assert step == 2
                # the restored global arrays match the writer's exactly
                for name in ('out_edges', 'in_edges', 'positions'):
                    assert np.array_equal(
                        np.asarray(jax.device_get(getattr(sim4.state,
                                                          name))),
                        np.asarray(jax.device_get(getattr(sim2.state,
                                                          name)))), name
                sim2.run(2)
                assert sim2.health()['health_flags'] == 0
                res[alg] = (
                    np.sort(np.asarray(jax.device_get(
                        sim2.state.out_edges)), 1),
                    np.sort(np.asarray(jax.device_get(
                        sim2.state.in_edges)), 1),
                    sim2.stats()['synapses_formed'])
            assert np.array_equal(res['old'][0], res['new'][0])
            assert np.array_equal(res['old'][1], res['new'][1])
            assert res['old'][2] == res['new'][2] > 0
        print('ELASTIC_OLD_NEW_OK')
    """)
    assert "ELASTIC_OLD_NEW_OK" in out


def test_elastic_shrink_sparse_matches_dense():
    """The same R=4 sparse checkpoint restored at R=2 as sparse and as
    dense agrees bitwise on the physical state after two more chunks —
    the rebuilt registry is exactly the dense exchange's information."""
    out = run_py("""
        import dataclasses, tempfile, os
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs.msp_brain import BrainConfig
        from repro.runtime import elastic
        from repro.sim import Simulator
        base = BrainConfig(neurons_per_rank=64, local_levels=3,
                           frontier_cap=32, max_synapses=8, rate_period=10,
                           requests_cap_factor=100, subs_cap_factor=100,
                           rate_exchange='sparse')
        sim4 = Simulator(base)
        sim4.run(2)
        with tempfile.TemporaryDirectory() as d:
            sim4.save(d)
            mesh2 = Mesh(np.array(jax.devices()[:2]), ('ranks',))
            res = {}
            for exch in ['sparse', 'dense']:
                cfg2 = dataclasses.replace(base, neurons_per_rank=128,
                                           rate_exchange=exch)
                sim2, _ = elastic.remesh_restore_brain(d, cfg2,
                                                       mesh=mesh2)
                sim2.run(2)
                st = sim2.state
                res[exch] = [np.asarray(jax.device_get(x)) for x in
                             (st.neurons.v, st.neurons.calcium,
                              st.neurons.rate, st.out_edges, st.in_edges)]
            for a, b in zip(res['sparse'], res['dense']):
                assert np.array_equal(a, b)
        print('ELASTIC_SPARSE_DENSE_OK')
    """)
    assert "ELASTIC_SPARSE_DENSE_OK" in out


def test_degrade_ladder_4rank():
    """Persistent subscription overflow first grows the achieved cap,
    and with growth disabled falls back to the dense layout; the run
    completes either way and the escalations are counted."""
    out = run_py("""
        import dataclasses, tempfile, os
        from repro.configs.msp_brain import BrainConfig
        from repro.runtime import chaos
        from repro.runtime.sim_runner import (SimRunnerConfig,
                                              SimulationRunner)
        base = chaos.overflow_config(
            BrainConfig(neurons_per_rank=256, local_levels=3,
                        frontier_cap=32, max_synapses=16, rate_period=10,
                        rate_exchange='sparse'))
        with tempfile.TemporaryDirectory() as d:
            r = SimulationRunner(
                SimRunnerConfig(os.path.join(d, 'ck'), ckpt_every=2,
                                overflow_patience=1), cfg=base)
            assert r.run(4) == 'done'
            assert r.sim.stats()['degrade_events'] >= 1
            assert r.sim.cfg.rate_exchange == 'sparse'
            assert r.sim.cfg.subs_cap_factor > base.subs_cap_factor
        with tempfile.TemporaryDirectory() as d:
            r = SimulationRunner(
                SimRunnerConfig(os.path.join(d, 'ck'), ckpt_every=2,
                                overflow_patience=1, subs_growth_factor=0),
                cfg=base)
            assert r.run(4) == 'done'
            assert r.sim.cfg.rate_exchange == 'dense'
            assert r.sim.stats()['degrade_events'] >= 1
        print('DEGRADE_OK')
    """)
    assert "DEGRADE_OK" in out


# ===================================================================
# heartbeat staleness (read side + runner lifecycle echo)
# ===================================================================
def test_read_heartbeat_fresh_stale_missing(tmp_path):
    hb = str(tmp_path / "hb.json")
    payload, age, verdict = ft.read_heartbeat(hb, max_age_s=1.0)
    assert (payload, age, verdict) == (None, None, "missing")
    ft.write_heartbeat(hb, {"chunk": 7})
    payload, age, verdict = ft.read_heartbeat(hb, max_age_s=60.0)
    assert verdict == "fresh" and payload["chunk"] == 7 and age >= 0
    # clock override: the same beat judged 2 minutes later is stale
    stale_now = payload["t"] + 120.0
    payload, age, verdict = ft.read_heartbeat(hb, max_age_s=60.0,
                                              now=stale_now)
    assert verdict == "stale" and age > 60.0
    # no threshold -> age is reported but never judged stale
    _, _, verdict = ft.read_heartbeat(hb, now=stale_now)
    assert verdict == "fresh"
    # a garbled file reads as missing (atomic writes can't tear, so
    # unparseable JSON means no heartbeat was ever completed)
    with open(hb, "w") as f:
        f.write("{not json")
    assert ft.read_heartbeat(hb)[2] == "missing"


def test_runner_counts_stale_heartbeat(tmp_path, small_cfg):
    hb = str(tmp_path / "hb.json")
    # plant an ancient beat: the runner's first interval must flag it
    ft.write_heartbeat(hb, {"chunk": 0})
    with open(hb) as f:
        old = json.load(f)
    old["t"] -= 3600.0
    with open(hb, "w") as f:
        json.dump(old, f)
    r = SimulationRunner(
        SimRunnerConfig(str(tmp_path / "ck"), ckpt_every=1,
                        heartbeat_path=hb, heartbeat_max_age_s=60.0),
        cfg=small_cfg)
    assert r.run(2) == "done"
    assert r.sim.lifecycle["heartbeat_stale"] == 1   # only the planted beat
    assert r.sim.stats()["heartbeat_stale"] == 1


# ===================================================================
# health_verdict unit matrix: each monitored field individually
# tripped and individually reported (single-rank here; 4-rank below)
# ===================================================================
from repro.telemetry import metrics as tm  # noqa: E402


def _fresh_sim(small_cfg):
    sim = Simulator(small_cfg)
    sim.run(2)
    assert sim.probe_health() == 0
    return sim


def _put_leaf(leaf, value, index=0):
    arr = np.array(jax.device_get(leaf))
    arr.reshape(-1)[index] = value
    return jax.device_put(arr, leaf.sharding)


@pytest.mark.parametrize("field", ["v", "u", "calcium", "rate"])
@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_health_matrix_neuron_fields(small_cfg, field, bad):
    sim = _fresh_sim(small_cfg)
    st = sim.state
    sim._state = st._replace(neurons=st.neurons._replace(
        **{field: _put_leaf(getattr(st.neurons, field), bad)}))
    assert sim.probe_health() == tm.HEALTH_NONFINITE


@pytest.mark.parametrize("bad", [np.nan, np.inf])
def test_health_matrix_positions(small_cfg, bad):
    sim = _fresh_sim(small_cfg)
    st = sim.state
    sim._state = st._replace(
        positions=_put_leaf(st.positions, bad, index=-1))
    assert sim.probe_health() == tm.HEALTH_NONFINITE


def test_health_matrix_half_edge_asymmetry(small_cfg):
    sim = _fresh_sim(small_cfg)
    st = sim.state
    arr = np.array(jax.device_get(st.in_edges))
    live = np.argwhere(arr >= 0)
    assert len(live) > 0
    arr[tuple(live[0])] = -1          # orphan one half-edge
    sim._state = st._replace(
        in_edges=jax.device_put(arr, st.in_edges.sharding))
    flags = sim.probe_health()
    assert flags & tm.HEALTH_ASYMMETRY
    assert not flags & tm.HEALTH_NONFINITE


def test_health_matrix_synapse_conservation(small_cfg):
    sim = _fresh_sim(small_cfg)
    st = sim.state
    c = dict(st.stats.counters)
    arr = np.array(jax.device_get(c["synapses_formed"]))
    arr += 10                         # census now outside [2F-2D, 2F-D]
    c["synapses_formed"] = jax.device_put(
        arr, st.stats.counters["synapses_formed"].sharding)
    import dataclasses as _dc
    sim._state = st._replace(stats=_dc.replace(st.stats, counters=c))
    assert sim.probe_health() == tm.HEALTH_CONSERVATION


def test_health_matrix_overflow_masks_census_checks(small_cfg):
    """The asymmetry/conservation bits are guarded on request_overflow
    == 0 (dropped notifications legitimately skew the census)."""
    sim = _fresh_sim(small_cfg)
    st = sim.state
    c = dict(st.stats.counters)
    for key, delta in (("synapses_formed", 10), ("request_overflow", 1)):
        arr = np.array(jax.device_get(c[key]))
        arr += delta
        c[key] = jax.device_put(arr, st.stats.counters[key].sharding)
    import dataclasses as _dc
    sim._state = st._replace(stats=_dc.replace(st.stats, counters=c))
    assert sim.probe_health() == 0


def test_health_matrix_4rank():
    """The same matrix where it matters operationally: each fault class
    planted on ONE rank's shard must surface in the psum'd global
    verdict on a 4-rank mesh."""
    out = run_py(f"""
        import dataclasses, jax, numpy as np
        from repro.configs.msp_brain import BrainConfig
        from repro.sim import Simulator
        from repro.telemetry import metrics as tm

        sim = Simulator(BrainConfig(**{SMALL!r}))
        assert sim.num_ranks == 4
        sim.run(2)
        assert sim.probe_health() == 0
        clean = sim.state

        def put(leaf, value, index):
            arr = np.array(jax.device_get(leaf))
            arr.reshape(-1)[index] = value
            return jax.device_put(arr, leaf.sharding)

        # nonfinite: one element in rank 2's shard of each field
        for field in ("v", "u", "calcium", "rate"):
            st = clean
            n = np.asarray(jax.device_get(
                getattr(st.neurons, field))).size
            sim._state = st._replace(neurons=st.neurons._replace(
                **{{field: put(getattr(st.neurons, field), np.nan,
                               n // 2)}}))
            assert sim.probe_health() == tm.HEALTH_NONFINITE, field
        st = clean
        sim._state = st._replace(
            positions=put(st.positions, np.inf, -1))
        assert sim.probe_health() == tm.HEALTH_NONFINITE

        # asymmetry: orphan a half-edge on one rank only
        st = clean
        arr = np.array(jax.device_get(st.in_edges))
        live = np.argwhere(arr >= 0)
        arr[tuple(live[len(live) // 2])] = -1
        sim._state = st._replace(
            in_edges=jax.device_put(arr, st.in_edges.sharding))
        assert sim.probe_health() & tm.HEALTH_ASYMMETRY

        # conservation: inflate one rank's formed counter
        st = clean
        c = dict(st.stats.counters)
        arr = np.array(jax.device_get(c["synapses_formed"]))
        arr[1] += 10
        c["synapses_formed"] = jax.device_put(
            arr, st.stats.counters["synapses_formed"].sharding)
        sim._state = st._replace(
            stats=dataclasses.replace(st.stats, counters=c))
        assert sim.probe_health() == tm.HEALTH_CONSERVATION
        print("MATRIX4-OK")
    """)
    assert "MATRIX4-OK" in out
