"""Connectome subsystem: octree membership-cap overflow, stable bucket-rank
property, vectorized synapse-table ops vs the sequential semantics, and the
Pallas Barnes-Hut traversal kernel — kernel-vs-reference bit-identity plus
the engine-level old==new invariant under ``connectivity_impl='fused'``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.msp_brain import BrainConfig
from repro.connectome import routing, synapses, traverse
from repro.connectome import tree as ctree
from repro.core import engine
from repro.kernels import ops as kops
from repro.scenarios import Lesion, Recover, Scenario, Stimulate, library


# ---------------------------------------------------------------- tree
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=40))
def test_positions_within_stable_bucket_ranks(ids):
    """positions_within(ids)[i] counts the EARLIER occurrences of ids[i] —
    the stable-rank property every router (deletion messages, formation
    request slots, leaf membership) relies on."""
    a = jnp.asarray(ids, jnp.int32)
    got = np.asarray(ctree.positions_within(a, 8))
    want = [sum(1 for j in range(i) if ids[j] == ids[i])
            for i in range(len(ids))]
    np.testing.assert_array_equal(got, np.asarray(want))


@pytest.mark.parametrize("members_cap", [1, 2, 4])
def test_build_local_tree_members_cap_overflow(members_cap):
    """A leaf holding more neurons than members_cap keeps exactly the cap
    many, lowest-indexed first (stable), never corrupting other cells; the
    count/centroid aggregates still see every neuron."""
    cfg = BrainConfig(neurons_per_rank=12, local_levels=2)
    # 8 neurons stacked into one leaf cell, 4 spread elsewhere
    dense = jnp.tile(jnp.array([[0.03, 0.03, 0.03]]), (8, 1))
    sparse = jnp.array([[0.9, 0.9, 0.9], [0.6, 0.2, 0.2],
                        [0.2, 0.6, 0.2], [0.2, 0.2, 0.6]])
    pos = jnp.concatenate([dense, sparse])
    w = jnp.ones((12,))
    tree = ctree.build_local_tree(pos, w, 0, cfg, num_ranks=1,
                                  members_cap=members_cap)
    assert tree.leaf_members.shape[1] == members_cap
    from repro.core import morton
    cell = int(morton.morton_encode(dense[:1], cfg.local_levels)[0])
    row = np.asarray(tree.leaf_members[cell])
    # cap many members, stable: the lowest original indices win
    np.testing.assert_array_equal(row, np.arange(members_cap))
    # every other row holds no phantom members from the overflow
    members = np.asarray(tree.leaf_members)
    listed = members[members >= 0]
    assert len(listed) == len(set(listed.tolist()))
    overflow_victims = set(range(members_cap, 8))
    assert not (set(listed.tolist()) & overflow_victims)
    # aggregation is unaffected by the cap
    np.testing.assert_allclose(float(tree.counts[0].sum()), 12.0, rtol=1e-6)


# ---------------------------------------------------------------- synapses
def _seq_remove(edges, msg_lid, msg_gid, msg_valid):
    """The seed's sequential drain: each message removes the then-first
    matching slot of its row."""
    e = np.asarray(edges).copy()
    for lid, gid, ok in zip(msg_lid, msg_gid, msg_valid):
        if not ok:
            continue
        hits = np.where(e[int(lid)] == int(gid))[0]
        if len(hits):
            e[int(lid), hits[0]] = -1
    return e


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_remove_edges_by_messages_matches_sequential(seed):
    """The vectorized segment/cumsum removal == the sequential message drain,
    including duplicate messages, repeated edge values, and no-op messages."""
    rng = np.random.default_rng(seed)
    n, s_max, q = 5, 6, 16
    edges = rng.integers(-1, 7, size=(n, s_max)).astype(np.int32)
    lid = rng.integers(0, n, size=q).astype(np.int32)
    gid = rng.integers(-1, 7, size=q).astype(np.int32)
    valid = rng.random(q) < 0.75
    got = np.asarray(synapses.remove_edges_by_messages(
        jnp.asarray(edges), jnp.asarray(lid), jnp.asarray(gid),
        jnp.asarray(valid)))
    np.testing.assert_array_equal(got, _seq_remove(edges, lid, gid, valid))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_compact_is_stable_front_packing(seed):
    rng = np.random.default_rng(seed)
    edges = rng.integers(-1, 9, size=(4, 7)).astype(np.int32)
    got = np.asarray(synapses.compact(jnp.asarray(edges)))
    for i in range(edges.shape[0]):
        occ = edges[i][edges[i] >= 0]
        want = np.concatenate([occ, -np.ones(7 - len(occ), np.int32)])
        np.testing.assert_array_equal(got[i], want)


# ---------------------------------------------------------------- kernel
def _phase_b_inputs(n=96, q=75, local_levels=3, key=0):
    """A local tree + batch of queries with a non-block-multiple Q (so the
    kernel's query padding is exercised)."""
    cfg = BrainConfig(neurons_per_rank=n, local_levels=local_levels,
                      frontier_cap=32, max_synapses=8)
    k = jax.random.key(key)
    pos = jax.random.uniform(jax.random.fold_in(k, 1), (n, 3), maxval=0.999)
    vac = jax.random.uniform(jax.random.fold_in(k, 2), (n,)) * 2
    tree = ctree.build_local_tree(pos, vac, 0, cfg, num_ranks=1)
    x = jax.random.uniform(jax.random.fold_in(k, 3), (q, 3), maxval=0.999)
    gids = jnp.arange(q, dtype=jnp.int32)
    start = jnp.zeros((q,), jnp.int32)
    valid = jnp.arange(q) % 5 != 0         # a few masked queries
    return cfg, tree, pos, vac, x, gids, start, valid


@pytest.mark.parametrize("block_q", [32, 128])
def test_bh_traverse_kernel_bit_identical_to_reference(block_q):
    """The Pallas traversal kernel (interpret) == the jnp phase_b_core, bit
    for bit, across query blockings — the connectivity_impl contract."""
    cfg, tree, pos, vac, x, gids, start, valid = _phase_b_inputs()
    stacked = traverse.stack_levels(tree.counts, tree.centroids, 0)
    kw = dict(seed=cfg.seed, sizes=stacked.sizes, theta=cfg.theta,
              sigma=cfg.sigma, frontier=cfg.frontier_cap,
              n_levels=cfg.local_levels + 1)
    chunk, gid_base = jnp.int32(3), jnp.int32(0)
    want = jax.jit(lambda: traverse.phase_b_core(
        stacked.counts, stacked.centroids, tree.leaf_members, pos, vac, x,
        start, gids, valid, chunk, gid_base, **kw))()
    from repro.kernels.bh_traverse import bh_traverse
    got = jax.jit(lambda: bh_traverse(
        stacked.counts, stacked.centroids, tree.leaf_members, pos, vac, x,
        start, gids, valid, chunk, gid_base, block_q=block_q,
        interpret=True, **kw))()
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    assert int(jnp.sum(got[1])) > 0, "no query found a partner at all"
    # masked queries stay masked
    assert not np.any(np.asarray(got[1])[::5])


def test_bh_traverse_prng_is_location_independent():
    """The Gumbel stream depends only on (seed, chunk, gid, round, draw):
    permuting the query batch permutes the results exactly — the property
    that lets the owning rank re-derive a remote searcher's stream."""
    cfg, tree, pos, vac, x, gids, start, valid = _phase_b_inputs()
    stacked = traverse.stack_levels(tree.counts, tree.centroids, 0)
    kw = dict(seed=cfg.seed, sizes=stacked.sizes, theta=cfg.theta,
              sigma=cfg.sigma, frontier=cfg.frontier_cap,
              n_levels=cfg.local_levels + 1)
    chunk, gid_base = jnp.int32(1), jnp.int32(0)
    perm = jnp.asarray(np.random.default_rng(7).permutation(x.shape[0]))
    a = traverse.phase_b_core(stacked.counts, stacked.centroids,
                              tree.leaf_members, pos, vac, x, start, gids,
                              valid, chunk, gid_base, **kw)
    b = traverse.phase_b_core(stacked.counts, stacked.centroids,
                              tree.leaf_members, pos, vac, x[perm],
                              start[perm], gids[perm], valid[perm], chunk,
                              gid_base, **kw)
    np.testing.assert_array_equal(np.asarray(a[0])[perm], np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1])[perm], np.asarray(b[1]))


def test_connectivity_impl_validation():
    # unknown variant names fail eagerly at config construction
    base = BrainConfig(neurons_per_rank=16, local_levels=2, frontier_cap=32,
                       max_synapses=4)
    with pytest.raises(ValueError, match="connectivity_impl"):
        dataclasses.replace(base, connectivity_impl="bogus")
    with pytest.raises(ValueError, match="tree_impl"):
        dataclasses.replace(base, tree_impl="bogus")
    with pytest.raises(ValueError, match="apply_impl"):
        dataclasses.replace(base, apply_impl="bogus")


# ---------------------------------------------------------------- retract
def _retract_argsort_oracle(key, edges, n_delete, row_gids):
    """The pre-PR full per-row stable argsort over priorities — the oracle
    the masked top-k rank-by-counting must match bit-for-bit."""
    n, s_max = edges.shape
    occupied = edges >= 0
    flat_prio = synapses.edge_priority(
        key, jnp.broadcast_to(row_gids[:, None], edges.shape).reshape(-1),
        jnp.where(occupied, edges, 0).reshape(-1))
    prio = jnp.where(occupied, flat_prio.reshape(edges.shape), 2.0)
    order = jnp.argsort(prio, axis=1, stable=True)
    ranks = jnp.zeros_like(edges).at[
        jnp.arange(n)[:, None], order].set(jnp.arange(s_max)[None, :])
    kill = occupied & (ranks < n_delete[:, None])
    return jnp.where(kill, -1, edges), kill


def _check_retract_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, s_max = 24, 8
    edges = jnp.asarray(rng.integers(-1, 30, (n, s_max)), jnp.int32)
    # n_delete beyond occupancy and zero both occur
    nd = jnp.asarray(rng.integers(0, s_max + 2, n), jnp.int32)
    gids = jnp.asarray(rng.integers(0, 200, n), jnp.int32)
    key = jax.random.key(seed % 2**31)
    got_e, got_k = synapses.retract_synapses(key, edges, nd, gids)
    want_e, want_k = _retract_argsort_oracle(key, edges, nd, gids)
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(want_e))
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_retract_topk_matches_argsort_oracle(seed):
    """The masked top-k-by-priority retraction == the full per-row argsort
    it replaced, bit-for-bit (same Threefry priority stream)."""
    _check_retract_matches_oracle(seed)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_retract_topk_matches_argsort_oracle_random(seed):
    _check_retract_matches_oracle(seed)


# ---------------------------------------------------------------- engine
SMALL = dataclasses.replace(library.SMOKE_SCENARIO_CONFIG,
                            neurons_per_rank=48, max_synapses=8,
                            rate_period=25)


def _scaled(scn: Scenario, div=20) -> Scenario:
    evs = []
    for e in scn.events:
        if isinstance(e, Stimulate):
            evs.append(dataclasses.replace(
                e, t0=e.t0 // div, t1=max(e.t1 // div, e.t0 // div + 10)))
        elif isinstance(e, (Lesion, Recover)):
            evs.append(dataclasses.replace(e, t=e.t // div))
    return dataclasses.replace(scn, events=tuple(evs))


def test_engine_fused_connectivity_equals_reference():
    """connectivity_impl='fused' commits bit-identical edge tables AND
    neuron state through the full jitted sim."""
    mesh = engine.make_brain_mesh()
    res = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(SMALL, connectivity_impl=impl)
        init_fn, chunk = engine.build_sim(cfg, mesh)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[impl] = st
    a, b = res["reference"], res["fused"]
    np.testing.assert_array_equal(np.asarray(a.out_edges),
                                  np.asarray(b.out_edges))
    np.testing.assert_array_equal(np.asarray(a.in_edges),
                                  np.asarray(b.in_edges))
    for f in ("v", "calcium", "ax_elements", "de_elements", "rate"):
        np.testing.assert_array_equal(np.asarray(getattr(a.neurons, f)),
                                      np.asarray(getattr(b.neurons, f)),
                                      err_msg=f)
    assert float(a.stats["synapses_formed"].sum()) > 0
    assert float(a.stats["formation_requests"].sum()) > 0  # tracked on 'new'


def test_engine_fused_tree_apply_equals_reference():
    """tree_impl='fused' + apply_impl='fused' (the radix-sort tree build and
    the VMEM-resident synapse-apply kernels) commit bit-identical edge
    tables AND neuron state through the full jitted sim at a single rank —
    the acceptance contract of the whole-chunk-residency PR. The lesion
    scenario drives BOTH stages of the kernel live (formation and
    deletion)."""
    scn = _scaled(library.get_scenario("lesion_rewiring"))
    mesh = engine.make_brain_mesh()
    res = {}
    for impl in ("reference", "fused"):
        cfg = dataclasses.replace(SMALL, tree_impl=impl, apply_impl=impl)
        init_fn, chunk = engine.build_sim(cfg, mesh, scenario=scn)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[impl] = st
    a, b = res["reference"], res["fused"]
    np.testing.assert_array_equal(np.asarray(a.out_edges),
                                  np.asarray(b.out_edges))
    np.testing.assert_array_equal(np.asarray(a.in_edges),
                                  np.asarray(b.in_edges))
    for f in ("v", "calcium", "ax_elements", "de_elements", "rate"):
        np.testing.assert_array_equal(np.asarray(getattr(a.neurons, f)),
                                      np.asarray(getattr(b.neurons, f)),
                                      err_msg=f)
    assert float(a.stats["synapses_formed"].sum()) > 0
    assert float(a.stats["synapses_deleted"].sum()) > 0


@pytest.mark.parametrize("name", sorted(library.SCENARIOS))
def test_fused_tree_apply_old_new_identical(name):
    """THE paper invariant under the new kernels: with fused tree build and
    fused apply, both connectivity algorithms still commit bit-identical
    edge tables, for every library scenario (lesion protocols exercise the
    big-cap deletion routing path through the route_build kernel)."""
    scn = _scaled(library.get_scenario(name))
    mesh = engine.make_brain_mesh()
    res = {}
    for alg in ("old", "new"):
        cfg = dataclasses.replace(SMALL, tree_impl="fused",
                                  apply_impl="fused", connectivity_alg=alg)
        init_fn, chunk = engine.build_sim(cfg, mesh, scenario=scn)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                    np.sort(np.asarray(st.in_edges), 1),
                    float(st.stats["synapses_formed"].sum()))
    assert res["old"][2] == res["new"][2] > 0
    np.testing.assert_array_equal(res["old"][0], res["new"][0])
    np.testing.assert_array_equal(res["old"][1], res["new"][1])


@pytest.mark.parametrize("name", sorted(library.SCENARIOS))
def test_fused_connectivity_old_new_identical(name):
    """THE paper invariant under the traversal kernel: with
    connectivity_impl='fused' both connectivity algorithms still commit
    bit-identical edge tables, for every library scenario."""
    scn = _scaled(library.get_scenario(name))
    mesh = engine.make_brain_mesh()
    res = {}
    for alg in ("old", "new"):
        cfg = dataclasses.replace(SMALL, connectivity_impl="fused",
                                  connectivity_alg=alg)
        init_fn, chunk = engine.build_sim(cfg, mesh, scenario=scn)
        st = init_fn()
        for _ in range(3):
            st = chunk(st)
        res[alg] = (np.sort(np.asarray(st.out_edges), 1),
                    np.sort(np.asarray(st.in_edges), 1),
                    float(st.stats["synapses_formed"].sum()))
    assert res["old"][2] == res["new"][2] > 0
    np.testing.assert_array_equal(res["old"][0], res["new"][0])
    np.testing.assert_array_equal(res["old"][1], res["new"][1])


# ---------------------------------------------------------------- routing
def test_formation_requests_counted_on_new_path():
    """42B formation-and-calculation requests show up in stats on the new
    algorithm path (they used to be tracked only for 'old')."""
    cfg = dataclasses.replace(SMALL, connectivity_alg="new")
    mesh = engine.make_brain_mesh()
    init_fn, chunk = engine.build_sim(cfg, mesh)
    st = init_fn()
    for _ in range(3):
        st = chunk(st)
    fr = float(st.stats["formation_requests"].sum())
    bh = float(st.stats["bh_requests"].sum())
    assert fr == bh > 0
