"""End-to-end behaviour tests for the paper's system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.msp_brain import BrainConfig
from repro.core import engine


def test_training_loss_decreases_end_to_end(tmp_path):
    """Tiny LM + synthetic Markov data: CE drops well below ln(V) (the data
    pipeline is learnable, the optimizer works, the runner checkpoints)."""
    from repro.launch.train import build_everything
    from repro.launch.mesh import make_mesh
    from repro.configs import get_smoke_config
    from repro.runtime.fault_tolerance import RunnerConfig, TrainingRunner

    cfg = get_smoke_config("qwen2-7b")
    mesh = make_mesh((1, 1), ("data", "model"))
    # steps=80 sizes the LR warmup to the run (8 steps, not the default 100)
    api, params, opt, step, data = build_everything(cfg, mesh, 8, 64,
                                                    steps=80)
    runner = TrainingRunner(RunnerConfig(ckpt_dir=str(tmp_path),
                                         ckpt_every=100),
                            step, params, opt, data)
    runner.run(80)
    data.close()
    first = np.mean(runner.history[:5])
    last = np.mean(runner.history[-5:])
    assert last < first - 0.15, (first, last)


def test_brain_simulation_paper_loop():
    """MSP loop: calcium approaches target, synapse count rises, both spike
    algorithms run (single rank)."""
    cfg = BrainConfig(neurons_per_rank=32, local_levels=3, frontier_cap=32,
                      max_synapses=24, fraction_excitatory=1.0)
    mesh = engine.make_brain_mesh()
    init_fn, chunk = engine.build_sim(cfg, mesh)
    st = init_fn()
    cals, syns = [], []
    for i in range(25):
        st = chunk(st)
        cals.append(float(st.neurons.calcium.mean()))
        syns.append(int((st.in_edges >= 0).sum()))
    assert cals[-1] > cals[0]
    assert syns[-1] > syns[0]
    assert syns[-1] >= 32  # at least ~1 synapse per neuron by 2.5k steps


def test_brain_old_spike_alg_single_rank():
    cfg = BrainConfig(neurons_per_rank=32, local_levels=3, frontier_cap=32,
                      max_synapses=16, spike_alg="old",
                      fraction_excitatory=1.0)
    mesh = engine.make_brain_mesh()
    init_fn, chunk = engine.build_sim(cfg, mesh)
    st = init_fn()
    for _ in range(3):
        st = chunk(st)
    assert float(st.stats["spikes_sent"].sum()) > 0
    assert bool(jnp.all(jnp.isfinite(st.neurons.calcium)))


def test_serve_generates_tokens():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-14b")
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                          cfg.vocab_size)}
    logits, state = api.prefill(params, batch, pad_cache_to=16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    for _ in range(6):
        logits, state = api.decode_step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    gen = jnp.stack(outs, 1)
    assert gen.shape == (2, 7)
    assert int(gen.min()) >= 0 and int(gen.max()) < cfg.vocab_size
