"""HLO roofline parser unit tests + a real tiny compile."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import roofline as rl

SYNTH = """\
HloModule test

%cond.1 (arg.0: s32[]) -> pred[] {
  %arg.0 = s32[] parameter(0)
  %constant.5 = s32[] constant(12)
  ROOT %lt = pred[] compare(%arg.0, %constant.5), direction=LT
}

%body.1 (arg.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg.1 = (s32[], f32[8,16]) parameter(0)
  %w = f32[16,16]{1,0} constant({...})
  %x = f32[8,16]{1,0} get-tuple-element(%arg.1), index=1
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum.1
  ROOT %t = (s32[], f32[8,16]) tuple(%x, %ar)
}

%sum.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %wl = (s32[], f32[8,16]) while(%tup), condition=%cond.1, body=%body.1
  %big = f32[32,64]{1,0} all-gather(%p0), replica_groups=[4,2]<=[8], dimensions={0}
  ROOT %r = f32[8,16]{1,0} get-tuple-element(%wl), index=1
}
"""


def test_shape_bytes():
    assert rl.shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert rl.shape_bytes("bf16[4,4]") == 32
    assert rl.shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert rl.shape_bytes("pred[7]") == 7


def test_synthetic_module_trip_counts_and_collectives():
    ana = rl.analyze_hlo(SYNTH, num_devices=8)
    # dot inside while body: 2*8*16*16 flops x 12 trips
    assert ana["dot_flops"] == 2 * 8 * 16 * 16 * 12
    # all-reduce in body: 8*16*4 bytes x 12 x wire factor 2*(4-1)/4
    ar = ana["collective_wire_bytes"]["all-reduce"]
    assert ar == pytest.approx(8 * 16 * 4 * 12 * 2 * 3 / 4)
    # all-gather at entry: group size 2 from [4,2] v2 format
    ag = ana["collective_wire_bytes"]["all-gather"]
    assert ag == pytest.approx(32 * 64 * 4 * (2 - 1) / 2)


def test_real_compile_collectives_nonzero():
    """Compile a tiny sharded matmul on 1 device and parse its HLO."""
    x = jnp.ones((8, 8))

    def f(a):
        y = a @ a
        return jax.lax.scan(lambda c, _: (c @ a, None), y, None, length=5)[0]

    hlo = jax.jit(f).lower(x).compile().as_text()
    ana = rl.analyze_hlo(hlo, num_devices=1)
    # scan body dot must be multiplied by 5 (+1 for the outer matmul)
    assert ana["dot_flops"] >= 2 * 8 * 8 * 8 * 6


def test_roofline_terms_dominance():
    t = rl.roofline_terms(1e15, 1e9, 1e9)     # compute-bound
    assert t["dominant"] == "compute" and t["roofline_fraction"] == 1.0
    t = rl.roofline_terms(1e12, 1e9, 1e12)    # collective-bound
    assert t["dominant"] == "collective"
    assert t["roofline_fraction"] < 1.0
