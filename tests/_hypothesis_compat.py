"""Optional-hypothesis shim.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis imports when the package is installed. When it is not,
``@given``-decorated property tests turn into clean pytest skips while every
plain test in the same module still collects and runs (a module-level
``pytest.importorskip`` would throw those away too).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(f):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _Strategies:
        """Attribute access yields inert strategy factories (never drawn)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
