# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses (tests/test_multidevice.py).
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def make_batch(cfg, batch=2, seq=16, seed=1):
    """Family-correct random batch for a smoke config."""
    import jax
    import jax.numpy as jnp
    key = jax.random.key(seed)
    out = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                        cfg.vocab_size)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.num_patches, cfg.d_model)).astype(dt)
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            key, (batch, cfg.encoder_seq, cfg.d_model)).astype(dt)
    return out


@pytest.fixture
def rng():
    import jax
    return jax.random.key(0)
