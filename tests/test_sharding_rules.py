"""Pure-logic tests for the sharding rules and the MoE cost model (no
compiles; hypothesis sweeps)."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.moe import choose_strategy, moe_strategy_cost
from repro.parallel import sharding as shd


def _mesh(shape=(4, 2), axes=("data", "model")):
    # abstract mesh is enough for spec logic on 1 device? use real devices
    import jax
    from jax.sharding import Mesh
    devs = np.array(jax.devices() * (int(np.prod(shape))))[
        : int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_divisibility_guard_drops_axes():
    mesh = _mesh((4, 2))
    # vocab 51865 doesn't divide 2 -> 'model' dropped on dim0
    spec = shd.infer_param_spec(
        (jax.tree_util.DictKey("embed"), jax.tree_util.DictKey("table")),
        (51865, 512), mesh)
    assert spec[0] is None
    # divisible case keeps the axes
    spec = shd.infer_param_spec(
        (jax.tree_util.DictKey("embed"), jax.tree_util.DictKey("table")),
        (51200, 512), mesh)
    assert spec[0] == "model"


def test_expert_rule_keeps_ep_in_both_layouts():
    mesh = _mesh((4, 2))
    path = (jax.tree_util.DictKey("layers_stacked"),
            jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("w_up"))
    for layout in ("tp", "fsdp"):
        spec = shd.infer_param_spec(path, (8, 16, 2048, 1408), mesh,
                                    layout=layout)
        assert spec[1] == "model", (layout, spec)


def test_fsdp_layout_row_shards_everything():
    mesh = _mesh((4, 2))
    path = (jax.tree_util.DictKey("layers_stacked"),
            jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq"))
    spec = shd.infer_param_spec(path, (8, 4096, 4096), mesh, layout="fsdp")
    assert spec == P(None, ("data", "model"), None)
    spec_tp = shd.infer_param_spec(path, (8, 4096, 4096), mesh, layout="tp")
    assert spec_tp == P(None, "data", "model")


def test_small_leaves_replicated():
    mesh = _mesh((4, 2))
    spec = shd.infer_param_spec(
        (jax.tree_util.DictKey("final_norm"), jax.tree_util.DictKey("scale")),
        (4096,), mesh)
    assert spec == P()


@settings(max_examples=30, deadline=None)
@given(st.integers(64, 65536), st.sampled_from([4, 8, 16, 32]))
def test_moe_auto_strategy_is_min_cost(t_local, model_size):
    cfg = get_config("moonshot-v1-16b-a3b")
    c = moe_strategy_cost(cfg, t_local, model_size)
    pick = choose_strategy(cfg, t_local, model_size)
    assert c[pick] == min(c.values())


def test_moe_cost_crossover_matches_napkin_math():
    """Small per-device token counts favor move_compute (tokens are light);
    huge ones favor move_data (weights become lighter than tokens)."""
    cfg = get_config("moonshot-v1-16b-a3b")
    assert choose_strategy(cfg, 1024, 16) == "move_compute"
    assert choose_strategy(cfg, 1_000_000, 16) == "move_data"
    # arctic's experts are enormous: move_data practically never wins
    arctic = get_config("arctic-480b")
    assert choose_strategy(arctic, 65536, 16) == "move_compute"


def test_constrain_outside_mesh_is_noop():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.constrain(x, ("batch", None))
    assert y is x
