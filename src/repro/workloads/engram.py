"""Engram formation / pattern-completion workload (DESIGN.md §13).

Follows the structural-plasticity learning protocol of Tiddia et al.
(arXiv:2307.11735) on the MSP engine: a *pattern* region is stimulated
while the connectome grows, so the homeostatic rule wires the co-active
ensemble together (the engram); after a rest period the *cue* subregion
of the pattern is lesioned (its synapses retract, partners are
notified), and recall is probed with a weaker stimulus on the pattern.
The quality observable is **recall overlap** — the fraction of surviving
pattern neurons (pattern minus cue) whose window-averaged rate clears a
threshold during the probe — next to the *selectivity* margin over the
unstimulated rest of the sheet.

Everything is a plain protocol (``Stimulate``/``Lesion`` events compiled
trace-stably), so the workload runs bit-identically across dense/sparse
rate exchange and reference/fused activity lowerings — which is exactly
what tests/test_workloads.py asserts; the value itself is gated against
the committed baseline by benchmarks/check_regression.py (the
``workloads`` family).

Run ``python -m repro.workloads.engram --smoke`` for the CI smoke.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.msp_brain import SMOKE_CONFIG, BrainConfig
from repro.scenarios.protocol import Lesion, Scenario, Stimulate
from repro.scenarios.regions import Region, region_mask
from repro.sim.api import Simulator


@dataclasses.dataclass(frozen=True)
class EngramSpec:
    """The engram protocol. ``cue`` must be a subregion of ``pattern``
    (the lesioned fraction of the ensemble); times are in chunks of
    ``cfg.rate_period`` steps."""
    pattern: Region = Region("pattern", lo=(0.0, 0.0, 0.0),
                             hi=(0.5, 1.0, 1.0))
    cue: Region = Region("cue", lo=(0.0, 0.0, 0.0), hi=(0.25, 1.0, 1.0))
    train_chunks: int = 6
    rest_chunks: int = 2
    recall_chunks: int = 4
    train_amplitude: float = 4.0    # training drive (cf. focal_stimulation)
    recall_amplitude: float = 2.0   # weaker recall probe
    rate_threshold: float = 0.02    # "active" rate (per-step; bg ~0.01)

    @property
    def total_chunks(self) -> int:
        return self.train_chunks + self.rest_chunks + self.recall_chunks


def scenario(spec: EngramSpec, rate_period: int) -> Scenario:
    """Compile the spec into a protocol Scenario. The cue region rides
    along for the lesion mask; the recall probe stimulates the whole
    pattern, but lesioned cue neurons are dead and cannot respond — only
    the surviving ensemble (pattern minus cue) can complete it."""
    t_train = spec.train_chunks * rate_period
    t_recall = (spec.train_chunks + spec.rest_chunks) * rate_period
    t_end = spec.total_chunks * rate_period
    return Scenario(
        name="engram",
        regions=(spec.pattern, spec.cue),
        events=(
            Stimulate(spec.pattern.name, spec.train_amplitude, 0, t_train),
            Lesion(spec.cue.name, t_recall),
            Stimulate(spec.pattern.name, spec.recall_amplitude, t_recall,
                      t_end),
        ),
        num_chunks=spec.total_chunks)


def recall_metrics(state, spec: EngramSpec) -> dict:
    """Device-side quality readout on the final global state (one
    transfer of four scalars). ``recall_overlap`` = fraction of target
    neurons (pattern minus the lesioned cue) active at the end of the
    recall probe; ``background_activation`` the same fraction outside
    the pattern; ``engram_selectivity`` their margin."""
    pat = region_mask(state.positions, spec.pattern)
    cue = region_mask(state.positions, spec.cue)
    target = pat & ~cue
    outside = ~pat
    active = state.neurons.rate >= spec.rate_threshold
    n_t = jnp.maximum(target.sum(), 1)
    n_o = jnp.maximum(outside.sum(), 1)
    overlap = (active & target).sum() / n_t
    background = (active & outside).sum() / n_o
    vals = jax.device_get((overlap, background, target.sum(), cue.sum()))
    out = {"recall_overlap": float(vals[0]),
           "background_activation": float(vals[1]),
           "engram_selectivity": float(vals[0]) - float(vals[1]),
           "target_neurons": float(vals[2]),
           "cue_neurons": float(vals[3])}
    return out


def run_engram(cfg: Optional[BrainConfig] = None,
               spec: EngramSpec = EngramSpec(), dataset=None,
               mesh=None) -> dict:
    """Run the full protocol and return the quality metrics plus the
    simulator (for stats/telemetry readout) as ``(metrics, sim)``.

    With ``dataset`` the sheet starts from the loaded connectome
    (``Simulator.from_connectome``) instead of growing from empty — the
    engram then forms by *rewiring* a realistic heavy-tailed connectome
    rather than by growth alone."""
    cfg = cfg or dataclasses.replace(SMOKE_CONFIG, requests_cap_factor=1000)
    scn = scenario(spec, cfg.rate_period)
    if dataset is not None:
        sim = Simulator.from_connectome(cfg, dataset, scenario=scn,
                                        mesh=mesh)
    else:
        sim = Simulator.from_config(cfg, scenario=scn, mesh=mesh)
    sim.run(spec.total_chunks)
    return recall_metrics(sim.state, spec), sim


def main(argv=None) -> dict:
    import argparse
    p = argparse.ArgumentParser(description="engram workload")
    p.add_argument("--smoke", action="store_true",
                   help="smoke scale (64 neurons/rank)")
    p.add_argument("--sparse", action="store_true",
                   help="sparse rate exchange")
    p.add_argument("--connectome", action="store_true",
                   help="start from a generated surrogate connectome")
    args = p.parse_args(argv)
    cfg = dataclasses.replace(
        SMOKE_CONFIG, requests_cap_factor=1000,
        rate_exchange="sparse" if args.sparse else "dense")
    if not args.smoke:
        cfg = dataclasses.replace(cfg, neurons_per_rank=256)
    dataset = None
    if args.connectome:
        from repro.workloads import datasets as wds
        num_ranks = len(jax.devices())
        dataset = wds.generate_hemibrain_surrogate(
            num_ranks * cfg.neurons_per_rank, cfg.neurons_per_rank,
            max_degree=cfg.max_synapses,
            fraction_excitatory=cfg.fraction_excitatory)
    metrics, sim = run_engram(cfg, dataset=dataset)
    metrics["chunks"] = float(EngramSpec().total_chunks)
    metrics["synapses_formed"] = sim.stats()["synapses_formed"]
    print(json.dumps(metrics, indent=2, sort_keys=True))
    return metrics


if __name__ == "__main__":
    main()
