"""repro.workloads — realistic workloads for the MSP brain (DESIGN.md §13).

Everything the scenario library runs is synthetic; this package supplies
the shapes the paper's machinery exists to serve:

  datasets.py    versioned on-disk connectome format (npz: positions, typed
                 edge list, region labels, per-neuron excitation) + the
                 deterministic hemibrain-shaped surrogate generator
                 (log-normal degrees, spatially clustered regions) and the
                 edge-list -> (n, S) synapse-table builder behind
                 ``Simulator.from_connectome``
  engram.py      train-with-stimulus / lesion-the-cue pattern-completion
                 workload (Tiddia et al., arXiv:2307.11735) reporting
                 ``recall_overlap`` as a device-side quality observable
  assimilate.py  host-driven rate-assimilation loop nudging per-region
                 drive toward a target trace between chunks, through the
                 retrace-free ``DynamicParams`` pytree (the first slice of
                 the static/dynamic config split — ROADMAP item 5)

Import is lazy (the modules pull in the full engine stack).
"""
from __future__ import annotations

_LAZY = {
    "ConnectomeDataset": ("repro.workloads.datasets", "ConnectomeDataset"),
    "generate_hemibrain_surrogate": (
        "repro.workloads.datasets", "generate_hemibrain_surrogate"),
    "save": ("repro.workloads.datasets", "save"),
    "load": ("repro.workloads.datasets", "load"),
    "EngramSpec": ("repro.workloads.engram", "EngramSpec"),
    "run_engram": ("repro.workloads.engram", "run_engram"),
    "AssimilationLoop": ("repro.workloads.assimilate", "AssimilationLoop"),
}

__all__ = sorted(_LAZY) + ["assimilate", "datasets", "engram"]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro.workloads' has no attribute {name!r}")
