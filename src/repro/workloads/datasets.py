"""Connectome datasets: the versioned on-disk format, the deterministic
hemibrain-shaped surrogate generator, and the edge-list -> synapse-table
builder behind ``Simulator.from_connectome`` (DESIGN.md §13).

On-disk format (``repro.connectome/v1``, one compressed npz)::

    format_version  ()        int   — 1
    name            ()        str
    positions       (N, 3)    f32   — unit cube [0, 1)^3, row == gid
    edges           (E, 2)    i32   — (pre_gid, post_gid), sorted by
                                      (pre, post); multi-edges allowed,
                                      self-loops not
    edge_types      (E,)      i32   — 0 excitatory / 1 inhibitory (the
                                      pre-neuron's sign)
    region_ids      (N,)      i32   — region label per neuron
    region_names    (nr,)     str
    region_boxes    (nr, 2, 3) f32  — axis-aligned [lo, hi) per region
    is_excitatory   (N,)      bool

The canonical invariant is **gid == global row**: rank ``r`` of an
``R``-rank simulation with ``n = N / R`` neurons per rank owns rows
``[r*n, (r+1)*n)``. The generator emits rows in Morton order so those
blocks are spatially coherent (the octree build tolerates — clips — the
stragglers near block boundaries), and assigns excitation periodically
within each ``block`` of rows (first ``int(block * fraction_excitatory)``
rows excitatory) so the dataset matches the population table every rank
derives from ``(cfg, scenario, n)`` — the replicated-derivation invariant
that lets any rank look up a synapse weight from ``gid % n``
(``check_population_layout`` enforces this at load time).

The surrogate is hemibrain *shaped*, not hemibrain data: log-normal
out-degrees (heavy tail), spatially clustered regions of uneven size
(Dirichlet weights over the Morton octants), distance-biased targets
(``p_local`` of each neuron's synapses stay in-region). Scaled up it
reaches the Drosophila-hemibrain envelope simulated on Loihi 2
(arXiv:2508.16792): ``generate_hemibrain_surrogate(139_264, block=...,
avg_degree=390)`` ≈ 139k neurons / 54M synapses — while CI runs the same
generator at smoke scale with no download.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import numpy as np

FORMAT_VERSION = 1
FORMAT = "repro.connectome/v1"

_MORTON_LEVEL = 9   # canonical-order resolution (matches core.morton cap)


class ConnectomeDataset(NamedTuple):
    """An immutable host-side connectome (see module docstring for the
    field contracts). All arrays are plain numpy."""
    name: str
    positions: np.ndarray       # (N, 3) f32
    edges: np.ndarray           # (E, 2) i32 (pre, post)
    edge_types: np.ndarray      # (E,) i32
    region_ids: np.ndarray      # (N,) i32
    region_names: Tuple[str, ...]
    region_boxes: np.ndarray    # (nr, 2, 3) f32
    is_excitatory: np.ndarray   # (N,) bool

    @property
    def num_neurons(self) -> int:
        return int(self.positions.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.edges[:, 0], minlength=self.num_neurons)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.edges[:, 1], minlength=self.num_neurons)

    def regions(self):
        """The dataset's regions as scenario ``Region`` boxes, usable
        directly in protocols / ``assign_regions`` (same geometry the
        region_ids were assigned from)."""
        from repro.scenarios.regions import Region
        return tuple(
            Region(name, lo=tuple(float(x) for x in box[0]),
                   hi=tuple(float(x) for x in box[1]))
            for name, box in zip(self.region_names, self.region_boxes))


# ================================================================ save/load
def save(path: str, ds: ConnectomeDataset) -> None:
    """Write ``ds`` to one compressed npz (format-versioned)."""
    validate(ds)
    np.savez_compressed(
        path, format_version=np.int64(FORMAT_VERSION), name=str(ds.name),
        positions=ds.positions.astype(np.float32),
        edges=ds.edges.astype(np.int32),
        edge_types=ds.edge_types.astype(np.int32),
        region_ids=ds.region_ids.astype(np.int32),
        region_names=np.asarray(ds.region_names),
        region_boxes=ds.region_boxes.astype(np.float32),
        is_excitatory=ds.is_excitatory.astype(bool))


def load(path: str) -> ConnectomeDataset:
    """Read and validate a ``repro.connectome/v1`` npz."""
    with np.load(path, allow_pickle=False) as z:
        version = int(z["format_version"])
        if version != FORMAT_VERSION:
            raise ValueError(
                f"{path}: connectome format_version {version}, "
                f"this build reads {FORMAT_VERSION} ({FORMAT})")
        ds = ConnectomeDataset(
            name=str(z["name"]),
            positions=np.asarray(z["positions"], np.float32),
            edges=np.asarray(z["edges"], np.int32),
            edge_types=np.asarray(z["edge_types"], np.int32),
            region_ids=np.asarray(z["region_ids"], np.int32),
            region_names=tuple(str(s) for s in z["region_names"]),
            region_boxes=np.asarray(z["region_boxes"], np.float32),
            is_excitatory=np.asarray(z["is_excitatory"], bool))
    validate(ds)
    return ds


def validate(ds: ConnectomeDataset) -> None:
    """Structural invariants every dataset must hold (gid == row, positions
    in the unit cube, edges in range, no self-loops, typed by source)."""
    N, E = ds.num_neurons, ds.num_edges
    if ds.positions.shape != (N, 3):
        raise ValueError(f"positions shape {ds.positions.shape} != ({N}, 3)")
    if not (np.isfinite(ds.positions).all() and ds.positions.min() >= 0.0
            and ds.positions.max() < 1.0):
        raise ValueError("positions must be finite and inside [0, 1)^3")
    if ds.edges.shape != (E, 2) or ds.edge_types.shape != (E,):
        raise ValueError("edges must be (E, 2) with (E,) edge_types")
    if E and (ds.edges.min() < 0 or ds.edges.max() >= N):
        raise ValueError("edge gids out of range [0, N)")
    if E and (ds.edges[:, 0] == ds.edges[:, 1]).any():
        raise ValueError("self-loop edges are not allowed")
    if ds.region_ids.shape != (N,):
        raise ValueError("region_ids must be (N,)")
    nr = len(ds.region_names)
    if ds.region_boxes.shape != (nr, 2, 3):
        raise ValueError("region_boxes must be (len(region_names), 2, 3)")
    if nr and ds.region_ids.size and \
            (ds.region_ids.min() < 0 or ds.region_ids.max() >= nr):
        raise ValueError("region_ids out of range")
    if ds.is_excitatory.shape != (N,):
        raise ValueError("is_excitatory must be (N,)")
    if E and not np.array_equal(
            ds.edge_types, (~ds.is_excitatory[ds.edges[:, 0]]).astype(
                np.int32)):
        raise ValueError("edge_types must be the pre-neuron's sign "
                         "(0 excitatory / 1 inhibitory)")


def check_population_layout(ds: ConnectomeDataset, cfg, scenario,
                            num_ranks: int) -> None:
    """The weight-sign replicated-derivation invariant: every rank derives
    one (n,) population table from (cfg, scenario) and reads any neuron's
    synapse weight at ``gid % n`` — so the dataset's per-neuron excitation
    must equal that table on EVERY rank block. (Arbitrary per-neuron signs
    need a global (N,) weight table threaded through both activity
    lowerings — noted as future work in DESIGN.md §13.)"""
    from repro.scenarios import populations as pops
    n = cfg.neurons_per_rank
    table = np.asarray(pops.table_for(cfg, scenario, n).is_excitatory)
    got = ds.is_excitatory.reshape(num_ranks, n)
    bad = np.nonzero((got != table[None, :]).any(axis=1))[0]
    if bad.size:
        raise ValueError(
            f"dataset excitation layout does not match the population table "
            f"on rank block(s) {bad.tolist()[:4]}: each block of "
            f"{n} rows must put its excitatory neurons exactly where the "
            f"(cfg, scenario) population table does (generator: pass "
            f"block={n} and matching fraction_excitatory)")


# ================================================================ morton
def _np_part1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & np.uint32(0x3FF)
    x = (x | (x << 16)) & np.uint32(0x030000FF)
    x = (x | (x << 8)) & np.uint32(0x0300F00F)
    x = (x | (x << 4)) & np.uint32(0x030C30C3)
    x = (x | (x << 2)) & np.uint32(0x09249249)
    return x


def _np_compact1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & np.uint32(0x09249249)
    x = (x ^ (x >> 2)) & np.uint32(0x030C30C3)
    x = (x ^ (x >> 4)) & np.uint32(0x0300F00F)
    x = (x ^ (x >> 8)) & np.uint32(0x030000FF)
    x = (x ^ (x >> 16)) & np.uint32(0x000003FF)
    return x


def morton_codes(pos: np.ndarray, level: int) -> np.ndarray:
    """Host-side Morton codes, bit-compatible with ``core.morton``."""
    g = 1 << level
    ijk = np.clip((pos * g).astype(np.int64), 0, g - 1).astype(np.uint32)
    code = (_np_part1by2(ijk[:, 0]) | (_np_part1by2(ijk[:, 1]) << 1)
            | (_np_part1by2(ijk[:, 2]) << 2))
    return code.astype(np.int64)


def _cell_boxes(level: int) -> np.ndarray:
    """(8^level, 2, 3) [lo, hi) box per Morton cell at ``level``."""
    cells = np.arange(8 ** level, dtype=np.uint32)
    ijk = np.stack([_np_compact1by2(cells), _np_compact1by2(cells >> 1),
                    _np_compact1by2(cells >> 2)], axis=-1)
    size = 1.0 / (1 << level)
    lo = ijk.astype(np.float32) * size
    return np.stack([lo, lo + np.float32(size)], axis=1)


# ================================================================ generator
def generate_hemibrain_surrogate(
        num_neurons: int, block: int, *, avg_degree: float = 8.0,
        max_degree: int = 16, seed: int = 0,
        fraction_excitatory: float = 0.8, region_level: int = 1,
        degree_sigma: float = 1.0, p_local: float = 0.7,
        cluster: float = 0.35, name: str = "hemibrain-surrogate",
) -> ConnectomeDataset:
    """Deterministic hemibrain-shaped surrogate (see module docstring).

    ``block`` must match the intended ``cfg.neurons_per_rank`` (excitation
    is laid out periodically per block) and ``fraction_excitatory`` the
    intended config's. ``max_degree`` caps BOTH out- and in-degree — set it
    to the intended ``cfg.max_synapses`` so the edge tables fit. Regions
    are the ``8^region_level`` Morton cells of the unit cube with Dirichlet
    -weighted (uneven) neuron counts; neurons cluster around their region
    center (``cluster`` in cell-size units). Same arguments -> bit-equal
    dataset, on any machine (single fixed PCG64 stream).
    """
    if num_neurons % block:
        raise ValueError(f"num_neurons={num_neurons} not a multiple of "
                         f"block={block}")
    if max_degree < 1:
        raise ValueError("max_degree must be >= 1")
    rng = np.random.default_rng(seed)
    N = num_neurons
    nr = 8 ** region_level
    boxes = _cell_boxes(region_level)
    names = tuple(f"m{region_level}c{i:02d}" for i in range(nr))

    # --- spatially clustered regions of uneven size -------------------
    weights = rng.dirichlet(np.full(nr, 1.5))
    region_of = rng.choice(nr, size=N, p=weights).astype(np.int32)
    size = 1.0 / (1 << region_level)
    center = (boxes[region_of, 0] + boxes[region_of, 1]) * 0.5
    off = np.clip(rng.normal(0.0, cluster * size, (N, 3)),
                  -0.5 * size + 1e-6, 0.5 * size - 1e-6)
    pos = np.clip(center + off, 0.0, 1.0 - 1e-6).astype(np.float32)

    # --- canonical order: global Morton sort (gid == row, rank blocks
    # spatially coherent; region cells are Morton-aligned, so each
    # region's rows come out contiguous) ------------------------------
    order = np.argsort(morton_codes(pos, _MORTON_LEVEL), kind="stable")
    pos, region_of = pos[order], region_of[order]
    is_exc = (np.arange(N) % block) < int(block * fraction_excitatory)

    # --- log-normal out-degrees (heavy tail), distance-biased targets -
    mu = math.log(max(avg_degree, 1e-6)) - 0.5 * degree_sigma ** 2
    deg = np.clip(np.rint(rng.lognormal(mu, degree_sigma, N)),
                  0, max_degree).astype(np.int64)
    src = np.repeat(np.arange(N, dtype=np.int64), deg)
    start = np.searchsorted(region_of, np.arange(nr))
    count = np.bincount(region_of, minlength=nr)
    rsrc = region_of[src]
    local = (rng.random(src.size) < p_local) & (count[rsrc] > 1)
    tgt_local = start[rsrc] + rng.integers(
        0, np.maximum(count[rsrc], 1), size=src.size)
    tgt_global = rng.integers(0, N, size=src.size)
    tgt = np.where(local, tgt_local, tgt_global)
    keep = tgt != src                                    # no self-loops
    src, tgt = src[keep], tgt[keep]

    # --- deterministic in-degree cap: keep each target's first
    # ``max_degree`` in-edges in (pre, post) order --------------------
    order = np.lexsort((tgt, src))
    src, tgt = src[order], tgt[order]
    o2 = np.argsort(tgt, kind="stable")
    rank_in_tgt = np.arange(tgt.size) - np.searchsorted(tgt[o2], tgt[o2])
    keep = np.zeros(tgt.size, bool)
    keep[o2] = rank_in_tgt < max_degree
    src, tgt = src[keep], tgt[keep]

    edges = np.stack([src, tgt], axis=1).astype(np.int32)
    ds = ConnectomeDataset(
        name=name, positions=pos, edges=edges,
        edge_types=(~is_exc[src]).astype(np.int32),
        region_ids=region_of.astype(np.int32), region_names=names,
        region_boxes=boxes.astype(np.float32),
        is_excitatory=is_exc)
    validate(ds)
    return ds


# ================================================================ tables
def edge_tables(ds: ConnectomeDataset, s_max: int):
    """Global front-packed synapse tables from the edge list: ``(out_edges
    (N, s_max) target gids, in_edges (N, s_max) source gids)``, -1 empty.
    Rows are compacted (occupied slots first) — the layout every table op
    (``accept_requests`` in particular) assumes — and slot order is the
    canonical (pre, post) edge order, so save -> load -> rebuild is
    bit-stable. Raises if any degree exceeds ``s_max``."""
    N = ds.num_neurons
    src, tgt = ds.edges[:, 0].astype(np.int64), ds.edges[:, 1].astype(
        np.int64)
    order = np.lexsort((tgt, src))
    src, tgt = src[order], tgt[order]
    for what, deg in (("out", np.bincount(src, minlength=N)),
                      ("in", np.bincount(tgt, minlength=N))):
        mx = int(deg.max()) if deg.size else 0
        if mx > s_max:
            raise ValueError(
                f"dataset {ds.name!r}: max {what}-degree {mx} exceeds "
                f"max_synapses={s_max} — raise cfg.max_synapses or "
                f"regenerate with max_degree<={s_max}")
    out_edges = np.full((N, s_max), -1, np.int32)
    slot = np.arange(src.size) - np.searchsorted(src, src)
    out_edges[src, slot] = tgt
    o2 = np.argsort(tgt, kind="stable")
    s2, t2 = src[o2], tgt[o2]
    in_edges = np.full((N, s_max), -1, np.int32)
    slot2 = np.arange(t2.size) - np.searchsorted(t2, t2)
    in_edges[t2, slot2] = s2
    return out_edges, in_edges


def max_unique_remote_sources(ds: ConnectomeDataset, n: int) -> int:
    """max over ranks of |unique remote source gids in the rank's in-edge
    table| — the measured quantity ``cap_subs`` sizes the subscription
    registry from (``cfg.subs_cap_base``; satellite of DESIGN.md §13)."""
    src, tgt = ds.edges[:, 0].astype(np.int64), ds.edges[:, 1].astype(
        np.int64)
    post_rank = tgt // n
    remote = post_rank != (src // n)
    if not remote.any():
        return 0
    pairs = np.unique(np.stack([post_rank[remote], src[remote]], 1), axis=0)
    counts = np.bincount(pairs[:, 0], minlength=ds.num_neurons // n)
    return int(counts.max())
