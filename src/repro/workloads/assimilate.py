"""Rate assimilation: steer per-region activity onto a target trace
(DESIGN.md §13).

A host-driven closed loop around ``Simulator.step_with``: after every
chunk the host reads the per-region mean rate (one small transfer),
updates an integral controller, and feeds the corrected per-region drive
offsets back in through the ``phases.DynamicParams`` pytree — a TRACED
argument with replicated leaves, so the whole experiment compiles
exactly once (``AssimilationResult.compile_count`` asserts it). This is
the first concrete slice of the static/dynamic config split (ROADMAP
item 5): the drive *levels* are dynamic, everything else — shapes,
phase selection, protocol — stays baked into the trace.

Targets are a ``(T, nb)`` array over the scenario's region buckets
(``assign_regions`` order, trailing 'rest' bucket); ``NaN`` marks a
bucket the controller leaves alone (drive 0). Chaos hooks (e.g.
``runtime.chaos.drop_region_input``) fire before every chunk and may
call ``loop.drop(region, chunks)`` to zero a region's external drive —
the controller must then wind the drive back up, which
tests/test_workloads.py asserts.

Run ``python -m repro.workloads.assimilate --smoke`` for the CI smoke.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro import telemetry
from repro.configs.msp_brain import SMOKE_CONFIG, BrainConfig
from repro.scenarios.protocol import Scenario
from repro.scenarios.regions import Region, assign_regions, num_buckets
from repro.sim import phases as sim_phases
from repro.sim.api import Simulator


@dataclasses.dataclass
class AssimilationResult:
    target: np.ndarray        # (T, nb) the requested trace (NaN = free)
    measured: np.ndarray      # (T, nb) per-bucket mean rate after each chunk
    drive: np.ndarray         # (T, nb) the offsets that produced row t
    abs_err: np.ndarray       # (T,) mean |target - measured| over controlled
    compile_count: int        # must be 1: retrace-free dynamic params

    @property
    def final_abs_err(self) -> float:
        return float(self.abs_err[-1])


class AssimilationLoop:
    """Integral controller nudging each controlled region bucket's mean
    rate toward ``target[t]`` chunk by chunk.

    ``gain`` is in drive-units per rate-unit (background drive is ~5.0,
    rates ~0.01/step); ``clip`` bounds the accumulated offset so a dead
    region cannot wind the integrator up without bound."""

    def __init__(self, sim: Simulator, target, gain: float = 120.0,
                 clip: float = 4.0, hooks: Sequence = ()):
        if sim.scenario is None or not sim.scenario.regions:
            raise ValueError("AssimilationLoop needs a scenario with "
                             "named regions (the control buckets)")
        self.sim = sim
        self.regions = sim.scenario.regions
        self.nb = num_buckets(self.regions)
        self.target = np.asarray(target, np.float32)
        if self.target.ndim != 2 or self.target.shape[1] != self.nb:
            raise ValueError(
                f"target must be (chunks, {self.nb}) — one column per "
                f"region bucket incl. the trailing 'rest'; got "
                f"{self.target.shape}")
        self.gain = float(gain)
        self.clip = float(clip)
        self.hooks = list(hooks)
        self.chunk_index = 0
        self._drive = np.zeros((self.nb,), np.float32)
        self._drop_left = np.zeros((self.nb,), np.int64)
        # positions never change: resolve bucket membership once
        rid = assign_regions(sim.state.positions, self.regions)
        self._rid = np.asarray(jax.device_get(rid))
        self._counts = np.maximum(np.bincount(self._rid, minlength=self.nb),
                                  1).astype(np.float32)

    def _bucket(self, region) -> int:
        name = region.name if isinstance(region, Region) else region
        for i, r in enumerate(self.regions):
            if r.name == name:
                return i
        raise KeyError(f"unknown region {name!r}; "
                       f"have {[r.name for r in self.regions]}")

    def drop(self, region, chunks: int) -> None:
        """Zero ``region``'s external drive for the next ``chunks``
        chunks (chaos injection surface — ``chaos.drop_region_input``)."""
        b = self._bucket(region)
        self._drop_left[b] = max(self._drop_left[b], int(chunks))

    def measured_rates(self) -> np.ndarray:
        """(nb,) per-bucket mean rate of the current state."""
        rate = np.asarray(jax.device_get(self.sim.state.neurons.rate))
        return (np.bincount(self._rid, weights=rate, minlength=self.nb)
                / self._counts).astype(np.float32)

    def run(self) -> AssimilationResult:
        T = self.target.shape[0]
        controlled = ~np.isnan(self.target)
        measured = np.zeros((T, self.nb), np.float32)
        drives = np.zeros((T, self.nb), np.float32)
        abs_err = np.zeros((T,), np.float32)
        bg = self.sim.cfg.background_mean
        with telemetry.span("workloads.assimilate", chunks=T, nb=self.nb):
            for t in range(T):
                self.chunk_index = t
                for hook in self.hooks:
                    hook(self)
                applied = self._drive.copy()
                dropped = self._drop_left > 0
                # a dropped region's mean external drive is cancelled
                # outright (controller offset included)
                applied[dropped] = -bg
                drives[t] = applied
                self.sim.step_with(sim_phases.DynamicParams(
                    region_drive=applied))
                measured[t] = self.measured_rates()
                err = np.where(controlled[t],
                               np.nan_to_num(self.target[t]) - measured[t],
                               0.0)
                abs_err[t] = (np.abs(err).sum()
                              / max(controlled[t].sum(), 1))
                # integrate only where not dropped: winding up against a
                # zeroed input would overshoot on recovery
                self._drive = np.clip(
                    self._drive + self.gain * np.where(dropped, 0.0, err),
                    -self.clip, self.clip).astype(np.float32)
                self._drop_left = np.maximum(self._drop_left - 1, 0)
        return AssimilationResult(
            target=self.target, measured=measured, drive=drives,
            abs_err=abs_err, compile_count=self.sim.dyn_compile_count())


def constant_target(chunks: int, nb: int, bucket: int,
                    value: float) -> np.ndarray:
    """(chunks, nb) trace holding ``bucket`` at ``value``, every other
    bucket free (NaN)."""
    t = np.full((chunks, nb), np.nan, np.float32)
    t[:, bucket] = value
    return t


def default_scenario() -> Scenario:
    """One controlled region (the left half-sheet) and the free rest."""
    return Scenario(
        name="assimilation",
        regions=(Region("driven", lo=(0.0, 0.0, 0.0), hi=(0.5, 1.0, 1.0)),),
        num_chunks=12)


def run_assimilation(cfg: Optional[BrainConfig] = None, chunks: int = 12,
                     target_rate: float = 0.02, gain: float = 120.0,
                     hooks: Sequence = (),
                     mesh=None) -> Tuple[AssimilationResult, Simulator]:
    """Build the default one-region experiment and run it."""
    cfg = cfg or dataclasses.replace(SMOKE_CONFIG, requests_cap_factor=1000)
    scn = default_scenario()
    sim = Simulator.from_config(cfg, scenario=scn, mesh=mesh)
    target = constant_target(chunks, num_buckets(scn.regions), 0,
                             target_rate)
    loop = AssimilationLoop(sim, target, gain=gain, hooks=hooks)
    return loop.run(), sim


def main(argv=None) -> dict:
    import argparse
    p = argparse.ArgumentParser(description="rate-assimilation workload")
    p.add_argument("--smoke", action="store_true",
                   help="smoke scale (64 neurons/rank)")
    p.add_argument("--chunks", type=int, default=12)
    p.add_argument("--target-rate", type=float, default=0.02)
    args = p.parse_args(argv)
    cfg = dataclasses.replace(SMOKE_CONFIG, requests_cap_factor=1000)
    if not args.smoke:
        cfg = dataclasses.replace(cfg, neurons_per_rank=256)
    res, _ = run_assimilation(cfg, chunks=args.chunks,
                              target_rate=args.target_rate)
    out = {"assim_final_abs_err": res.final_abs_err,
           "assim_first_abs_err": float(res.abs_err[0]),
           "dyn_compile_count": float(res.compile_count),
           "chunks": float(args.chunks)}
    assert res.compile_count == 1, \
        f"dynamic params retraced: {res.compile_count} compiles"
    print(json.dumps(out, indent=2, sort_keys=True))
    return out


if __name__ == "__main__":
    main()
