"""GPipe-style pipeline parallelism over a mesh axis (ppermute + microbatch
scan inside shard_map).

Each stage owns a contiguous slice of layers (stacked params sharded over the
stage axis). A step runs M microbatches through S stages in M+S-1 ticks; the
activation handoff is a single collective-permute per tick. Used when a model
doesn't fit even fully sharded (none of the assigned archs needs it at 256
chips — see DESIGN.md §5 — but the machinery is here and tested on 4 host
devices in tests/test_pipeline.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P


def pipeline_apply(layer_fn, stage_params, x_microbatches, mesh,
                   axis="stage"):
    """layer_fn(params_slice, x) -> x; stage_params: leaves (L_per_stage, ...)
    per stage (global leading dim = S * L_per_stage, sharded over ``axis``).
    x_microbatches: (M, mb, ...) replicated. Returns (M, mb, ...) outputs.
    """
    s = mesh.shape[axis]

    def body(stage_p, xs):
        idx = jax.lax.axis_index(axis)
        m = xs.shape[0]
        ticks = m + s - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def stage_compute(p, x):
            def one(xc, lp):
                return layer_fn(lp, xc), None
            y, _ = jax.lax.scan(one, x, p)
            return y

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any)
            feed = jnp.where(t < m, t, m - 1)
            x_in = jnp.where((idx == 0) & (t < m), 1.0, 0.0) * xs[feed] + \
                jnp.where(idx == 0, 0.0, 1.0) * buf
            y = stage_compute(stage_p, x_in)
            # hand off to the next stage; last stage's output is collected
            out_t = t - (s - 1)
            take = (idx == s - 1) & (out_t >= 0) & (out_t < m)
            outs = jax.lax.cond(
                take,
                lambda o: o.at[jnp.clip(out_t, 0, m - 1)].set(y),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis,
                                   [(i, (i + 1) % s) for i in range(s)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(m + s - 1))
        # only the last stage holds the outputs; psum-broadcast to all
        if s > 1:
            outs = jax.lax.psum(
                jnp.where(idx == s - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = P(axis)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pspec, stage_params), P()),
        out_specs=P(), check_vma=False)(stage_params, x_microbatches)
