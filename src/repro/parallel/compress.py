"""Gradient compression for cross-pod sync: int8 quantization with error
feedback (the residual of each round is carried into the next, so compression
error does not bias the trajectory).

Wire format: per-leaf absmax scale (f32) + int8 payload => 4x fewer bytes on
the pod-interconnect all-gather than f32 (verified from HLO by the roofline
parser in benchmarks/bench_compression.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, err):
    """-> (q int8, scale f32 scalar, new_err). x, err: same-shape f32."""
    xf = x.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, xf - deq


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def allreduce_int8(x, err, axis_name):
    """Error-feedback int8 all-reduce over ``axis_name``: all_gather the int8
    payload (1 B/el on the wire) + local dequant-sum. Returns (mean, new_err)."""
    q, scale, new_err = quantize(x, err)
    qs = jax.lax.all_gather(q, axis_name)            # (P, ...) int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)        # (P,) f32
    n = qs.shape[0]
    summed = jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)
    return summed / n, new_err


def tree_allreduce_int8(tree, err_tree, axis_name):
    out = jax.tree.map(lambda x, e: allreduce_int8(x, e, axis_name),
                       tree, err_tree)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return red, err
