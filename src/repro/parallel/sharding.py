"""Sharding rules: logical-axis mapping, best-effort constraints, param specs.

Scheme (DESIGN.md §5):
  batch             -> ('pod','data')  (pod folds into DP)
  weight "in" dim   -> 'data'   (FSDP row shard)   } only when the dim
  weight "out" dim  -> 'model'  (tensor col shard) } is large enough
  MoE expert dim    -> 'model'  (EP), fsdp dim 'data'
  optimizer m/v     -> like params, plus 'pod' on the fsdp dim (ZeRO across pods)

Small leaves (< _REPLICATE_BELOW elements) stay replicated: sharding a 64x64
matrix 256 ways buys nothing and costs collectives. Non-divisible dims are
allowed (GSPMD pads), but rules prefer divisible layouts.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

_REPLICATE_BELOW = 1 << 22          # 4M elements (~8MB bf16)

_mesh_var: contextvars.ContextVar = contextvars.ContextVar("repro_mesh",
                                                           default=None)
_layout_var: contextvars.ContextVar = contextvars.ContextVar("repro_layout",
                                                             default="tp")


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], layout: str = None):
    tok = _mesh_var.set(mesh)
    tok2 = _layout_var.set(layout) if layout else None
    try:
        yield mesh
    finally:
        _mesh_var.reset(tok)
        if tok2 is not None:
            _layout_var.reset(tok2)


def current_mesh() -> Optional[Mesh]:
    return _mesh_var.get()


def current_layout() -> str:
    return _layout_var.get()


def batch_axes(mesh: Mesh, layout: str = None):
    layout = layout or current_layout()
    names = ("pod", "data", "model") if layout == "fsdp" else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def _manual_axes():
    """Mesh axes currently under manual (shard_map) control at trace time."""
    return compat.manual_axes()


def constrain(x, spec_axes):
    """Best-effort with_sharding_constraint. spec_axes uses logical names:
    'batch' expands to ('pod','data'); None passes through. Axes already
    manual (inside a partial shard_map, e.g. the Delta-periodic pod loop) are
    dropped — the data is already split over them."""
    mesh = current_mesh()
    if mesh is None:
        return x
    manual = _manual_axes()
    if manual and not compat.PARTIAL_MANUAL_CONSTRAINT_OK:
        return x  # old XLA: constraints inside partial shard_map crash

    def drop_manual(ax):
        if ax is None:
            return None
        axes = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                     if a not in manual)
        return axes if axes else None

    resolved = []
    used = set()
    for ax in spec_axes:
        got = drop_manual(batch_axes(mesh) if ax == "batch" else ax)
        if got is not None:  # each mesh axis may appear once (fsdp layout
            axes = got if isinstance(got, tuple) else (got,)
            axes = tuple(a for a in axes if a not in used)  # puts 'model'
            used.update(axes)                               # in 'batch')
            got = axes if axes else None
        resolved.append(got)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ------------------------------------------------------------ param rules
_EXPERT3D = re.compile(r"(w_up|w_gate|w_down)$")
_COL = re.compile(r"(w_up|w_gate|wq|wk|wv|w_q|w_k|w_v|w_x|w_g|w_if|w)$")
_ROW = re.compile(r"(w_down|wo|w_out)$")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def infer_param_spec(path, shape, mesh: Mesh, *, opt_state=False,
                     layout: str = None) -> P:
    """Sharding rule for one parameter leaf, keyed on its name + rank."""
    layout = layout or current_layout()
    name = _path_str(path)
    # scanned models stack per-layer params under 'layers_stacked' (leading L dim)
    stacked = 1 if "layers_stacked" in name and len(shape) >= 2 else 0
    core = shape[stacked:]
    size = 1
    for s in shape:
        size *= s
    if size < _REPLICATE_BELOW or not core:
        return P()
    if layout == "fsdp":
        fsdp = tuple(a for a in (("pod", "data", "model") if opt_state
                                 else ("data", "model"))
                     if a in mesh.axis_names)
    else:
        fsdp = ("pod", "data") if (opt_state and "pod" in mesh.axis_names) \
            else "data"
    leaf_name = name.split("/")[-1]

    def _axes_size(ax):
        if ax is None:
            return 1
        axes = ax if isinstance(ax, tuple) else (ax,)
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out

    def pad(spec_tail):
        # drop any axis whose size does not divide the dim (jit in_shardings
        # rejects uneven shards — e.g. whisper's 51865 vocab on a 16-way axis)
        fitted = [ax if core[i] % _axes_size(ax) == 0 else None
                  for i, ax in enumerate(spec_tail)]
        return P(*([None] * stacked + fitted))

    if len(core) == 3 and _EXPERT3D.search(leaf_name):   # experts (E, d, ff)
        ep_fsdp = "data" if not opt_state or "pod" not in mesh.axis_names \
            else ("pod", "data")
        return pad(["model", ep_fsdp, None])             # EP in both layouts
    if layout == "fsdp":                                 # pure row sharding
        if len(core) >= 2:
            return pad([fsdp] + [None] * (len(core) - 1))
        return P()
    if leaf_name == "table" and len(core) == 2:          # embedding (V, d)
        return pad(["model", fsdp])
    if len(core) == 2:
        if _ROW.search(leaf_name):
            return pad(["model", fsdp])                  # (ff, d): ff->model
        if _COL.search(leaf_name) or leaf_name == "router":
            return pad([fsdp, "model"])                  # (d, ff): ff->model
        return pad([fsdp, None])
    if len(core) == 1:
        return P()
    return P()


def make_param_shardings(params_shapes, mesh: Mesh, *, opt_state=False,
                         layout: str = None):
    """params_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    def one(path, leaf):
        spec = infer_param_spec(path, leaf.shape, mesh, opt_state=opt_state,
                                layout=layout)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_sharding(mesh: Mesh, ndim: int, batch_dim=0, batch_size=None,
                   layout: str = None):
    """Shard dim ``batch_dim`` over the DP axes; replicate when the batch does
    not divide them (e.g. long_500k's global_batch=1)."""
    spec = [None] * ndim
    baxes = batch_axes(mesh, layout)
    import math as _math
    bsz = _math.prod(mesh.shape[a] for a in baxes) if baxes else 1
    if batch_size is None or (batch_size % max(bsz, 1) == 0
                              and batch_size >= bsz):
        spec[batch_dim] = baxes
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
