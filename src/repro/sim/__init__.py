"""repro.sim — the experiment-facing simulation facade (DESIGN.md §8).

  api.py       ``Simulator``: mesh + sharded init + fused multi-chunk
               ``run``/``step`` driver, ``stats``, ``lower``,
               ``save``/``restore``
  phases.py    ``PhaseContext`` + the engine-level phase implementations
  registry.py  the phase-implementation registry the five BrainConfig
               variant fields resolve through

Submodules are loaded lazily (PEP 562): ``repro.sim.registry`` is
import-light and safe from ``BrainConfig.__post_init__``; importing
``Simulator`` pulls in the full engine stack.
"""
from __future__ import annotations

_LAZY = {
    "Simulator": ("repro.sim.api", "Simulator"),
    "PhaseContext": ("repro.sim.phases", "PhaseContext"),
    "make_context": ("repro.sim.phases", "make_context"),
    "register_phase": ("repro.sim.registry", "register_phase"),
}

__all__ = sorted(_LAZY) + ["api", "phases", "registry"]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro.sim' has no attribute {name!r}")
