"""Phase-implementation registry: the variant fields of ``BrainConfig``
resolve to callables here, at build time, instead of being string-compared
mid-trace in three different modules.

Each *domain* is one variant axis of the paper's three-phase loop; each
*name* is one registered implementation:

  domain          config field         registered implementations
  --------------  -------------------  ------------------------------------
  activity        activity_impl        reference (jnp scan) | fused (Pallas
                                       megakernel)           [sim/phases.py]
  spikes          spike_alg            old (per-step IDs) | new (rates +
                                       counter PRNG)         [sim/phases.py]
  connectivity    connectivity_alg     old (move data) | new (move compute)
                                                        [connectome/update.py]
  traversal       connectivity_impl    reference (jnp phase-B) | fused
                                       (Pallas traversal) [connectome/traverse]
  rate_exchange   rate_exchange        dense ((R, n) all-gather) | sparse
                                       (subscription push) [connectome/update]
  tree            tree_impl            reference (jnp Morton sort) | fused
                                       (Pallas radix sort) [connectome/tree]
  apply           apply_impl           reference (jnp segment ranks) | fused
                                       (Pallas edge-table kernel)
                                                      [connectome/synapses]

``_DOMAINS`` is the single source of truth for the *allowed names*: it is
plain data, so ``BrainConfig.__post_init__`` can validate eagerly (at
construction, with the allowed set in the error) without importing any of
the jax-heavy implementation modules. ``register_phase`` refuses a name not
declared here — adding an implementation means adding its name to the table
AND decorating the callable, one line each, in the same PR.

This module is import-light on purpose (stdlib only): configs, kernels, and
the connectome all import it without cycles. ``resolve`` lazily imports
``repro.sim.phases`` the first time so every ``@register_phase`` decorator
has run before any lookup.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_DOMAINS: Dict[str, Tuple[str, ...]] = {
    "activity": ("reference", "fused"),
    "spikes": ("old", "new"),
    "connectivity": ("old", "new"),
    "traversal": ("reference", "fused"),
    "rate_exchange": ("dense", "sparse"),
    "tree": ("reference", "fused"),
    "apply": ("reference", "fused"),
}

# domain -> the BrainConfig field it is selected by (also used in errors, so
# a bad value names the field the user actually typed)
CONFIG_FIELDS: Dict[str, str] = {
    "activity": "activity_impl",
    "spikes": "spike_alg",
    "connectivity": "connectivity_alg",
    "traversal": "connectivity_impl",
    "rate_exchange": "rate_exchange",
    "tree": "tree_impl",
    "apply": "apply_impl",
}

_IMPLS: Dict[Tuple[str, str], Callable] = {}


def register_phase(domain: str, name: str):
    """Decorator: register ``fn`` as the ``name`` implementation of
    ``domain``. The (domain, name) pair must be declared in ``_DOMAINS``."""
    if domain not in _DOMAINS:
        raise ValueError(f"unknown phase domain {domain!r}; "
                         f"declared: {sorted(_DOMAINS)}")
    if name not in _DOMAINS[domain]:
        raise ValueError(f"implementation name {name!r} not declared for "
                         f"domain {domain!r}; declared: {_DOMAINS[domain]} "
                         f"(add it to registry._DOMAINS first)")

    def deco(fn):
        _IMPLS[(domain, name)] = fn
        return fn
    return deco


def allowed(domain: str) -> Tuple[str, ...]:
    return _DOMAINS[domain]


def _bad_value(domain: str, name) -> ValueError:
    field = CONFIG_FIELDS[domain]
    opts = ", ".join(repr(v) for v in _DOMAINS[domain])
    return ValueError(f"unknown {field} {name!r}; allowed: {opts}")


def check_config(cfg) -> None:
    """Eager validation of all variant fields plus cross-field
    compatibility. Called from ``BrainConfig.__post_init__`` so an illegal
    config can never reach a trace. Pure data lookup — no heavy imports."""
    for domain, field in CONFIG_FIELDS.items():
        value = getattr(cfg, field)
        if value not in _DOMAINS[domain]:
            raise _bad_value(domain, value)
    if cfg.activity_impl == "fused" and cfg.spike_alg != "new":
        raise ValueError(
            "activity_impl='fused' requires spike_alg='new' — the old "
            "algorithm exchanges spiked IDs every step (a collective), "
            "which cannot run inside the megakernel")


def ensure_loaded() -> None:
    """Import the modules that carry ``@register_phase`` decorators."""
    import repro.sim.phases  # noqa: F401  (pulls in connectome.* transitively)


def resolve(domain: str, name: str) -> Callable:
    """Name -> callable, loading implementations on first use. Raises
    ``ValueError`` naming the config field and the allowed set."""
    ensure_loaded()
    try:
        return _IMPLS[(domain, name)]
    except KeyError:
        raise _bad_value(domain, name) from None
