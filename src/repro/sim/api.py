"""The user-facing simulation facade.

``Simulator`` owns everything the examples and benchmarks used to
hand-roll: mesh construction, sharded init under shard_map, the jitted
per-chunk step, a fused multi-chunk ``run`` (ONE jitted ``lax.scan`` over
chunks with donated carry — no Python dispatch between chunks), summed
stats, scenario-aware lowering for the dry-run/roofline path, and
checkpointing built on ``repro.checkpoint.manager``.

Bit-identity contract: ``engine.build_sim`` (the deprecated shim) returns
this class's own jitted callables, so both entry points share one trace;
and ``run(k)`` is bit-identical to ``k`` sequential ``step()`` calls
because every source of randomness is keyed by counters carried in the
state (``state.chunk``, the per-step counter hash), never by Python-side
loop indices (DESIGN.md §2/§8; tests/test_sim_api.py,
tests/test_multidevice.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import telemetry
from repro.checkpoint import manager
from repro.connectome import routing
from repro.core import engine
from repro.core import spikes
from repro.scenarios import observables
from repro.scenarios import protocol as proto
from repro.sim import phases as sim_phases
from repro.sim import registry
from repro.telemetry import metrics as telemetry_metrics


class Simulator:
    """Drive the MSP brain simulation.

    >>> sim = Simulator.from_config(cfg, scenario=scn)   # mesh + init
    >>> sim.run(20)                                      # one fused scan
    >>> sim.stats()["synapses_formed"]

    Observability: every public entry point runs under a
    ``telemetry.span`` (wall-clock records + jax.profiler trace
    annotations; read back via ``telemetry.spans()``), and
    ``profile_dir=...`` wraps every ``run`` in a profiler capture
    (one trace directory per run, viewable in Perfetto/XProf).
    """

    def __init__(self, cfg, scenario=None, mesh=None, profile_dir=None):
        # cfg was validated eagerly in BrainConfig.__post_init__ (registry
        # .check_config); here we only make sure every @register_phase
        # decorator has run before the first resolve() inside a trace
        registry.ensure_loaded()
        self.cfg = cfg
        self.scenario = scenario
        self.profile_dir = profile_dir
        self.mesh = mesh if mesh is not None else engine.make_brain_mesh()
        self.num_ranks = self.mesh.shape["ranks"]
        with telemetry.span("sim.construct", ranks=self.num_ranks,
                            n=cfg.neurons_per_rank):
            shapes = jax.eval_shape(
                lambda: engine.init_state(cfg, 0, self.num_ranks, scenario))
            self.specs = engine.state_specs(shapes)

            def init_body():
                rank = jax.lax.axis_index("ranks")
                return engine.init_state(cfg, rank, self.num_ranks, scenario)

            self.init_fn = jax.jit(compat.shard_map(
                init_body, mesh=self.mesh, in_specs=(), out_specs=self.specs,
                check_vma=False))

            def chunk_body(st):
                rank = jax.lax.axis_index("ranks")
                ctx = sim_phases.make_context(cfg, rank, "ranks",
                                              self.num_ranks, scenario)
                return sim_phases.sim_chunk(st, ctx)

            # the un-jitted shard_map'd chunk: `step` jits it directly,
            # `run` scans it — both drive the SAME traced computation
            self._chunk_shard = compat.shard_map(
                chunk_body, mesh=self.mesh, in_specs=(self.specs,),
                out_specs=self.specs, check_vma=False)
            self.chunk_fn = jax.jit(self._chunk_shard, donate_argnums=(0,))
            self._run_cache = {}
            self._state = None
            self._probe_fn = None
            self._rebuild_fn = None
            self._dyn_fn = None
            # host-side runner lifecycle counters (telemetry.metrics
            # .LIFECYCLE_KEYS), merged into stats() and owned jointly
            # with runtime.sim_runner.SimulationRunner
            self.lifecycle = {k: 0 for k in telemetry_metrics.LIFECYCLE_KEYS}

    @classmethod
    def from_config(cls, cfg, scenario=None, mesh=None,
                    profile_dir=None) -> "Simulator":
        return cls(cfg, scenario=scenario, mesh=mesh,
                   profile_dir=profile_dir)

    @classmethod
    def from_connectome(cls, cfg, dataset, scenario=None, mesh=None,
                        profile_dir=None) -> "Simulator":
        """A Simulator whose initial state is wired from a
        ``workloads.datasets.ConnectomeDataset`` instead of empty tables
        (DESIGN.md §13).

        The dataset's row count must equal ``num_ranks *
        cfg.neurons_per_rank`` (gid == global row), its excitation layout
        must match the (cfg, scenario) population table per rank block
        (checked eagerly), and no degree may exceed ``cfg.max_synapses``.
        Under the sparse exchange the subscription registry is sized from
        the MEASURED per-rank unique-remote-source count (baked into
        ``cfg.subs_cap_base``; ``subs_cap_factor`` stays head-room on top)
        so heavy-tailed degree distributions don't start life overflowing,
        and the registry itself is derived through ``rebuild_exchange`` —
        the exact per-chunk computation, so sparse == dense bit-identity
        holds from the very first chunk."""
        from repro.workloads import datasets as wds
        wds.validate(dataset)
        mesh = mesh if mesh is not None else engine.make_brain_mesh()
        num_ranks = mesh.shape["ranks"]
        n = cfg.neurons_per_rank
        if dataset.num_neurons != num_ranks * n:
            raise ValueError(
                f"dataset {dataset.name!r} has {dataset.num_neurons} "
                f"neurons; need num_ranks*neurons_per_rank = "
                f"{num_ranks}*{n} = {num_ranks * n} (gid == global row)")
        wds.check_population_layout(dataset, cfg, scenario, num_ranks)
        if cfg.rate_exchange == "sparse" and cfg.subs_cap_base is None:
            cfg = dataclasses.replace(
                cfg, subs_cap_base=wds.max_unique_remote_sources(dataset, n))
        sim = cls(cfg, scenario=scenario, mesh=mesh,
                  profile_dir=profile_dir)
        sim._install_connectome(dataset)
        return sim

    def _install_connectome(self, dataset) -> None:
        """Overwrite the freshly initialized state's connectivity with the
        dataset: positions, front-packed out/in edge tables, per-neuron
        excitation, and synaptic-element counts covering the wired degrees
        (each neuron keeps its seeded vacant draw ON TOP of the wired
        elements, so the loaded connectome is homeostatically stable — the
        first update grows from it rather than retracting it)."""
        from repro.workloads import datasets as wds
        with telemetry.span("sim.from_connectome",
                            neurons=dataset.num_neurons,
                            edges=dataset.num_edges):
            out_e, in_e = wds.edge_tables(dataset, self.cfg.max_synapses)
            st = self.init()
            sh = self.shardings()
            out_deg = (out_e >= 0).sum(1).astype(np.float32)
            in_deg = (in_e >= 0).sum(1).astype(np.float32)
            vac_a = np.asarray(jax.device_get(st.neurons.ax_elements))
            vac_d = np.asarray(jax.device_get(st.neurons.de_elements))
            neurons = st.neurons._replace(
                ax_elements=jax.device_put(vac_a + out_deg,
                                           sh.neurons.ax_elements),
                de_elements=jax.device_put(vac_d + in_deg,
                                           sh.neurons.de_elements),
                is_excitatory=jax.device_put(dataset.is_excitatory,
                                             sh.neurons.is_excitatory))
            self._state = st._replace(
                neurons=neurons,
                positions=jax.device_put(dataset.positions, sh.positions),
                out_edges=jax.device_put(out_e, sh.out_edges),
                in_edges=jax.device_put(in_e, sh.in_edges))
            # derive subs/rate_slots/remote_rates from the installed
            # in-edge table (rates are all zero, so the pushed buffer
            # matches the dense table's zeros bit-for-bit)
            self.rebuild_exchange()

    # ------------------------------------------------------------ state
    @property
    def state(self):
        """The current BrainState (global sharded arrays); initializes on
        first access."""
        if self._state is None:
            self.init()
        return self._state

    def init(self):
        """(Re)initialize from cfg.seed and return the fresh state."""
        with telemetry.span("sim.init"):
            self._state = self.init_fn()
        return self._state

    # ------------------------------------------------------------ driving
    def step(self):
        """Advance one chunk (Delta activity steps + connectivity update)."""
        with telemetry.span("sim.step"):
            self._state = self.chunk_fn(self.state)
        return self._state

    def step_with(self, dyn):
        """Advance one chunk with a ``phases.DynamicParams`` pytree fed as
        a TRACED ARGUMENT (replicated leaves) — the host may change the
        values between every chunk without a single retrace, which
        ``dyn_compile_count`` asserts. This is the drive surface of the
        assimilation loop (``workloads.assimilate``; ROADMAP item 5's
        static/dynamic split, first slice). With ``dyn=None`` semantics
        are ``step()``'s exactly (use that instead — the argument-free
        trace is the bit-identity baseline)."""
        if self._dyn_fn is None:
            cfg, num_ranks, scn = self.cfg, self.num_ranks, self.scenario

            def body(st, dyn):
                rank = jax.lax.axis_index("ranks")
                ctx = sim_phases.make_context(cfg, rank, "ranks", num_ranks,
                                              scn, dyn=dyn)
                return sim_phases.sim_chunk(st, ctx)

            dyn_specs = jax.tree.map(lambda _: P(), dyn)
            self._dyn_fn = jax.jit(compat.shard_map(
                body, mesh=self.mesh, in_specs=(self.specs, dyn_specs),
                out_specs=self.specs, check_vma=False), donate_argnums=(0,))
        with telemetry.span("sim.step_with"):
            self._state = self._dyn_fn(self.state, dyn)
        return self._state

    def dyn_compile_count(self) -> int:
        """Number of compiled traces behind ``step_with`` — the
        assimilation loop asserts this stays at 1 across a whole run
        (retrace-free dynamic params)."""
        return 0 if self._dyn_fn is None else self._dyn_fn._cache_size()

    def run(self, num_chunks: int, recorder: Optional[object] = None):
        """Advance ``num_chunks`` chunks as ONE jitted ``lax.scan`` with
        donated carry — a single dispatch, no per-chunk Python overhead.

        With ``recorder`` (an ``observables.Recorder``), one row of
        per-region observables is recorded after every chunk (on the
        global arrays, inside the same scan) and the advanced recorder is
        returned: ``state, rec = sim.run(k, recorder=rec)``. Without it,
        returns the final state.

        Runs under a ``telemetry.span``; with ``profile_dir`` set, the
        whole call (fenced by ``block_until_ready``) is captured as one
        profiler trace under ``<profile_dir>/``."""
        state = self.state   # init outside the run span/capture
        fn = self._run_fn(int(num_chunks), recorder is not None)
        with telemetry.span("sim.run", chunks=int(num_chunks)), \
                telemetry.profile(self.profile_dir):
            if recorder is None:
                self._state = fn(state)
                out = self._state
            else:
                self._state, recorder = fn(state, recorder)
                out = (self._state, recorder)
            if self.profile_dir:
                # fence so the capture contains the device work, not just
                # the async dispatch
                jax.block_until_ready(self._state)
        return out

    def _run_fn(self, k: int, with_recorder: bool):
        key = (k, with_recorder)
        if key in self._run_cache:
            return self._run_cache[key]
        chunk, cfg = self._chunk_shard, self.cfg
        scn = self.scenario
        regions = scn.regions if scn is not None else ()
        events = scn.events if scn is not None else ()

        if with_recorder:
            def body(carry, _):
                st, rec = carry
                st = chunk(st)
                # st.chunk already advanced: the global step at this
                # chunk's end, correct even when resuming from a restore
                alive = proto.alive_mask(events, regions, st.positions,
                                         st.chunk * cfg.rate_period) \
                    if events else None
                rec = observables.record(rec, st.positions,
                                         st.neurons.calcium,
                                         st.neurons.rate, st.out_edges,
                                         regions, alive)
                return (st, rec), None

            def runner(st, rec):
                (st, rec), _ = jax.lax.scan(body, (st, rec), None, length=k)
                return st, rec

            # only the state is donated: donating the caller's recorder
            # would silently invalidate their reference, and its buffers
            # are a few (cap, nb) rows — nothing worth reusing
            fn = jax.jit(runner, donate_argnums=(0,))
        else:
            def runner(st):
                st, _ = jax.lax.scan(lambda s, _: (chunk(s), None), st,
                                     None, length=k)
                return st

            fn = jax.jit(runner, donate_argnums=(0,))
        self._run_cache[key] = fn
        return fn

    # ------------------------------------------------------------ readout
    def stats(self, reduce: bool = True) -> dict:
        """The device counters (paper byte accounting + per-phase work),
        fetched in ONE ``jax.device_get`` of the whole counter subtree
        (not one transfer per key), plus the host-side runner lifecycle
        counters (``checkpoint_saves``/``restores``/``rollbacks``/
        ``restarts``/``degrade_events``). ``reduce=True`` (default) sums
        over ranks to plain floats; ``reduce=False`` keeps the (R,)
        per-rank resolution as host arrays (device counters only)."""
        counters = jax.device_get(self.state.stats.counters)
        if reduce:
            out = {k: float(v.sum()) for k, v in counters.items()}
            out.update({k: float(v) for k, v in self.lifecycle.items()})
            return out
        return dict(counters)

    def health(self) -> dict:
        """The health gauges written by the LAST completed chunk (one
        cheap transfer of four scalars per rank — the per-interval poll
        of DESIGN.md §10). ``health_flags`` is the psum'd global bitmask
        (reduced with max, identical on every rank); the census gauges
        sum over ranks. Zero flags = healthy. Stale until a chunk has
        run — use ``probe_health`` to evaluate the current state."""
        g = jax.device_get(self.state.stats.gauges)
        return {k: float(v.max() if k == "health_flags" else v.sum())
                for k, v in g.items()}

    def probe_health(self) -> int:
        """Recompute the health verdict on the CURRENT state (same device
        math as the in-scan gauge refresh — ``phases.health_verdict``) and
        return the global ``health_flags`` bitmask. The runner calls this
        on the exact state it is about to checkpoint, so every checkpoint
        on disk is verified-good."""
        if self._probe_fn is None:
            cfg, num_ranks, scn = self.cfg, self.num_ranks, self.scenario

            def body(st):
                rank = jax.lax.axis_index("ranks")
                ctx = sim_phases.make_context(cfg, rank, "ranks", num_ranks,
                                              scn)
                stats = sim_phases.health_verdict(st, ctx)
                return stats.gauges["health_flags"]

            self._probe_fn = jax.jit(compat.shard_map(
                body, mesh=self.mesh, in_specs=(self.specs,),
                out_specs=P("ranks"), check_vma=False))
        flags = jax.device_get(self._probe_fn(self.state))
        return int(flags.max())

    def rebuild_exchange(self):
        """Re-derive the sparse rate-exchange fields (subscription
        registry, edge->slot remap, subscribed-rate buffer) from the
        in-edge table and advertised rates — the exact computation
        ``exchange_sparse`` runs at every chunk's end, so on a state
        restored at a chunk boundary the rebuilt fields are bit-identical
        to the checkpointed ones. The elastic resume path uses this to
        rebuild the registry for a new rank count; no-op under the dense
        layout (whose table restores/reshapes directly)."""
        if self.cfg.rate_exchange != "sparse":
            return self.state
        if self._rebuild_fn is None:
            cfg, num_ranks = self.cfg, self.num_ranks
            n = cfg.neurons_per_rank

            def body(st):
                rank = jax.lax.axis_index("ranks")
                subs, rate_slots, _ = spikes.build_subscriptions(
                    st.in_edges, rank, n, routing.cap_subs(cfg, num_ranks))
                remote_rates, _ = routing.push_subscribed_rates(
                    subs, st.neurons.rate, "ranks", num_ranks, n)
                return st._replace(subs=subs, rate_slots=rate_slots,
                                   remote_rates=remote_rates)

            self._rebuild_fn = jax.jit(compat.shard_map(
                body, mesh=self.mesh, in_specs=(self.specs,),
                out_specs=self.specs, check_vma=False))
        with telemetry.span("sim.rebuild_exchange"):
            self._state = self._rebuild_fn(self.state)
        return self._state

    def shardings(self):
        """The state's NamedShardings on THIS simulator's mesh (same
        structure as ``self.specs``)."""
        return jax.tree.map(
            lambda spec: NamedSharding(self.mesh, spec), self.specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    def metrics(self) -> "telemetry.Metrics":
        """The full device metrics tree — counters, per-chunk rings, and
        histograms — fetched in one transfer; leaves are host arrays with
        the per-rank leading axis intact."""
        with telemetry.span("sim.metrics"):
            return jax.device_get(self.state.stats)

    def lower(self):
        """Lower one sim chunk at the global sharded shapes — scenario
        included, so the dry-run/roofline path sees the trace that will
        actually run (stimulus tables, population params, lesion masks)."""
        with telemetry.span("sim.lower"):
            return self.chunk_fn.lower(jax.eval_shape(self.init_fn))

    # ------------------------------------------------------------ persist
    def ckpt_metadata(self) -> dict:
        """Checkpoint metadata: enough for a fresh process (possibly on a
        different rank count or after a degrade) to decide how to restore
        — see runtime.sim_runner.try_resume / runtime.elastic."""
        return {"cfg": self.cfg.name,
                "rate_exchange": self.cfg.rate_exchange,
                "num_ranks": self.num_ranks,
                "neurons_per_rank": self.cfg.neurons_per_rank,
                "subs_cap_factor": self.cfg.subs_cap_factor,
                "subs_cap_base": self.cfg.subs_cap_base,
                "requests_cap_factor": self.cfg.requests_cap_factor,
                "lifecycle": dict(self.lifecycle)}

    def save(self, path: str) -> int:
        """Atomic full-state checkpoint at ``<path>/step_<chunk>/`` via
        ``checkpoint.manager``. Returns the saved chunk number."""
        st = self.state
        step = int(jax.device_get(st.chunk))
        with telemetry.span("sim.save", step=step):
            manager.save(path, step, st, metadata=self.ckpt_metadata())
        self.lifecycle["checkpoint_saves"] += 1
        return step

    def restore(self, path: str, step: Optional[int] = None) -> int:
        """Load a checkpoint (latest step by default) and reshard it onto
        THIS simulator's mesh. ``run``/``step`` continue bit-identically
        to an uninterrupted run: all randomness is keyed by the restored
        ``chunk`` counter and the per-step hash, and the stats
        accumulators travel with the state."""
        if step is None:
            step = manager.latest_step(path)
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {path!r}")
        with telemetry.span("sim.restore", step=step):
            target = jax.eval_shape(self.init_fn)
            tree, _ = manager.restore(path, step, target, self.shardings())
            self._state = tree
        self.lifecycle["checkpoint_restores"] += 1
        return step
