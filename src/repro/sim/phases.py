"""PhaseContext + the engine-level phase implementations.

``PhaseContext`` replaces the ``(state, cfg, rank, axis_name, num_ranks,
scenario)`` six-argument threading that every phase used to take: build it
once per trace (inside the shard_map body, where ``rank`` is the traced
axis index) and every phase, registered variant, and helper reads the same
bundle. The derived tables (population parameters, region/event tuples) are
computed here so the phases do not re-derive them.

The activity-phase variants (``activity_impl``) and the per-step spike
exchange variants (``spike_alg``) are registered here; the connectivity
formation pair, the phase-B traversal lowerings, and the rate-exchange
layouts register themselves next to their implementations in
``repro.connectome``. ``repro.core.engine`` keeps thin compat shims with
the old six-arg signatures — this module must NOT import it (engine imports
us).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.connectome.update import connectivity_update
from repro.core import spikes
from repro.kernels import ops as kops
from repro.kernels.activity_fused import step_core
from repro.scenarios import populations as pops
from repro.scenarios import protocol as proto
from repro.scenarios import regions as regions_mod
from repro.sim import registry
from repro.telemetry import metrics as telemetry_metrics


class DynamicParams(NamedTuple):
    """Runtime parameters a host loop may change between chunks WITHOUT
    retracing — the first concrete slice of the static/dynamic config split
    (ROADMAP item 5). Every leaf is a traced chunk-function ARGUMENT
    (replicated, fed through ``Simulator.step_with``), never a Python
    constant baked into the trace; shapes are fixed by the scenario's
    region count, so new values reuse the compiled program.

    ``region_drive``: (num_buckets,) f32 additive offset on the background
    drive MEAN per region bucket (``regions.assign_regions`` order: named
    regions first, the trailing 'rest' bucket last). Riding on ``bg_mean``
    — already a per-neuron operand of ``step_core`` and the fused
    megakernel — keeps both activity lowerings untouched."""
    region_drive: Any

    @staticmethod
    def zeros(num_buckets: int) -> "DynamicParams":
        return DynamicParams(
            region_drive=jnp.zeros((num_buckets,), jnp.float32))


@dataclass
class PhaseContext:
    """Everything a phase implementation needs besides the BrainState.

    ``rank`` is the traced ``lax.axis_index`` inside shard_map (or a
    concrete int in single-rank helpers); ``table`` is the per-neuron
    population parameter table; ``regions``/``events`` are the scenario's
    static tuples (empty when scenario is None); ``dyn`` is the traced
    ``DynamicParams`` argument (None on the default, argument-free trace —
    kept None rather than zeros so the seed trace stays bit-identical);
    ``metrics`` is the shared ``telemetry.metrics.Recorder`` every
    registered phase implementation records through (one jnp expression
    per quantity — the bit-identity surface of DESIGN.md §9)."""
    cfg: Any
    rank: Any
    axis_name: Optional[str]
    num_ranks: int
    scenario: Any = None
    table: Any = None
    regions: Tuple = ()
    events: Tuple = ()
    dyn: Optional[DynamicParams] = None
    metrics: Any = None


def make_context(cfg, rank, axis_name, num_ranks: int,
                 scenario=None, dyn=None) -> PhaseContext:
    table = pops.table_for(cfg, scenario, cfg.neurons_per_rank)
    regions = scenario.regions if scenario is not None else ()
    events = scenario.events if scenario is not None else ()
    return PhaseContext(cfg=cfg, rank=rank, axis_name=axis_name,
                        num_ranks=num_ranks, scenario=scenario, table=table,
                        regions=regions, events=events, dyn=dyn,
                        metrics=telemetry_metrics.Recorder(
                            n=cfg.neurons_per_rank))


# ================================================================ activity
def _window_inputs(state, ctx: PhaseContext):
    """Shared per-window tables: Izhikevich params, background drive,
    protocol tables, and the layout-dependent rate view (dense reads the
    replicated (R, n) table; sparse the compact subscribed-rate buffer
    through the (n, S) edge->slot remap)."""
    cfg, table = ctx.cfg, ctx.table
    izh = (table.izh_a, table.izh_b, table.izh_c, table.izh_d,
           table.growth_rate, table.target_calcium)
    ca_consts = (cfg.calcium_decay, cfg.calcium_beta)
    bg_mean, bg_std = regions_mod.background_tables(state.positions,
                                                    ctx.regions, cfg)
    if ctx.dyn is not None:
        # dynamic per-region drive (DynamicParams.region_drive, a traced
        # argument): lift bg_mean to (n,) and add each neuron's bucket
        # offset — both lowerings already take bg_mean as a per-neuron
        # operand, so new drive values never retrace
        rid = regions_mod.assign_regions(state.positions, ctx.regions)
        bg_mean = jnp.broadcast_to(
            jnp.asarray(bg_mean, jnp.float32), rid.shape) \
            + ctx.dyn.region_drive[rid]
    stim = proto.stim_tables(ctx.events, ctx.regions, state.positions) \
        if ctx.events else None
    lesions = proto.lesion_tables(ctx.events, ctx.regions, state.positions) \
        if ctx.events else None
    if cfg.rate_exchange == "sparse":
        rates, rate_slots = state.remote_rates, state.rate_slots
    else:
        rates, rate_slots = state.rates_table, None
    return izh, ca_consts, bg_mean, bg_std, stim, lesions, rates, rate_slots


def _st7(neurons):
    return (neurons.v, neurons.u, neurons.calcium, neurons.ax_elements,
            neurons.de_elements, neurons.spiked, neurons.spike_count)


def _unpack_st7(neurons, out):
    return neurons._replace(v=out[0], u=out[1], calcium=out[2],
                            ax_elements=out[3], de_elements=out[4],
                            spiked=out[5], spike_count=out[6])


@registry.register_phase("spikes", "old")
def spikes_old(st7, state, ctx: PhaseContext, stats):
    """OLD spike transmission, one step: all-gather sorted spiked-ID
    buffers, binary-search each remote in-edge."""
    n = ctx.cfg.neurons_per_rank
    all_ids, _ = spikes.exchange_spiked_ids(st7[5], ctx.rank, n,
                                            ctx.axis_name, ctx.num_ranks)
    hits = spikes.lookup_spikes(all_ids, state.in_edges, n)
    remote_in = hits & ((state.in_edges // n) != ctx.rank) \
        & (state.in_edges >= 0)
    stats = stats.count("spikes_sent", jnp.sum(st7[5]))
    return remote_in, stats


@registry.register_phase("spikes", "new")
def spikes_new(st7, state, ctx: PhaseContext, stats):
    """NEW spike transmission: no per-step exchange at all — step_core
    reconstructs remote spikes from the counter hash + exchanged rates."""
    return None, stats


@registry.register_phase("activity", "reference")
def activity_reference(state, ctx: PhaseContext):
    """jax.lax.scan over the window's steps, each step the shared
    ``kernels.activity_fused.step_core`` jnp math (~6 fused passes per
    step, (n, s_max) temporaries in HBM)."""
    cfg = ctx.cfg
    n = cfg.neurons_per_rank
    izh, ca_consts, bg_mean, bg_std, stim, lesions, rates, rate_slots = \
        _window_inputs(state, ctx)
    spike_exchange = registry.resolve("spikes", cfg.spike_alg)

    def step(carry, t):
        st, stats = carry
        remote_in, stats = spike_exchange(st, state, ctx, stats)
        st = step_core(st, state.in_edges, ctx.table.synapse_weight,
                       rates, bg_mean, bg_std, izh, ca_consts,
                       cfg.seed, state.chunk * cfg.rate_period + t, ctx.rank,
                       n, stim=stim, lesions=lesions,
                       remote_override=remote_in, rate_slots=rate_slots)
        # this step's fired count — the same per-step reduction the fused
        # megakernel writes to its spike-count output block
        return (st, stats), jnp.sum(st[5].astype(jnp.float32))

    (out, stats), spikes_per_step = jax.lax.scan(
        step, (_st7(state.neurons), state.stats),
        jnp.arange(cfg.rate_period, dtype=jnp.int32))
    stats = ctx.metrics.activity_window(stats, spikes_per_step)
    return state._replace(neurons=_unpack_st7(state.neurons, out),
                          stats=stats)


@registry.register_phase("activity", "fused")
def activity_fused(state, ctx: PhaseContext):
    """One Pallas megakernel per rate window (grid over steps,
    Delta-resident VMEM state — zero per-step HBM temporaries). Requires
    spike_alg='new' (enforced at config construction): the old algorithm's
    per-step spiked-ID all-gather cannot live inside a kernel."""
    cfg = ctx.cfg
    izh, ca_consts, bg_mean, bg_std, stim, lesions, rates, rate_slots = \
        _window_inputs(state, ctx)
    out, spikes_per_step = kops.fused_activity_window(
        _st7(state.neurons), state.in_edges, ctx.table.synapse_weight, rates,
        bg_mean, bg_std, state.chunk, ctx.rank, seed=cfg.seed,
        num_steps=cfg.rate_period, izh=izh, ca_consts=ca_consts,
        stim=stim, lesions=lesions, rate_slots=rate_slots)
    stats = ctx.metrics.activity_window(state.stats, spikes_per_step)
    return state._replace(neurons=_unpack_st7(state.neurons, out),
                          stats=stats)


# ================================================================ dispatch
def activity_phase(state, ctx: PhaseContext):
    """rate_period electrical steps; lowering per ``cfg.activity_impl``,
    per-step spike exchange per ``cfg.spike_alg``. Both lowerings draw
    noise/remote spikes from the same counter-based hash keyed by (seed,
    chunk*Delta + t, neuron/edge id), so they are bit-identical
    (tests/test_activity_fused.py)."""
    return registry.resolve("activity", ctx.cfg.activity_impl)(state, ctx)


def connectivity_phase(state, ctx: PhaseContext):
    """One structural-plasticity update — owned by the connectome subsystem
    (repro.connectome; DESIGN.md §6). ``cfg.connectivity_alg`` picks the
    paper's algorithm pair, ``cfg.connectivity_impl`` the phase-B lowering,
    ``cfg.rate_exchange`` the Delta-periodic exchange layout — all resolved
    through the phase registry."""
    return connectivity_update(state, ctx)


# ================================================================ health
def health_verdict(state, ctx: PhaseContext):
    """The device-side health verdict (DESIGN.md §10): a few reductions
    over state that is already resident, folded into one psum — cheap
    enough to run every chunk inside the jitted scan.

    Checks (bits of ``health_flags``, identical math under every variant
    lowering so it never perturbs old==new / dense==sparse bit-identity):

      HEALTH_NONFINITE     NaN/Inf anywhere in the physical per-neuron
                           state (v, u, calcium, rate) or positions;
      HEALTH_ASYMMETRY     global live out-edge entries != live in-edge
                           entries (every synapse is one entry in each
                           table) — only asserted while
                           ``request_overflow`` is 0, since dropped
                           deletion notifications legitimately leave
                           stale partner entries;
      HEALTH_CONSERVATION  global live entries outside
                           ``[2F - 2D, 2F - D]`` for F = synapses_formed,
                           D = synapses_deleted: formation writes two
                           entries per acceptance; retraction removes
                           between one (double-retraction counts the kill
                           twice) and two (local + notified partner)
                           entries per counted kill. Same overflow guard.

    ``health_flags`` is psum'd so every rank carries the same verdict —
    readers must reduce it with max(), never sum(). The raw per-rank
    census gauges stay rank-local for diagnosis.

    Under the multi-tenant service (repro.service) this whole verdict is
    vmapped over the slot axis: every gauge — ``health_flags`` included —
    gains a leading (B,) axis and each slot's bits are computed from that
    slot's lane alone (the psum batches per-lane over 'ranks' only), so
    the service can quarantine exactly the offending tenant.
    """
    neu = state.neurons
    nonfinite = sum(
        jnp.sum((~jnp.isfinite(x)).astype(jnp.float32))
        for x in (neu.v, neu.u, neu.calcium, neu.rate, state.positions))
    out_live = jnp.sum((state.out_edges >= 0).astype(jnp.float32))
    in_live = jnp.sum((state.in_edges >= 0).astype(jnp.float32))
    c = state.stats.counters
    local = jnp.stack([nonfinite, out_live, in_live,
                       c["synapses_formed"][0], c["synapses_deleted"][0],
                       c["request_overflow"][0]])
    g = jax.lax.psum(local, ctx.axis_name) \
        if ctx.axis_name is not None else local
    g_nf, g_out, g_in, formed, deleted, overflow = (g[i] for i in range(6))
    clean = overflow == 0
    flags = jnp.where(g_nf > 0,
                      jnp.float32(telemetry_metrics.HEALTH_NONFINITE), 0.0)
    flags = flags + jnp.where(
        clean & (g_out != g_in),
        jnp.float32(telemetry_metrics.HEALTH_ASYMMETRY), 0.0)
    live = g_out + g_in
    lo = 2.0 * formed - 2.0 * deleted
    hi = 2.0 * formed - deleted
    flags = flags + jnp.where(
        clean & ((live < lo) | (live > hi)),
        jnp.float32(telemetry_metrics.HEALTH_CONSERVATION), 0.0)
    return state.stats.set_gauges({
        "health_flags": flags, "nonfinite_state": nonfinite,
        "out_edges_live": out_live, "in_edges_live": in_live})


def sim_chunk(state, ctx: PhaseContext):
    """One chunk = one rate window (Delta activity steps) + one
    connectivity update. Each phase runs under a ``jax.named_scope`` so it
    shows up as a named region in profiler traces / HLO metadata, the
    chunk's counter increments are written into the per-chunk metrics ring
    (per-Delta resolution; telemetry.metrics), and the health gauges are
    refreshed so the fault-tolerant runner can poll the verdict without
    touching the full state (DESIGN.md §10)."""
    start = state.stats.counters
    with jax.named_scope("repro.activity"):
        state = activity_phase(state, ctx)
    with jax.named_scope("repro.connectivity"):
        state = connectivity_phase(state, ctx)
    # connectivity_update advanced state.chunk: slot = the chunk just run
    stats = state.stats.record_chunk(start, state.chunk - 1)
    with jax.named_scope("repro.health"):
        stats = health_verdict(state._replace(stats=stats), ctx)
    return state._replace(stats=stats)
