"""SimulationService: the request-driven multi-tenant front end over a
``SlotBatch`` (DESIGN.md §12).

One service = one compiled slot template (BrainConfig + scenario + mesh)
and ``num_slots`` lanes. Clients ``submit`` requests (per-tenant seed,
chunk budget, priority, deadline, retry policy) and receive a
``RequestHandle``; ``tick()`` advances every lane one boundary-aligned
step and runs the whole robustness layer:

  admission      bounded priority queue, typed shed on overflow
                 (``ServiceOverloaded``) — never unbounded;
  isolation      per-slot health verdicts (the in-scan gauges + a
                 re-probe of the current state) quarantine ONLY the
                 offending lane; co-tenants continue bit-identically to
                 solo runs (tests/test_service.py);
  retry          quarantined slots roll back to their last verified
                 snapshot after an exponential backoff with
                 deterministic jitter, bounded by ``max_retries``;
  deadlines      wall-clock deadlines are checked cooperatively at
                 chunk boundaries; expired requests cancel and free
                 their slot;
  watchdog       a slot whose chunk counter stops advancing for
                 ``stall_patience`` ticks is treated as stalled
                 (quarantine -> retry -> STALLED eviction);
  degradation    sustained overload or quarantine pressure walks a
                 ladder: (1) shrink the per-tick chunk count to its
                 floor, (2) shed the lowest-priority running tenant
                 (typed SHED eviction).

The tick is boundary-cooperative: the per-tick chunk count never
overshoots any running tenant's remaining budget, so completion,
cancellation, and eviction all happen at exact chunk boundaries — the
property that keeps every lane's trajectory bit-identical to a solo run.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
import zlib
from typing import Dict, List, Optional

import jax
import numpy as np

from repro import telemetry
from repro.checkpoint import manager
from repro.runtime.fault_tolerance import write_heartbeat
from repro.service.slots import SlotBatch
from repro.service.types import (BackoffRecord, IncompatibleRequest,
                                 RequestHandle, RequestStatus,
                                 ServiceConfigError, ServiceOverloaded,
                                 SimRequest, TenantResult)

SERVICE_LIFECYCLE_KEYS = (
    "requests_admitted", "requests_completed", "requests_rejected",
    "requests_shed", "deadline_cancellations", "quarantines",
    "slot_rollbacks", "slot_evictions", "stall_evictions",
    "degrade_events", "snapshots", "ticks")


@dataclasses.dataclass
class ServiceConfig:
    """Host-side service knobs. ``chunks_per_tick`` is the degradation
    ladder's first rung (shrunk toward ``min_chunks_per_tick`` under
    pressure); ``queue_cap`` bounds admission. ``snapshot_every`` is in
    ticks; snapshots are probe-verified before capture so a rollback
    target is never poisoned (the per-slot version of DESIGN.md §10)."""
    num_slots: int = 4
    queue_cap: int = 8
    chunks_per_tick: int = 1
    min_chunks_per_tick: int = 1
    max_chunks_per_request: int = 100_000
    snapshot_every: int = 1
    # retry/backoff (ticks): delay = min(max, base * 2**(attempt-1)) + jitter
    backoff_base: int = 1
    backoff_max: int = 8
    # watchdog / degradation
    stall_patience: int = 4
    overload_patience: int = 3
    quarantine_patience: int = 3
    shed_enabled: bool = True
    # persistence / observability
    heartbeat_path: Optional[str] = None
    ckpt_dir: Optional[str] = None     # durable per-slot lane checkpoints
    keep_final_state: bool = True
    scrub_evicted: bool = True         # re-place snapshot over a poisoned
                                       # lane at eviction (numeric hygiene)


@dataclasses.dataclass
class _Slot:
    index: int
    handle: Optional[RequestHandle] = None
    seed: int = 0
    admit_tick: int = 0
    backoff_until: int = 0
    last_progress_tick: int = 0
    last_chunk: int = 0
    stall_ticks: int = 0               # chaos: ticks of simulated stall
    snap: object = None                # verified lane snapshot (device)
    snap_chunk: int = 0

    @property
    def busy(self) -> bool:
        return self.handle is not None


def _jitter(handle_id: int, attempt: int) -> int:
    """Deterministic 0/1-tick jitter (crc32 of the identity) — breaks
    retry synchronization between slots without nondeterminism."""
    return zlib.crc32(f"{handle_id}:{attempt}".encode()) & 1


class SimulationService:
    """Multi-tenant simulation service over one compiled slot template.

    >>> svc = SimulationService(cfg, ServiceConfig(num_slots=4))
    >>> h = svc.submit(SimRequest(seed=7, chunks=20))
    >>> svc.run_until_idle()
    >>> h.result.status
    <RequestStatus.DONE: 'done'>
    """

    def __init__(self, cfg, service_cfg: Optional[ServiceConfig] = None,
                 scenario=None, mesh=None, batch: Optional[SlotBatch] = None):
        self.cfg = cfg
        self.service_cfg = service_cfg or ServiceConfig()
        sc = self.service_cfg
        with telemetry.span("service.construct", slots=sc.num_slots):
            if batch is not None:
                # share one compiled slot template across service
                # restarts (service state is reinitialised below)
                if batch.num_slots != sc.num_slots:
                    raise ServiceConfigError(
                        f"shared batch has {batch.num_slots} slots, "
                        f"service config wants {sc.num_slots}")
                self.batch = batch
            else:
                self.batch = SlotBatch(cfg, sc.num_slots, mesh=mesh,
                                       scenario=scenario)
            self.slots = [_Slot(i) for i in range(sc.num_slots)]
            self._seeds = np.zeros(sc.num_slots, np.int32)
            self.state = self.batch.init_all(
                jax.numpy.asarray(self._seeds))
        self.queue: List = []          # heap of (-priority, seq, handle)
        self._seq = 0
        self.tick_count = 0
        self.chunks_per_tick = sc.chunks_per_tick
        self.lifecycle: Dict[str, int] = {k: 0
                                          for k in SERVICE_LIFECYCLE_KEYS}
        self.events: List[dict] = []
        self._overload_streak = 0
        self._quarantine_streak = 0
        # chaos hooks: callables(service) fired after every tick's step,
        # before the health read — the window a real fault occupies
        self.chaos_hooks: List = []

    # ------------------------------------------------------------ events
    def _event(self, kind: str, **fields):
        self.events.append(dict(fields, event=kind, tick=self.tick_count))

    # --------------------------------------------------------- admission
    def submit(self, request: SimRequest) -> RequestHandle:
        """Admit (or queue) one request. Raises ``IncompatibleRequest``
        for budgets the template cannot serve and ``ServiceOverloaded``
        when the bounded queue is full — submission never blocks and the
        queue never grows past ``queue_cap``."""
        sc = self.service_cfg
        if request.chunks <= 0 or \
                request.chunks > sc.max_chunks_per_request:
            raise IncompatibleRequest(
                f"chunk budget {request.chunks} outside "
                f"(0, {sc.max_chunks_per_request}]")
        handle = RequestHandle(
            request,
            deadline_at=(time.monotonic() + request.deadline_s
                         if request.deadline_s is not None else None))
        free = self._free_slot()
        if free is None and len(self.queue) >= sc.queue_cap:
            self.lifecycle["requests_rejected"] += 1
            self._event("rejected", request=handle.id,
                        queue_depth=len(self.queue))
            raise ServiceOverloaded(
                f"no free slot and queue at capacity "
                f"({len(self.queue)}/{sc.queue_cap})",
                queue_depth=len(self.queue), queue_cap=sc.queue_cap)
        if free is not None:
            self._admit(free, handle)
        else:
            self._seq += 1
            heapq.heappush(self.queue,
                           (-request.priority, self._seq, handle))
            self._event("queued", request=handle.id,
                        queue_depth=len(self.queue))
        return handle

    def _free_slot(self) -> Optional[_Slot]:
        for s in self.slots:
            if not s.busy:
                return s
        return None

    def _admit(self, slot: _Slot, handle: RequestHandle):
        """Place a fresh lane (per-slot seed) into the slot. A lane write
        is a dynamic-update-slice on the slot axis: co-tenant lanes pass
        through bit-untouched."""
        req = handle.request
        with telemetry.span("service.admit", slot=slot.index,
                            request=handle.id):
            lane = self.batch.init_lane(
                jax.numpy.asarray(req.seed, jax.numpy.int32))
            self.state = self.batch.place(self.state, lane, slot.index)
        slot.handle = handle
        slot.seed = req.seed
        slot.admit_tick = self.tick_count
        slot.backoff_until = 0
        slot.last_progress_tick = self.tick_count
        slot.last_chunk = 0
        slot.stall_ticks = 0
        slot.snap = self.batch.extract(self.state, slot.index)
        slot.snap_chunk = 0
        self._seeds[slot.index] = req.seed
        handle.status = RequestStatus.RUNNING
        handle.slot = slot.index
        self.lifecycle["requests_admitted"] += 1
        self.lifecycle["snapshots"] += 1
        self._event("admitted", request=handle.id, slot=slot.index,
                    seed=req.seed)

    # ---------------------------------------------------------- eviction
    def _finish(self, slot: _Slot, status: RequestStatus,
                keep_state: bool = False):
        """Terminal transition: deliver the TenantResult and free the
        slot. The lane keeps simulating harmlessly until re-admission
        (optionally scrubbed back to the last good snapshot first)."""
        handle = slot.handle
        counters = self.batch.counters(self.state, slot.index)
        final = self.batch.extract(self.state, slot.index) \
            if keep_state and self.service_cfg.keep_final_state else None
        handle.status = status
        handle.result = TenantResult(
            status=status, chunks_done=handle.chunks_done,
            retries=handle.retries, backoffs=list(handle.backoffs),
            observations=np.array(handle.observations, np.float64)
            if handle.observations else np.zeros((0, 5)),
            counters=counters, final_state=final)
        if status is not RequestStatus.DONE and \
                self.service_cfg.scrub_evicted and slot.snap is not None:
            self.state = self.batch.place(self.state, slot.snap,
                                          slot.index)
        slot.handle = None
        slot.snap = None
        slot.stall_ticks = 0
        if status is not RequestStatus.DONE:
            self.lifecycle["slot_evictions"] += 1
        self._event("finished", request=handle.id, slot=slot.index,
                    status=status.value, chunks=handle.chunks_done)

    # -------------------------------------------------------- quarantine
    def _quarantine(self, slot: _Slot, reason: str):
        """Per-slot fault handling: retries left -> schedule an
        exponential-backoff retry (the lane is restored from the
        verified snapshot at expiry); retries spent -> typed eviction."""
        handle = slot.handle
        self.lifecycle["quarantines"] += 1
        handle.retries += 1
        self._event("quarantined", request=handle.id, slot=slot.index,
                    reason=reason, attempt=handle.retries)
        if handle.retries > handle.request.max_retries:
            self._finish(slot, RequestStatus.STALLED if reason == "stall"
                         else RequestStatus.FAILED)
            return
        sc = self.service_cfg
        attempt = handle.retries
        delay = min(sc.backoff_max, sc.backoff_base * 2 ** (attempt - 1)) \
            + _jitter(handle.id, attempt)
        slot.backoff_until = self.tick_count + delay
        handle.status = RequestStatus.BACKOFF
        rec = BackoffRecord(attempt=attempt, delay_ticks=delay,
                            tick=self.tick_count, reason=reason)
        handle.backoffs.append(rec)
        with telemetry.span("service.backoff", slot=slot.index,
                            request=handle.id, attempt=attempt,
                            delay_ticks=delay, reason=reason):
            pass
        self._event("backoff", request=handle.id, slot=slot.index,
                    attempt=attempt, delay_ticks=delay)

    def _restore_slot(self, slot: _Slot):
        """Roll one lane back to its last verified snapshot — the
        slot-sliced version of the runner's checkpoint rollback. Every
        other lane passes through the dynamic-update-slice untouched."""
        with telemetry.span("service.rollback", slot=slot.index,
                            to_chunk=slot.snap_chunk):
            self.state = self.batch.place(self.state, slot.snap,
                                          slot.index)
        slot.last_chunk = slot.snap_chunk
        slot.last_progress_tick = self.tick_count
        slot.handle.chunks_done = slot.snap_chunk
        slot.handle.status = RequestStatus.RUNNING
        self.lifecycle["slot_rollbacks"] += 1
        self._event("rollback", request=slot.handle.id, slot=slot.index,
                    to_chunk=slot.snap_chunk)

    # ----------------------------------------------------------- ticking
    def _expire_deadlines(self):
        now = time.monotonic()
        # queued requests can expire before ever holding a slot
        kept = []
        for item in self.queue:
            h = item[2]
            if h.deadline_at is not None and now >= h.deadline_at:
                h.chunks_done = 0
                self.lifecycle["deadline_cancellations"] += 1
                self._event("deadline", request=h.id, slot=None)
                h.status = RequestStatus.DEADLINE_EXCEEDED
                h.result = TenantResult(
                    status=h.status, chunks_done=0, retries=0,
                    backoffs=[], observations=np.zeros((0, 5)),
                    counters={})
            else:
                kept.append(item)
        if len(kept) != len(self.queue):
            self.queue = kept
            heapq.heapify(self.queue)
        for slot in self.slots:
            h = slot.handle
            if h is not None and h.deadline_at is not None \
                    and now >= h.deadline_at:
                self.lifecycle["deadline_cancellations"] += 1
                self._event("deadline", request=h.id, slot=slot.index)
                self._finish(slot, RequestStatus.DEADLINE_EXCEEDED)

    def _admit_from_queue(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            _, _, handle = heapq.heappop(self.queue)
            self._admit(slot, handle)

    def _tick_chunks(self) -> int:
        """Boundary-cooperative chunk count: never overshoot any running
        tenant's remaining budget (cancellation/completion happen at
        exact chunk boundaries)."""
        k = self.chunks_per_tick
        for slot in self.slots:
            h = slot.handle
            if h is not None and h.status is RequestStatus.RUNNING:
                k = min(k, h.request.chunks - h.chunks_done)
        return max(k, 1)

    def tick(self) -> bool:
        """One service step. Returns True while there is work left."""
        sc = self.service_cfg
        self.tick_count += 1
        self.lifecycle["ticks"] += 1
        self._expire_deadlines()
        self._admit_from_queue()
        running = [s for s in self.slots if s.busy]
        if not running and not self.queue:
            return False
        k = self._tick_chunks()
        seeds = jax.numpy.asarray(self._seeds)
        with telemetry.span("service.tick", tick=self.tick_count,
                            chunks=k, active=len(running)):
            for _ in range(k):
                self.state = self.batch.step(self.state, seeds)
        for hook in list(self.chaos_hooks):
            hook(self)
        # one read each of the in-scan verdict, the current-state probe,
        # the per-slot chunk counters, and the observable rows
        flags = self.batch.health_flags(self.state) | \
            self.batch.probe(self.state, seeds)
        chunks = self.batch.chunks(self.state)
        obs = self.batch.observe(self.state)
        quarantined_now = 0
        for slot in self.slots:
            h = slot.handle
            if h is None:
                continue
            b = slot.index
            if h.status is RequestStatus.BACKOFF:
                if self.tick_count >= slot.backoff_until:
                    self._restore_slot(slot)
                continue
            # progress accounting (chaos stall freezes the credited
            # progress, emulating a tenant that stops advancing)
            if slot.stall_ticks > 0:
                slot.stall_ticks -= 1
            else:
                h.chunks_done = int(chunks[b]) - 0
                if int(chunks[b]) > slot.last_chunk:
                    slot.last_chunk = int(chunks[b])
                    slot.last_progress_tick = self.tick_count
            h.observations.append(
                np.concatenate(([float(self.tick_count)], obs[b])))
            if int(flags[b]) != 0:
                quarantined_now += 1
                self._quarantine(slot, "health")
                continue
            if self.tick_count - slot.last_progress_tick \
                    >= sc.stall_patience:
                quarantined_now += 1
                if slot.handle.retries >= slot.handle.request.max_retries:
                    self.lifecycle["stall_evictions"] += 1
                self._quarantine(slot, "stall")
                continue
            if h.chunks_done >= h.request.chunks:
                self.lifecycle["requests_completed"] += 1
                self._finish(slot, RequestStatus.DONE, keep_state=True)
                continue
            # probe-verified snapshot: the rollback target can never be
            # poisoned, and co-tenant lanes are not touched by capture
            if (self.tick_count - slot.admit_tick) \
                    % sc.snapshot_every == 0:
                slot.snap = self.batch.extract(self.state, b)
                slot.snap_chunk = h.chunks_done
                self.lifecycle["snapshots"] += 1
                if sc.ckpt_dir:
                    manager.save(
                        f"{sc.ckpt_dir}/slot{b}", h.chunks_done,
                        slot.snap,
                        metadata={"request": h.id, "seed": slot.seed,
                                  "tag": h.request.tag})
        self._maybe_degrade(quarantined_now)
        self._admit_from_queue()
        self._heartbeat()
        return any(s.busy for s in self.slots) or bool(self.queue)

    # -------------------------------------------------------- degradation
    def _maybe_degrade(self, quarantined_now: int):
        """The ladder: sustained overload (full queue) or quarantine
        pressure first shrinks the per-tick chunk count (finer boundaries
        = faster slot turnover and cheaper rollback re-runs), then sheds
        the lowest-priority running tenant with a typed SHED eviction."""
        sc = self.service_cfg
        self._overload_streak = self._overload_streak + 1 \
            if len(self.queue) >= sc.queue_cap else 0
        self._quarantine_streak = self._quarantine_streak + 1 \
            if quarantined_now > 0 else 0
        pressured = (self._overload_streak >= sc.overload_patience or
                     self._quarantine_streak >= sc.quarantine_patience)
        if not pressured:
            return
        self._overload_streak = 0
        self._quarantine_streak = 0
        if self.chunks_per_tick > sc.min_chunks_per_tick:
            self.chunks_per_tick = max(sc.min_chunks_per_tick,
                                       self.chunks_per_tick // 2)
            action = "shrink_chunks_per_tick"
        elif sc.shed_enabled:
            victims = [s for s in self.slots if s.busy]
            if not victims:
                return
            victim = min(victims,
                         key=lambda s: (s.handle.request.priority,
                                        -s.handle.id))
            self.lifecycle["requests_shed"] += 1
            action = "shed_lowest_priority"
            self._event("shed", request=victim.handle.id,
                        slot=victim.index,
                        priority=victim.handle.request.priority)
            self._finish(victim, RequestStatus.SHED)
        else:
            return
        self.lifecycle["degrade_events"] += 1
        with telemetry.span("service.degrade", action=action,
                            chunks_per_tick=self.chunks_per_tick):
            pass
        self._event("degrade", action=action,
                    chunks_per_tick=self.chunks_per_tick)

    # ------------------------------------------------------------- misc
    def _heartbeat(self):
        if self.service_cfg.heartbeat_path:
            write_heartbeat(self.service_cfg.heartbeat_path, {
                "tick": self.tick_count,
                "slots": {s.index: (s.handle.id if s.busy else None)
                          for s in self.slots},
                "progress": {s.index: s.last_chunk for s in self.slots
                             if s.busy},
                "lifecycle": dict(self.lifecycle)})

    def run_until_idle(self, max_ticks: int = 10_000) -> dict:
        """Drive ``tick`` until queue and slots drain (or ``max_ticks``).
        Returns the service lifecycle counters."""
        with telemetry.span("service.run_until_idle"):
            for _ in range(max_ticks):
                if not self.tick():
                    break
        return dict(self.lifecycle)

    def stats(self) -> dict:
        """Service lifecycle counters + live occupancy."""
        out = dict(self.lifecycle)
        out["slots_busy"] = sum(1 for s in self.slots if s.busy)
        out["queue_depth"] = len(self.queue)
        out["chunks_per_tick"] = self.chunks_per_tick
        return out
