"""Request/response types, typed rejections, and the slot lifecycle
state machine of the multi-tenant simulation service (DESIGN.md §12).

A *request* is one tenant's scenario instance: a per-slot seed, a chunk
budget, a priority, and optional deadline/retry semantics. The service
multiplexes admitted requests over fixed-shape slots (one lane of the
batched device state — ``repro.service.slots.SlotBatch``); everything in
this module is host-side bookkeeping.

Typed rejections (admission control never queues unboundedly):

  ``ServiceOverloaded``     the bounded queue is full — shed at submit;
  ``IncompatibleRequest``   the request cannot run on this service's
                            compiled template (chunk budget over the
                            admission cap, non-positive budget, ...);
  ``ServiceConfigError``    the service template itself is unusable
                            (fused kernel lowerings bake the seed as a
                            static kernel parameter and cannot take the
                            per-slot traced seed).

Slot lifecycle (one slot; DESIGN.md §12 state machine)::

    EMPTY --admit--> RUNNING --chunk==budget--> DONE        (slot freed)
    RUNNING --deadline expired @ boundary--> DEADLINE_EXCEEDED  (freed)
    RUNNING --shed (degradation ladder)--> SHED                 (freed)
    RUNNING --health flags / stall watchdog--> quarantine:
        retries left    --> BACKOFF --expiry--> RUNNING
                            (lane restored from its slot snapshot)
        retries spent   --> FAILED | STALLED                    (freed)

``RequestStatus`` mirrors the request's view of that machine; a freed
slot returns to EMPTY and the next queued request is admitted into it.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional


class ServiceError(Exception):
    """Base class for every typed service error."""


class ServiceOverloaded(ServiceError):
    """Admission rejection: all slots busy and the bounded queue is at
    capacity. The submit is shed immediately — never queued unboundedly.
    Carries the observed depth so clients can back off intelligently."""

    def __init__(self, msg: str, queue_depth: int = 0,
                 queue_cap: int = 0):
        super().__init__(msg)
        self.queue_depth = queue_depth
        self.queue_cap = queue_cap


class IncompatibleRequest(ServiceError):
    """Admission rejection: the request cannot run on this service's
    compiled slot template (e.g. chunk budget over the admission cap)."""


class ServiceConfigError(ServiceError):
    """The service template config cannot serve multi-tenant slots."""


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    BACKOFF = "backoff"              # quarantined, awaiting retry
    DONE = "done"
    DEADLINE_EXCEEDED = "deadline_exceeded"
    SHED = "shed"                    # evicted by the degradation ladder
    STALLED = "stalled"              # watchdog verdict, retries spent
    FAILED = "failed"                # health verdict, retries spent

    @property
    def terminal(self) -> bool:
        return self not in (RequestStatus.QUEUED, RequestStatus.RUNNING,
                            RequestStatus.BACKOFF)


@dataclasses.dataclass
class SimRequest:
    """One tenant's scenario instance. ``seed`` keys every source of
    randomness for the instance (init + the counter-based in-run hash),
    so the result is bit-identical to a solo ``Simulator`` run with
    ``BrainConfig(seed=seed)`` regardless of slot placement or
    co-tenants. ``chunks`` is the chunk budget (one chunk = Delta
    activity steps + one connectivity update); ``deadline_s`` is wall
    clock from submission, checked cooperatively at chunk boundaries."""
    seed: int
    chunks: int
    priority: int = 0                # higher = survives shedding longer
    deadline_s: Optional[float] = None
    max_retries: int = 2
    tag: str = ""


@dataclasses.dataclass
class BackoffRecord:
    """One retry backoff: scheduled at ``tick``, slot resumes (snapshot
    restored) ``delay_ticks`` later. Delays grow exponentially with
    ``attempt`` plus deterministic jitter (service.py)."""
    attempt: int
    delay_ticks: int
    tick: int
    reason: str = "health"           # 'health' | 'stall'


@dataclasses.dataclass
class TenantResult:
    """Delivered when the request leaves the service (any terminal
    status). ``observations`` is the streamed per-tick observable rows
    (tick, chunk, mean rate, mean calcium, live out-edges) harvested
    while the tenant ran; ``counters`` the tenant's own device metrics
    (summed over ranks) at eviction."""
    status: RequestStatus
    chunks_done: int
    retries: int
    backoffs: List[BackoffRecord]
    observations: Any                # (ticks, 5) float ndarray
    counters: Dict[str, float]
    final_state: Any = None          # BrainState lane, if kept


class RequestHandle:
    """The client's view of a submitted request."""

    _next_id = 0

    def __init__(self, request: SimRequest, deadline_at: Optional[float]):
        RequestHandle._next_id += 1
        self.id = RequestHandle._next_id
        self.request = request
        self.status = RequestStatus.QUEUED
        self.deadline_at = deadline_at   # time.monotonic() absolute
        self.slot: Optional[int] = None
        self.chunks_done = 0
        self.retries = 0
        self.backoffs: List[BackoffRecord] = []
        self.observations: List[Any] = []
        self.result: Optional[TenantResult] = None

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, status={self.status.value}, "
                f"slot={self.slot}, chunks={self.chunks_done}/"
                f"{self.request.chunks})")
