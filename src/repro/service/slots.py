"""SlotBatch: the device layer of the multi-tenant service — B independent
scenario instances stacked on a leading *slot* axis, one compiled trace
(DESIGN.md §12).

Layout: every ``BrainState`` leaf gains a leading axis of size
``num_slots`` (PartitionSpec ``P(None, *solo_spec)`` — the slot axis is
never sharded; each lane stays sharded over 'ranks' exactly like a solo
run). One service chunk is ``shard_map(vmap(sim_chunk))``: the vmap lifts
every per-instance op to a batched op that is elementwise in the slot
axis, and the collectives batch per-lane over 'ranks' only — **no op in
the trace mixes lanes**, which is the fault-isolation argument: a NaN,
an overflow, or any other poisoned value in lane *b* is algebraically
confined to lane *b*.

Per-slot identity rides in the lane itself: the seed is a traced (B,)
argument (``dataclasses.replace(cfg, seed=lane_seed)`` inside the vmapped
body — integer Threefry hashing is exact, so a traced seed produces the
same bits as a solo run's static seed), and the chunk counter is already
a per-state field. Together with the counter-keyed randomness contract
(DESIGN.md §2) this makes slot placement invisible: a lane's trajectory
is bit-identical to a solo ``Simulator`` run with the same config + seed,
asserted on a 4-rank mesh for dense and sparse exchange in
tests/test_service.py.

The fused Pallas lowerings bake ``seed`` as a static kernel parameter, so
a SlotBatch requires the jnp reference lowerings (typed
``ServiceConfigError`` otherwise) — the batch axis and the kernels are
orthogonal wins; fusing the vmapped trace is ROADMAP follow-up work.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import engine
from repro.service.types import ServiceConfigError
from repro.sim import phases as sim_phases
from repro.sim import registry

# cfg fields that must stay on the jnp reference lowering: the Pallas
# kernels take seed as a *static* kernel parameter, incompatible with the
# per-slot traced seed
_REFERENCE_ONLY = ("activity_impl", "connectivity_impl", "tree_impl",
                   "apply_impl")


def stacked_specs(specs):
    """Prepend the (unsharded) slot axis to every solo PartitionSpec."""
    return jax.tree.map(lambda sp: P(None, *sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


class SlotBatch:
    """Device-side state + compiled callables for ``num_slots`` co-batched
    instances of one ``BrainConfig``/scenario template. Host-side slot
    bookkeeping lives in ``repro.service.service.SimulationService``."""

    def __init__(self, cfg, num_slots: int, mesh=None, scenario=None):
        for field in _REFERENCE_ONLY:
            if getattr(cfg, field) != "reference":
                raise ServiceConfigError(
                    f"service template needs {field}='reference' (the "
                    f"fused kernels bake the seed as a static parameter; "
                    f"the service's per-slot seed is traced), got "
                    f"{getattr(cfg, field)!r}")
        if num_slots < 1:
            raise ServiceConfigError(f"num_slots must be >= 1, "
                                     f"got {num_slots}")
        registry.ensure_loaded()
        self.cfg = cfg
        self.scenario = scenario
        self.num_slots = int(num_slots)
        self.mesh = mesh if mesh is not None else engine.make_brain_mesh()
        self.num_ranks = self.mesh.shape["ranks"]
        shapes = jax.eval_shape(
            lambda: engine.init_state(cfg, 0, self.num_ranks, scenario))
        self.specs = engine.state_specs(shapes)
        self.sspecs = stacked_specs(self.specs)
        self._build()

    # ------------------------------------------------------------ build
    def _ctx(self, cfg_slot, rank):
        return sim_phases.make_context(cfg_slot, rank, "ranks",
                                       self.num_ranks, self.scenario)

    def _build(self):
        cfg, R, B = self.cfg, self.num_ranks, self.num_slots
        mesh, specs, sspecs = self.mesh, self.specs, self.sspecs
        scenario = self.scenario

        def init_all_body(seeds):
            rank = jax.lax.axis_index("ranks")

            def one(sd):
                c = dataclasses.replace(cfg, seed=sd)
                return engine.init_state(c, rank, R, scenario)

            return jax.vmap(one)(seeds)

        self.init_all = jax.jit(compat.shard_map(
            init_all_body, mesh=mesh, in_specs=(P(None),),
            out_specs=sspecs, check_vma=False))

        def init_one_body(seed):
            rank = jax.lax.axis_index("ranks")
            return engine.init_state(dataclasses.replace(cfg, seed=seed),
                                     rank, R, scenario)

        self.init_lane = jax.jit(compat.shard_map(
            init_one_body, mesh=mesh, in_specs=(P(),), out_specs=specs,
            check_vma=False))

        def chunk_body(st, seeds):
            rank = jax.lax.axis_index("ranks")

            def one(s, sd):
                return sim_phases.sim_chunk(
                    s, self._ctx(dataclasses.replace(cfg, seed=sd), rank))

            return jax.vmap(one)(st, seeds)

        # the service chunk: ONE compiled trace, shared by every slot and
        # every tick (seeds are a traced argument — no retrace on tenant
        # turnover); donated carry like Simulator.run
        self.step = jax.jit(compat.shard_map(
            chunk_body, mesh=mesh, in_specs=(sspecs, P(None)),
            out_specs=sspecs, check_vma=False), donate_argnums=(0,))

        def probe_body(st, seeds):
            rank = jax.lax.axis_index("ranks")

            def one(s, sd):
                ctx = self._ctx(dataclasses.replace(cfg, seed=sd), rank)
                return sim_phases.health_verdict(s, ctx).gauges[
                    "health_flags"]

            return jax.vmap(one)(st, seeds)      # (B, 1) per rank

        # health re-probe of the CURRENT stacked state (per-slot verdict
        # on exactly what a snapshot would capture — DESIGN.md §10 rule
        # "every rollback target is verified-good", now per slot)
        self._probe = jax.jit(compat.shard_map(
            probe_body, mesh=mesh, in_specs=(sspecs, P(None)),
            out_specs=P(None, "ranks"), check_vma=False))

        # lane surgery: dynamic-update-slice on the slot axis only —
        # every other lane's bits pass through untouched
        self._place = jax.jit(
            lambda st, lane, b: jax.tree.map(
                lambda f, o: f.at[b].set(o), st, lane),
            donate_argnums=(0,))
        self._extract = jax.jit(
            lambda st, b: jax.tree.map(lambda f: f[b], st))

        def observe_body(st):
            live = jnp.sum((st.out_edges >= 0).astype(jnp.float32),
                           axis=(1, 2))
            return jnp.stack([st.chunk.astype(jnp.float32),
                              jnp.mean(st.neurons.rate, axis=1),
                              jnp.mean(st.neurons.calcium, axis=1),
                              live], axis=1)

        # per-slot observable row (chunk, mean rate, mean calcium, live
        # out-edges): one tiny transfer per tick feeds the result streams
        self._observe = jax.jit(observe_body)

    # ------------------------------------------------------------ lanes
    def place(self, state, lane, b: int):
        """Write ``lane`` (a solo-shaped BrainState) into slot ``b``."""
        return self._place(state, lane, jnp.asarray(b, jnp.int32))

    def extract(self, state, b: int):
        """Copy slot ``b`` out as a solo-shaped BrainState."""
        return self._extract(state, jnp.asarray(b, jnp.int32))

    # ---------------------------------------------------------- readouts
    def probe(self, state, seeds) -> np.ndarray:
        """Per-slot health bitmask of the CURRENT state: (B,) ints. The
        in-scan gauges only reflect the last completed chunk; this
        re-evaluates ``health_verdict`` on the state as it is now."""
        flags = jax.device_get(self._probe(state, seeds))   # (B, R)
        return np.asarray(flags).max(axis=1).astype(np.int64)

    def health_flags(self, state) -> np.ndarray:
        """Per-slot psum'd health bitmask written by the last completed
        chunk (the in-scan verdict): (B,) ints, max-reduced over ranks."""
        g = jax.device_get(state.stats.gauges["health_flags"])  # (B, R)
        return np.asarray(g).max(axis=1).astype(np.int64)

    def chunks(self, state) -> np.ndarray:
        """Per-slot chunk counters: (B,) ints."""
        return np.asarray(jax.device_get(state.chunk)).astype(np.int64)

    def counters(self, state, b: Optional[int] = None):
        """Device counters summed over ranks: dict of (B,) arrays, or of
        floats for one slot when ``b`` is given."""
        c = jax.device_get(state.stats.counters)
        out = {k: np.asarray(v).sum(axis=tuple(range(1, np.ndim(v))))
               for k, v in c.items()}
        if b is None:
            return out
        return {k: float(v[b]) for k, v in out.items()}

    def observe(self, state) -> np.ndarray:
        """(B, 4) observable rows (chunk, mean rate, mean calcium, live
        out-edges) for the streaming path."""
        return np.asarray(jax.device_get(self._observe(state)))

    # ------------------------------------------------------------- misc
    def lane_sharding(self, leaf_path_example: Any = None):
        """NamedShardings of the stacked tree (for chaos injectors that
        re-place a host-edited leaf)."""
        return jax.tree.map(
            lambda sp: NamedSharding(self.mesh, sp), self.sspecs,
            is_leaf=lambda x: isinstance(x, P))
