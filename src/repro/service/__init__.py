"""repro.service — slot-based multi-tenant simulation service.

Public surface:

  ``SimulationService`` / ``ServiceConfig``  host loop: admission,
      deadlines, retry/backoff, watchdog, degradation ladder;
  ``SlotBatch``                              device layer: B lanes on a
      leading slot axis, one compiled trace;
  ``SimRequest`` / ``RequestHandle`` / ``TenantResult`` / request status
      + typed rejections.

See DESIGN.md §12 for the architecture and the isolation proof sketch.
"""
from repro.service.service import (SERVICE_LIFECYCLE_KEYS, ServiceConfig,
                                   SimulationService)
from repro.service.slots import SlotBatch, stacked_specs
from repro.service.types import (BackoffRecord, IncompatibleRequest,
                                 RequestHandle, RequestStatus, ServiceError,
                                 ServiceOverloaded, ServiceConfigError,
                                 SimRequest, TenantResult)

__all__ = [
    "SimulationService", "ServiceConfig", "SERVICE_LIFECYCLE_KEYS",
    "SlotBatch", "stacked_specs",
    "SimRequest", "RequestHandle", "TenantResult", "BackoffRecord",
    "RequestStatus", "ServiceError", "ServiceOverloaded",
    "IncompatibleRequest", "ServiceConfigError",
]
