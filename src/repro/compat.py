"""Version compatibility for the installed jax.

The repo targets the modern ``jax.shard_map`` API surface; the container
ships jax 0.4.37 where

  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
    replication check ``check_rep`` instead of ``check_vma``;
  * ``jax.sharding.AxisType`` does not exist (all mesh axes are Auto);
  * ``jax.make_mesh`` takes no ``axis_types`` keyword.

Every call site imports these three names from here instead of from jax so
the same code runs on both API generations.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: axis types not modeled; Auto is the default
    import enum

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


if hasattr(jax, "shard_map"):

    def shard_map(f, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None):
        kw = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None):
        # old API: manual axes are (mesh axes - auto); axis_names is the
        # modern complement (the axes that ARE manual)
        auto = frozenset() if axis_names is None else \
            frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma,
                          auto=auto)


# Old XLA's SPMD partitioner check-fails on sharding constraints over the
# auto axes inside a partially-manual shard_map ("IsManualSubgroup");
# best-effort constraints must be dropped there on the 0.4.x toolchain.
PARTIAL_MANUAL_CONSTRAINT_OK = hasattr(jax, "shard_map")


def axis_size(axis_name):
    """jax.lax.axis_size fallback (psum of a unit is the classic idiom —
    static, so it stays a Python int)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def manual_axes() -> frozenset:
    """Mesh axes under manual (shard_map) control at the current trace point.
    Modern jax records them on the abstract mesh; 0.4.x shard_map extends
    the named-axis environment instead."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return frozenset(a for a, t in zip(am.axis_names, am.axis_types)
                             if t == AxisType.Manual)
        return frozenset()
    except AttributeError:
        pass
    try:
        from jax._src.core import get_axis_env
        return frozenset(get_axis_env().axis_sizes)
    except Exception:
        return frozenset()


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """jax.make_mesh that tolerates the missing ``axis_types`` kwarg."""
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types, devices=devices)
    except TypeError:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             devices=devices)
