"""Fused activity-phase megakernel: one Pallas pass per rate window.

The engine's reference activity phase runs ~6 separate jnp passes per
electrical step x Delta=100 steps per chunk, materializing several
``(n, s_max)`` temporaries in HBM each step (local-spike hits, remote
Bernoulli draws, per-edge weights, the synaptic-input reduction, the noise
vector, and the two element updates). This module fuses the whole window
into a single ``pallas_call`` with ``grid=(num_steps,)``:

  * per step it (a) accumulates synaptic input from the ``(n, s_max)``
    in-edge table — true local spikes, counter-hash-reconstructed remote
    Bernoulli(rate) spikes, per-source signed weights — (b) adds per-region
    background noise plus protocol stimulation, and (c) runs Izhikevich
    integration + calcium + element growth under the lesion mask;
  * neuron state lives in VMEM for the whole window: every state operand is
    a full block with a constant index map and is aliased to its output
    (``input_output_aliases``), so nothing round-trips HBM between steps and
    zero ``(n, s_max)`` temporaries are ever materialized.

All randomness is the counter-based hash of ``kernels/hash.py`` keyed by
``(seed, domain, global step, neuron/edge id)``. ``step_core`` — the exact
per-step math — is plain jnp shared by this kernel, the jnp oracle
(``kernels/ref.activity_window_ref``) and the engine's reference scan,
which is what makes ``activity_impl='fused'`` bit-identical to
``'reference'`` (DESIGN.md §5).

TPU sizing: the window keeps the in-edge table and ~16 ``(n,)`` vectors
VMEM-resident, i.e. roughly ``(s_max + 16) * 4 * n`` bytes — n = 64k at
s_max = 32 is ~12.5 MB, the practical per-core ceiling. Beyond that, fall
back to ``activity_impl='reference'``. The dense rate exchange adds an
``(R, n)`` rates operand on top — O(R·n) VMEM that cannot survive large
meshes; the sparse exchange (``rate_slots`` given) replaces it with the
compact ``(subs_cap,)`` subscribed-rate buffer plus an ``(n, s_max)`` slot
remap (DESIGN.md §7). Like the other kernels in this package, CPU
containers run it with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import hash as chash

_N_STATE = 7   # v, u, calcium, ax_elements, de_elements, spiked, spike_count


def local_spike_hits(spiked_last, in_edges, rank, n: int):
    """True spikes for same-rank edges ('virtually free' in the paper).
    The math lives here (not core/spikes) so the kernel package never
    imports the engine package; ``core.spikes.local_spikes`` delegates."""
    src = in_edges
    valid = src >= 0
    src_rank = jnp.where(valid, src // n, 0)
    src_lid = jnp.where(valid, src % n, 0)
    local = valid & (src_rank == rank)
    return local & spiked_last[src_lid]


def reconstruct_remote_spikes(seed: int, gstep, all_rates, in_edges, rank,
                              n: int, rate_slots=None):
    """NEW spike algorithm, receive side: Bernoulli(rate) per REMOTE edge
    from the counter hash keyed by ``(seed, SPIKE_DOMAIN, gstep,
    dst_gid*S + slot)``. The edge id derives from the receiver's table
    coordinates, so any rank holding the same edge table draws the same
    stream. Returns (n, S) bool (False on local/empty edges).

    ``rate_slots=None`` (dense exchange): ``all_rates`` is the replicated
    (R, n) table, looked up by the edge's (src rank, src lid) — a 2-D
    gather over the full table. Otherwise (sparse exchange): ``all_rates``
    is the compact (subs_cap,) subscribed-rate buffer and ``rate_slots``
    the (n, S) edge→slot remap — a 1-D gather; slot -1 (local, empty, or
    overflowed subscription) reads rate 0. The Bernoulli stream is keyed by
    the edge id either way, so both layouts draw identical spikes wherever
    the subscription held the true rate (DESIGN.md §7)."""
    src = in_edges
    s_max = src.shape[1]
    valid = src >= 0
    src_rank = jnp.where(valid, src // n, 0)
    src_lid = jnp.where(valid, src % n, 0)
    remote = valid & (src_rank != rank)
    if rate_slots is None:
        rates = all_rates[src_rank, src_lid]
    else:
        cap = all_rates.shape[0]
        rates = jnp.where(rate_slots >= 0,
                          all_rates[jnp.clip(rate_slots, 0, cap - 1)], 0.0)
    dst_gid = rank * n + jnp.arange(n, dtype=jnp.int32)
    edge_id = dst_gid[:, None] * s_max + jnp.arange(s_max, dtype=jnp.int32)
    u = chash.uniform(seed, chash.SPIKE_DOMAIN, gstep, edge_id)
    return remote & (u < rates)


def step_core(state, in_edges, w_table, rates, bg_mean, bg_std, izh,
              ca_consts, seed: int, gstep, rank, n: int,
              stim=None, lesions=None, remote_override=None,
              rate_slots=None):
    """One electrical step, pure jnp — the single source of truth executed
    by the Pallas kernel body, the jnp oracle, and the engine's reference
    scan (bit-identity by construction).

    state: (v, u, ca, ax, de, spiked, spike_count); izh: (a, b, c, d, nu,
    eps) scalars or (n,); ca_consts: (calcium_decay, calcium_beta) floats;
    stim: ((E, n) f32 masks, ((amplitude, t0, t1), ...)) or None; lesions:
    ((W, n) bool masks, ((t0, t1), ...)) or None; remote_override: (n, S)
    bool remote-spike hits (old spike algorithm) or None to reconstruct
    them from the counter hash; rate_slots: None when ``rates`` is the
    dense (R, n) table, else the (n, S) edge→slot remap into the compact
    (subs_cap,) subscribed-rate buffer (sparse exchange)."""
    v, u, ca, ax, de, spiked, spike_count = state
    a, b, c, d, nu, eps = izh
    ca_decay, ca_beta = ca_consts

    # ---- (a) synaptic input from the in-edge table -----------------------
    local_in = local_spike_hits(spiked, in_edges, rank, n)
    if remote_override is None:
        remote_in = reconstruct_remote_spikes(seed, gstep, rates, in_edges,
                                              rank, n, rate_slots=rate_slots)
    else:
        remote_in = remote_override
    valid = in_edges >= 0
    src_lid = jnp.where(valid, in_edges, 0) % n
    weights = jnp.where(valid, w_table[src_lid], 0.0)
    syn_in = jnp.sum((local_in | remote_in) * weights, axis=-1)

    # ---- (b) background noise + stimulation ------------------------------
    gid = rank * n + jnp.arange(n, dtype=jnp.int32)
    noise = bg_mean + bg_std * chash.normal(seed, chash.NOISE_DOMAIN,
                                            gstep, gid)
    if stim is not None:
        masks, meta = stim
        for i, (amp, t0, t1) in enumerate(meta):
            active = ((gstep >= t0) & (gstep < t1)).astype(jnp.float32)
            noise = noise + amp * active * masks[i]
    alive = None
    if lesions is not None:
        masks, meta = lesions
        alive = jnp.ones((n,), bool)
        for i, (t0, t1) in enumerate(meta):
            alive = alive & ~(masks[i] & (gstep >= t0) & (gstep < t1))

    # ---- (c) Izhikevich + calcium + element growth -----------------------
    u_prev = u
    i_t = syn_in + noise
    for _ in range(2):  # two half-ms Euler steps (reference Izhikevich impl)
        v = v + 0.5 * (0.04 * v * v + 5.0 * v + 140.0 - u + i_t)
    u = u + a * (b * v - u)
    fired = v >= 30.0
    v = jnp.where(fired, c, v)
    u = jnp.where(fired, u + d, u)
    if alive is not None:
        fired = fired & alive
        v = jnp.where(alive, v,
                      jnp.broadcast_to(jnp.asarray(c, jnp.float32), v.shape))
        u = jnp.where(alive, u, u_prev)
    ca = ca + (-ca * ca_decay + ca_beta * fired)
    spike_count = spike_count + fired
    drive = nu * (1.0 - ca / eps)
    ax = jnp.maximum(ax + drive, 0.0)
    de = jnp.maximum(de + drive, 0.0)
    if alive is not None:
        ax = jnp.where(alive, ax, 0.0)
        de = jnp.where(alive, de, 0.0)
    return v, u, ca, ax, de, fired, spike_count


def _window_kernel(*refs, n_in, num_steps, seed, ca_consts, n, stim_meta,
                   lesion_meta, has_slots):
    t = pl.program_id(0)
    outs = refs[n_in:n_in + _N_STATE]
    spk_ref = refs[n_in + _N_STATE]   # (1,) block of the (T,) per-step counts

    @pl.when(t == 0)
    def _init():   # noqa: ANN202 — Delta-resident state: load once per window
        for o, i in zip(outs, refs[:_N_STATE]):
            o[...] = i[...]

    state = tuple(o[...] for o in outs)
    nxt = _N_STATE
    in_edges = refs[nxt][...]
    w_table = refs[nxt + 1][...]
    rates = refs[nxt + 2][...]
    nxt += 3
    rate_slots = None
    if has_slots:
        rate_slots = refs[nxt][...]
        nxt += 1
    bg_mean = refs[nxt][...]
    bg_std = refs[nxt + 1][...]
    izh = tuple(r[...] for r in refs[nxt + 2:nxt + 8])
    scal = refs[nxt + 8][...]
    chunk, rank = scal[0], scal[1]
    nxt += 9
    stim = None
    if stim_meta is not None:
        stim = (refs[nxt][...], stim_meta)
        nxt += 1
    lesions = None
    if lesion_meta is not None:
        lesions = (refs[nxt][...], lesion_meta)
        nxt += 1
    gstep = chunk * num_steps + t
    new = step_core(state, in_edges, w_table, rates, bg_mean, bg_std, izh,
                    ca_consts, seed, gstep, rank, n,
                    stim=stim, lesions=lesions, rate_slots=rate_slots)
    for o, val in zip(outs, new):
        o[...] = val
    # this step's fired count — the same reduction the reference scan emits
    # as its ys (telemetry spikes-per-step; bit-identity by construction)
    spk_ref[...] = jnp.sum(new[5].astype(jnp.float32))[None]


def activity_window(state, in_edges, w_table, rates, bg_mean, bg_std,
                    chunk, rank, *, seed: int, num_steps: int, izh,
                    ca_consts, stim=None, lesions=None, rate_slots=None,
                    interpret=False):
    """Run ``num_steps`` electrical steps in one ``pallas_call``.

    state: 7-tuple (v, u, ca, ax, de, spiked (bool), spike_count), all (n,);
    in_edges: (n, s_max) i32; w_table: (n,) signed per-source weights;
    rates: the dense (R, n) replicated table, or — with ``rate_slots``
    (n, s_max) given — the compact (subs_cap,) subscribed-rate buffer of the
    sparse exchange (the kernel then holds O(subs_cap) rate state in VMEM
    instead of O(R·n)); bg_mean/bg_std: scalar or (n,); chunk/rank: traced
    i32 scalars; izh: 6-tuple, scalar or (n,); stim/lesions: protocol
    tables (see ``scenarios.protocol.stim_tables``/``lesion_tables``).
    Returns ``(state7, spikes_per_step)`` — the updated 7-tuple (inputs
    donated via input_output_aliases) plus the (num_steps,) f32 per-step
    fired counts (each grid step writes one slot of an unaliased output;
    the telemetry spikes-per-step signal, identical to the reference scan's
    per-step reduction)."""
    n = state[0].shape[0]
    s_max = in_edges.shape[1]
    f32 = jnp.float32
    vec = lambda x: jnp.broadcast_to(jnp.asarray(x, f32), (n,))  # noqa: E731
    bg_mean, bg_std = vec(bg_mean), vec(bg_std)
    izh = tuple(vec(x) for x in izh)
    scal = jnp.stack([jnp.asarray(chunk, jnp.int32),
                      jnp.asarray(rank, jnp.int32)])

    row = pl.BlockSpec((n,), lambda t: (0,))
    operands = list(state) + [in_edges, w_table, rates]
    in_specs = [row] * _N_STATE + [
        pl.BlockSpec((n, s_max), lambda t: (0, 0)),       # in_edges
        row,                                              # w_table
        # rates: dense (R, n) table or sparse (subs_cap,) compact buffer
        pl.BlockSpec(rates.shape, lambda t: (0,) * rates.ndim),
    ]
    if rate_slots is not None:
        operands.append(rate_slots)
        in_specs.append(pl.BlockSpec((n, s_max), lambda t: (0, 0)))
    operands += [bg_mean, bg_std, *izh, scal]
    in_specs += [
        row, row,                                         # bg_mean, bg_std
        *([row] * 6),                                     # izh
        pl.BlockSpec((2,), lambda t: (0,)),               # chunk, rank
    ]
    stim_meta = lesion_meta = None
    if stim is not None:
        masks, stim_meta = stim
        operands.append(masks)
        in_specs.append(pl.BlockSpec(masks.shape, lambda t: (0, 0)))
    if lesions is not None:
        masks, lesion_meta = lesions
        operands.append(masks)
        in_specs.append(pl.BlockSpec(masks.shape, lambda t: (0, 0)))

    out_shape = [jax.ShapeDtypeStruct((n,), f32)] * 5 + \
        [jax.ShapeDtypeStruct((n,), jnp.bool_),
         jax.ShapeDtypeStruct((n,), f32),
         jax.ShapeDtypeStruct((num_steps,), f32)]   # per-step fired counts
    kernel = functools.partial(
        _window_kernel, n_in=len(operands), num_steps=num_steps, seed=seed,
        ca_consts=(float(ca_consts[0]), float(ca_consts[1])), n=n,
        stim_meta=stim_meta, lesion_meta=lesion_meta,
        has_slots=rate_slots is not None)
    res = pl.pallas_call(
        kernel, grid=(num_steps,), in_specs=in_specs,
        out_specs=[row] * _N_STATE + [pl.BlockSpec((1,), lambda t: (t,))],
        out_shape=out_shape,
        input_output_aliases={i: i for i in range(_N_STATE)},
        interpret=interpret,
    )(*operands)
    return tuple(res[:_N_STATE]), res[_N_STATE]


def window_hbm_bytes(n: int, s_max: int, num_ranks: int,
                     num_stim: int = 0, num_lesions: int = 0, *,
                     subs_cap=None, num_steps: int = 100) -> int:
    """Analytic HBM traffic of one fused window on TPU: each operand is
    streamed HBM->VMEM once and the 7 state outputs written back once —
    there are no per-step HBM temporaries (that is the point). Used by
    ``benchmarks/bench_activity.py`` against the roofline byte count of the
    reference lowering.

    ``subs_cap=None`` models the dense exchange (the replicated (R, n)
    rates table streams in); an integer models the sparse exchange (the
    compact (subs_cap,) rate buffer plus the (n, s_max) slot remap);
    ``num_steps`` sizes the (T,) per-step spike-count telemetry output."""
    state_in = 6 * 4 * n + n                 # 6 f32 vectors + bool spiked
    if subs_cap is None:
        rate_bytes = num_ranks * n * 4       # dense (R, n) table
    else:
        rate_bytes = subs_cap * 4 + s_max * 4 * n   # compact buffer + slots
    tables = (s_max * 4 * n                  # in_edges
              + 4 * n                        # w_table
              + rate_bytes
              + 2 * 4 * n                    # bg mean/std
              + 6 * 4 * n                    # izh params
              + 8                            # chunk, rank
              + num_stim * 4 * n + num_lesions * n)
    state_out = state_in
    spk_out = 4 * num_steps                  # (T,) per-step fired counts
    return state_in + tables + state_out + spk_out
