"""Pallas TPU kernel for the MSP pairwise Gaussian connection-probability —
the compute hot-spot of the paper's synapse-formation phase (55% of the
optimized runtime in paper Fig. 11 is Barnes-Hut computation, and this kernel
is its inner loop: probability evaluation between searchers and candidates).

P[i, j] = w[j] * exp(-||x_i - y_j||^2 / sigma^2)

TPU adaptation: the distance matrix is evaluated via the MXU-friendly identity
||x-y||^2 = |x|^2 + |y|^2 - 2 x.y, with the 3-wide coordinate axis zero-padded
to 8 lanes so the (bn, 8) x (8, bm) dot maps onto the systolic array; the
rest is VPU elementwise. Tiles are (block_n x block_m) in VMEM.

Also exposes a fused row-sum (the normalization the direct O(n^2) evaluation
needs), accumulated across the m-grid in VMEM scratch.

Precision caveat: the MXU identity cancels catastrophically for near-zero
distances; the resulting |d2| error (~1e-6) is amplified by exp(-d2/sigma^2)
when sigma is small (relative error ~1e-6/sigma^2). For the MSP's sigma=0.25
this is ~2e-5 — acceptable; below sigma~0.05 prefer the direct VPU form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PAD = 8  # coordinate lanes (3 -> 8 for MXU alignment)


def _kernel(x_ref, y_ref, w_ref, p_ref, rs_ref, acc_scr, *, sigma: float,
            bn: int, bm: int):
    mi = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]                                  # (bn, PAD)
    y = y_ref[...]                                  # (bm, PAD)
    w = w_ref[...]                                  # (bm,)
    xx = jnp.sum(x * x, axis=-1, keepdims=True)     # (bn, 1)
    yy = jnp.sum(y * y, axis=-1)[None, :]           # (1, bm)
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    p = w[None, :] * jnp.exp(-d2 / (sigma * sigma))
    p_ref[...] = p.astype(p_ref.dtype)
    acc_scr[...] = acc_scr[...] + jnp.sum(p, axis=-1)

    @pl.when(mi == nm - 1)
    def _fin():
        rs_ref[...] = acc_scr[...].astype(rs_ref.dtype)


def bh_gauss_probs(x, y, w, *, sigma: float, block_n=256, block_m=256,
                   interpret=False):
    """x: (N, 3) searcher positions; y: (M, 3) candidate positions;
    w: (M,) vacant-element weights. Returns (P (N, M), rowsum (N,)).

    n/m that are not multiples of the block are padded up to it and the
    outputs sliced (padded candidates carry w=0, so P and the row-sum are
    untouched) — shrinking the block to a divisor would degrade to block=1
    for prime sizes (the same fix ``neuron_step`` got)."""
    n, _ = x.shape
    m, _ = y.shape
    bn = min(block_n, n)
    bm = min(block_m, m)
    n_pad = -(-n // bn) * bn
    m_pad = -(-m // bm) * bm
    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, PAD - 3)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, m_pad - m), (0, PAD - 3)))
    wp = jnp.pad(w.astype(jnp.float32), (0, m_pad - m))
    kern = functools.partial(_kernel, sigma=sigma, bn=bn, bm=bm)
    p, rs = pl.pallas_call(
        kern,
        grid=(n_pad // bn, m_pad // bm),
        in_specs=[
            pl.BlockSpec((bn, PAD), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((bm, PAD), lambda ni, mi: (mi, 0)),
            pl.BlockSpec((bm,), lambda ni, mi: (mi,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda ni, mi: (ni, mi)),
            pl.BlockSpec((bn,), lambda ni, mi: (ni,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n_pad, m_pad), jnp.float32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)],
        interpret=interpret,
    )(xp, yp, wp)
    if n_pad != n or m_pad != m:
        p, rs = p[:n, :m], rs[:n]
    return p, rs
