"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """Naive full-softmax attention. q: (B,Hq,S,D); k,v: (B,Hkv,S,D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window and window > 0:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def bh_gauss_ref(x, y, w, *, sigma: float):
    """P[i,j] = w_j exp(-||x_i-y_j||^2/sigma^2) and its row sums."""
    d2 = jnp.sum(jnp.square(x[:, None, :].astype(jnp.float32)
                            - y[None, :, :].astype(jnp.float32)), axis=-1)
    p = w[None, :].astype(jnp.float32) * jnp.exp(-d2 / (sigma * sigma))
    return p, jnp.sum(p, axis=-1)


def activity_window_ref(state, in_edges, w_table, rates, bg_mean, bg_std,
                        chunk, rank, *, seed: int, num_steps: int, izh,
                        ca_consts, stim=None, lesions=None, rate_slots=None):
    """jnp oracle for ``activity_fused.activity_window``: the same
    ``step_core`` math scanned over the window with ``jax.lax.scan``.
    Returns ``(state7, spikes_per_step)`` like the kernel does. The Pallas
    kernel must match this bit-for-bit in interpret mode
    (tests/test_activity_fused.py)."""
    from repro.kernels.activity_fused import step_core
    n = state[0].shape[0]
    chunk = jnp.asarray(chunk, jnp.int32)

    def step(carry, t):
        new = step_core(carry, in_edges, w_table, rates, bg_mean, bg_std,
                        izh, ca_consts, seed, chunk * num_steps + t, rank,
                        n, stim=stim, lesions=lesions, rate_slots=rate_slots)
        return new, jnp.sum(new[5].astype(jnp.float32))

    out, spikes_per_step = jax.lax.scan(step, tuple(state),
                                        jnp.arange(num_steps, dtype=jnp.int32))
    return out, spikes_per_step


def neuron_step_ref(v, u, ca, ax, de, inp, cfg, params=None):
    """Mirror of repro.core.neuron.update_activity + update_elements.
    ``params`` (NeuronParams, scalar or per-neuron) overrides BrainConfig."""
    from repro.core.neuron import params_from_config
    p = params or params_from_config(cfg)
    a, b, c, d = p.izh_a, p.izh_b, p.izh_c, p.izh_d
    nu, eps = p.growth_rate, p.target_calcium
    for _ in range(2):
        v = v + 0.5 * (0.04 * v * v + 5.0 * v + 140.0 - u + inp)
    u = u + a * (b * v - u)
    spiked = v >= 30.0
    v = jnp.where(spiked, c, v)
    u = jnp.where(spiked, u + d, u)
    ca = ca + (-ca * cfg.calcium_decay + cfg.calcium_beta * spiked)
    drive = nu * (1.0 - ca / eps)
    ax = jnp.maximum(ax + drive, 0.0)
    de = jnp.maximum(de + drive, 0.0)
    return v, u, ca, ax, de, spiked
