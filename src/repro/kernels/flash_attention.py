"""Pallas TPU flash-attention forward (causal / sliding-window, GQA-aware).

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv dimension is innermost and
sequential on TPU, so (m, l, acc) live in VMEM scratch across kv steps.
BlockSpec index maps pull the matching KV head for GQA (kv_head = q_head // g)
without materializing repeated K/V. Fully-masked causal tiles are skipped via
pl.when — on TPU that prunes ~half the kv loop.

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py);
on-device it replaces the pure-JAX chunked attention in models/attention.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, bq: int, bk: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # tile-level skip: causal tile fully above the diagonal / out of window
    live = True
    if causal:
        live = (ki * bk) <= (qi * bq + bq - 1)
    if window and window > 0:
        live = live & ((qi * bq) - (ki * bk + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                                   # (bq, d)
        k = k_ref[0, 0]                                   # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= k_pos <= q_pos
        if window and window > 0:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, block_q=128,
                        block_k=128, interpret=False):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D) -> (B, Hq, S, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    while sq % bq:
        bq -= 1
    while skv % bk:
        bk -= 1
    grid = (b, hq, sq // bq, skv // bk)
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(_kernel, causal=causal, window=window,
                             bq=bq, bk=bk, scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki, g_=g: (b_, h // g_, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki, g_=g: (b_, h // g_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
