"""Pallas TPU kernel: stable LSD radix argsort over non-negative int32 keys,
plus the fused Morton-sort entry point that feeds the on-device octree build.

The reference tree build (``connectome/tree.py``) is "host-shaped": it runs
``jnp.argsort(stable=True)`` + ``searchsorted`` + a full-length rank scatter
per update, and on CPU XLA the 32K-element scatters serialize into
per-element while loops that the trip-count-aware roofline prices at
gigabytes. This kernel keeps the whole sort VMEM-resident: per 8-bit digit
it builds a 256-bucket histogram (scatter-add), turns it into bucket starts
(exclusive cumsum — the integer equivalent of ``searchsorted`` over a dense
key range), and derives each element's stable within-bucket rank with a
cumsum per bucket. Ranks are *defined* identically to
``jnp.argsort(stable=True)`` — position = #{smaller keys} + #{equal keys
earlier in buffer order} — and every quantity is integer arithmetic, so
``radix_argsort`` is bit-identical to the stable argsort (asserted on
adversarial inputs in tests/test_radix_sort.py), which makes the fused tree
build bit-identical to the reference.

``morton_sort`` composes the Morton encode (``core/morton.py``) with one
radix sort over the relative leaf cells and returns (rel, slot): exactly the
(``rel``, ``positions_within(rel, n_leaf)``) pair the reference build
computes, without any sort/scatter leaving the kernel. Like the other
kernels here, CPU containers run it with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import morton

DIGIT_BITS = 8


def bucket_ranks(keys, num_buckets: int):
    """Stable rank of each element WITHIN its bucket — #{j < i: keys[j] ==
    keys[i]}, exactly ``tree.positions_within`` — via one masked cumsum per
    bucket: O(B*n) VPU work, O(n) memory, no sort and no full-length
    scatter. ``keys`` must lie in [0, num_buckets)."""
    n = keys.shape[0]

    def body(b, within):
        eq = keys == b
        return jnp.where(eq, jnp.cumsum(eq.astype(jnp.int32)) - 1, within)

    return jax.lax.fori_loop(0, num_buckets, body,
                             jnp.zeros((n,), jnp.int32))


def stable_ranks(keys, num_buckets: int):
    """Stable GLOBAL rank of each element under an ascending bucket sort:
    ``rank[i] = #{j: keys[j] < keys[i]} + #{j < i: keys[j] == keys[i]}`` —
    the position ``jnp.argsort(keys, stable=True)`` assigns. Histogram
    (scatter-add) + exclusive cumsum for the bucket starts (the integer
    equivalent of ``searchsorted``), plus the within-bucket ranks. Shared
    by the kernel bodies and usable as a plain jnp op."""
    hist = jnp.zeros((num_buckets,), jnp.int32).at[keys].add(jnp.int32(1))
    start = jnp.cumsum(hist) - hist
    return start[keys] + bucket_ranks(keys, num_buckets)


def radix_ranks(keys, key_bits: int):
    """Stable ascending sort rank of each element of ``keys`` (non-negative
    int32, < 2**key_bits): LSD radix — one ``stable_ranks`` pass per 8-bit
    digit, permuting (key, original-index) pairs between passes. Stability
    of every pass makes the composition stable, so the result equals the
    inverse permutation of ``jnp.argsort(keys, stable=True)``."""
    n = keys.shape[0]
    k = keys.astype(jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    for shift in range(0, max(key_bits, 1), DIGIT_BITS):
        digit = (k >> shift) & ((1 << DIGIT_BITS) - 1)
        r = stable_ranks(digit, 1 << DIGIT_BITS)
        k = jnp.zeros_like(k).at[r].set(k)
        idx = jnp.zeros_like(idx).at[r].set(idx)
    # idx[r] = original position of sort rank r; invert to rank-per-element
    return jnp.zeros_like(idx).at[idx].set(jnp.arange(n, dtype=jnp.int32))


def _argsort_kernel(keys_ref, sorted_ref, order_ref, *, key_bits):
    keys = keys_ref[...]
    n = keys.shape[0]
    rank = radix_ranks(keys, key_bits)
    sorted_ref[...] = jnp.zeros_like(keys).at[rank].set(keys)
    order_ref[...] = jnp.zeros((n,), jnp.int32).at[rank].set(
        jnp.arange(n, dtype=jnp.int32))


def radix_argsort(keys, *, key_bits: int = 30, interpret: bool = False):
    """Stable ascending argsort of (n,) non-negative int32 ``keys`` in one
    VMEM-resident pass. Returns ``(sorted_keys, order)`` with ``order``
    bit-identical to ``jnp.argsort(keys, stable=True)``. ``key_bits`` bounds
    the key range (30 covers Morton codes at ``morton.MAX_LEVEL``)."""
    n = keys.shape[0]
    full = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_argsort_kernel, key_bits=key_bits),
        grid=(1,),
        in_specs=[full],
        out_specs=[full, full],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(keys.astype(jnp.int32))


def _morton_sort_kernel(pos_ref, base_ref, rel_ref, slot_ref, *, leaf_level,
                        n_leaf, key_bits):
    rel = morton.morton_encode(pos_ref[...], leaf_level) - base_ref[0]
    rel = jnp.clip(rel, 0, n_leaf - 1)
    rank = radix_ranks(rel, key_bits)
    hist = jnp.zeros((n_leaf,), jnp.int32).at[rel].add(jnp.int32(1))
    first = jnp.cumsum(hist) - hist
    rel_ref[...] = rel
    # global stable sort rank minus the cell's first rank = within-cell rank
    slot_ref[...] = rank - first[rel]


def morton_sort(positions, leaf_base, *, leaf_level: int, n_leaf: int,
                interpret: bool = False):
    """Morton-encode (n, 3) positions at ``leaf_level``, rebase to the
    rank's block (``leaf_base`` = base_cell * 8**local_levels, traced scalar
    ok), and radix-sort the relative cells on-device. Returns ``(rel,
    slot)`` — bit-identical to the reference path ``rel = clip(encode -
    leaf_base); slot = positions_within(rel, n_leaf)``."""
    n = positions.shape[0]
    key_bits = max((n_leaf - 1).bit_length(), 1)
    base = jnp.reshape(jnp.asarray(leaf_base, jnp.int32), (1,))
    kern = functools.partial(_morton_sort_kernel, leaf_level=leaf_level,
                             n_leaf=n_leaf, key_bits=key_bits)
    row = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, 3), lambda i: (0, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[row, row],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(positions, base)


def radix_sort_hbm_bytes(n: int) -> int:
    """Analytic HBM traffic of one fused ``radix_argsort`` on TPU: keys
    stream in once, (sorted, order) stream out once — histograms, bucket
    starts, and the per-pass permutations never leave VMEM."""
    return n * 4 + 2 * n * 4


def morton_sort_hbm_bytes(n: int) -> int:
    """Analytic HBM traffic of one fused ``morton_sort`` on TPU: positions
    + the base scalar in once, (rel, slot) out once."""
    return n * 3 * 4 + 4 + 2 * n * 4
