"""Counter-based PRNG shared by the fused activity megakernel and its oracle.

The engine's activity phase needs randomness that is (a) reproducible from
pure integers — ``(seed, domain, step, entity-id)`` — so the fused Pallas
kernel and the jnp reference path can draw bit-identical streams without
threading key arrays through HBM, and (b) cheap vector math (add / xor /
rotate on u32), so it runs on the VPU inside the kernel.

We use the full 20-round Threefry-2x32 block cipher (Salmon et al. 2011,
the same primitive behind ``jax.random``'s default implementation) with the
key derived from ``(seed, domain)`` and the counter from ``(step, entity)``.
Every function here is plain ``jnp`` elementwise math: the *same* Python
code executes inside a Pallas kernel body and in the reference scan, which
is what makes fused == reference bit-for-bit (DESIGN.md §5).

Entity ids: per-neuron streams use the global neuron id, per-edge streams
use ``dst_gid * s_max + slot``. Ids are folded mod 2^32 — collisions across
domains are prevented by the domain word in the key.

The Barnes-Hut traversal (repro.connectome.traverse and the Pallas kernel
kernels/bh_traverse.py) draws its Gumbels from the same primitive, keyed by
``(seed, BH_DOMAIN, bh_ctr(chunk, round, draw), source_gid)`` — the
counter packs the restart round and the frontier/member draw slot.
"""
from __future__ import annotations

import jax.numpy as jnp

# Domain separators (arbitrary distinct u32 constants).
NOISE_DOMAIN = 0x6E6F6973    # per-neuron background-noise gaussians
SPIKE_DOMAIN = 0x73706B73    # per-edge Bernoulli(rate) reconstruction
BH_DOMAIN = 0x62687472       # Barnes-Hut traversal/member Gumbel draws

# Barnes-Hut counter layout (see bh_ctr): each chunk owns BH_ROUNDS round
# slots, each round BH_DRAWS draw slots. Phase A expands from round 0,
# phase B from round 16, and member selection uses the last round — so the
# three stages of one searcher's chunk never share a counter. Static caps:
# frontier_cap and members_cap must be <= BH_DRAWS (checked at trace time).
BH_ROUNDS = 64
BH_DRAWS = 128

_PARITY = 0x1BD11BDA         # threefry key-schedule parity constant
_ROT_A = (13, 15, 26, 6)     # rotation schedule, even 4-round groups
_ROT_B = (17, 29, 16, 24)    # rotation schedule, odd 4-round groups


def _u32(x):
    if isinstance(x, int):   # Python ints >= 2^31 overflow the i32 default
        return jnp.uint32(x & 0xFFFFFFFF)
    return jnp.asarray(x).astype(jnp.uint32)


def _rotl(x, r: int):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Full 20-round Threefry-2x32: key (k0, k1), counter (c0, c1).
    All args int scalars/arrays (broadcast together); returns two u32."""
    k0, k1, x0, x1 = _u32(k0), _u32(k1), _u32(c0), _u32(c1)
    k2 = k0 ^ k1 ^ jnp.uint32(_PARITY)
    ks = (k0, k1, k2)
    x0 = x0 + k0
    x1 = x1 + k1
    for g in range(5):
        rots = _ROT_A if g % 2 == 0 else _ROT_B
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        x0 = x0 + ks[(g + 1) % 3]
        x1 = x1 + ks[(g + 2) % 3] + jnp.uint32(g + 1)
    return x0, x1


def bits(seed: int, domain: int, ctr, entity):
    """Two u32 words of hash output for (seed, domain, ctr, entity)."""
    return threefry2x32(seed, domain, ctr, entity)


def _to_unit(word):
    """u32 -> f32 uniform in [0, 1): top 24 bits, exactly representable."""
    return (word >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def uniform(seed: int, domain: int, ctr, entity):
    """f32 uniform in [0, 1), elementwise over broadcast(ctr, entity)."""
    x0, _ = bits(seed, domain, ctr, entity)
    return _to_unit(x0)


def bh_ctr(chunk, rnd, draw):
    """Pack the Barnes-Hut (chunk, round, draw) triple into one u32 counter.
    Wraps mod 2^32 after ~524k chunks — harmless (the stream stays keyed and
    reproducible; only cross-epoch decorrelation would degrade)."""
    return (jnp.asarray(chunk, jnp.int32) * BH_ROUNDS + rnd) * BH_DRAWS + draw


def gumbel(seed: int, domain: int, ctr, entity):
    """f32 standard Gumbel, elementwise over broadcast(ctr, entity).
    u is clamped away from 0 so both logs stay finite."""
    u = uniform(seed, domain, ctr, entity)
    return -jnp.log(-jnp.log(jnp.maximum(u, jnp.float32(1e-20))))


def normal(seed: int, domain: int, ctr, entity):
    """f32 standard normal via Box-Muller on the two hash words.
    1-u1 lies in (2^-24, 1], so the log never sees zero."""
    x0, x1 = bits(seed, domain, ctr, entity)
    u1 = _to_unit(x0)
    u2 = _to_unit(x1)
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log1p(-u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.14159265358979) * u2)
