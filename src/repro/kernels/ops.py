"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body is
executed in Python per grid step — correctness only). On TPU, set
``REPRO_PALLAS=device`` (or pass interpret=False) for the compiled path.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels.activity_fused import activity_window
from repro.kernels.bh_gauss import bh_gauss_probs
from repro.kernels.bh_traverse import bh_traverse as bh_traverse_kernel
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.neuron_step import neuron_step
from repro.kernels.radix_sort import morton_sort as morton_sort_kernel
from repro.kernels.radix_sort import radix_argsort as radix_argsort_kernel
from repro.kernels.synapse_apply import route_build as route_build_kernel
from repro.kernels.synapse_apply import synapse_apply as synapse_apply_kernel


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS", "") == "device":
        return False
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("sigma", "interpret"))
def gauss_probs(x, y, w, *, sigma: float, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return bh_gauss_probs(x, y, w, sigma=sigma, interpret=interpret)


def fused_neuron_step(v, u, ca, ax, de, inp, cfg, *, params=None,
                      interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return neuron_step(v, u, ca, ax, de, inp, cfg, params=params,
                       interpret=interpret)


def bh_traverse(counts, cents, members, npos, vac, x, start_cell, src_gid,
                valid, chunk, gid_base, *, seed, sizes, theta, sigma,
                frontier, n_levels, interpret=None):
    """Phase-B Barnes-Hut traversal kernel (see kernels/bh_traverse.py).
    Not jitted here: it runs inside the engine's jitted shard_map."""
    if interpret is None:
        interpret = _interpret_default()
    return bh_traverse_kernel(counts, cents, members, npos, vac, x,
                              start_cell, src_gid, valid, chunk, gid_base,
                              seed=seed, sizes=sizes, theta=theta,
                              sigma=sigma, frontier=frontier,
                              n_levels=n_levels, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("key_bits", "interpret"))
def radix_argsort(keys, *, key_bits: int = 30, interpret=None):
    """Stable radix argsort of non-negative int32 keys — returns
    (sorted_keys, order), bit-identical to ``jnp.argsort(stable=True)``
    (kernels/radix_sort.py). The reusable sort primitive."""
    if interpret is None:
        interpret = _interpret_default()
    return radix_argsort_kernel(keys, key_bits=key_bits, interpret=interpret)


def morton_sort(positions, leaf_base, *, leaf_level: int, n_leaf: int,
                interpret=None):
    """Fused Morton encode + radix sort feeding the on-device tree build
    (kernels/radix_sort.py). Not jitted here: it runs inside the engine's
    jitted shard_map."""
    if interpret is None:
        interpret = _interpret_default()
    return morton_sort_kernel(positions, leaf_base, leaf_level=leaf_level,
                              n_leaf=n_leaf, interpret=interpret)


def synapse_apply(edges, msg_lid, msg_gid, msg_valid, req_lid, req_src,
                  req_valid, req_prio, vacant_d, *, interpret=None):
    """Fused remove -> compact -> accept pass over one edge table
    (kernels/synapse_apply.py). Not jitted here: it runs inside the
    engine's jitted shard_map."""
    if interpret is None:
        interpret = _interpret_default()
    return synapse_apply_kernel(edges, msg_lid, msg_gid, msg_valid, req_lid,
                                req_src, req_valid, req_prio, vacant_d,
                                interpret=interpret)


def route_build(flat_other, flat_mine, *, n: int, num_ranks: int, cap: int,
                interpret=None):
    """Fused deletion-routing buffer build (kernels/synapse_apply.py). Not
    jitted here: it runs inside the engine's jitted shard_map."""
    if interpret is None:
        interpret = _interpret_default()
    return route_build_kernel(flat_other, flat_mine, n=n,
                              num_ranks=num_ranks, cap=cap,
                              interpret=interpret)


def fused_activity_window(state, in_edges, w_table, rates, bg_mean, bg_std,
                          chunk, rank, *, seed, num_steps, izh, ca_consts,
                          stim=None, lesions=None, rate_slots=None,
                          interpret=None):
    """Whole-rate-window activity megakernel (see kernels/activity_fused.py).
    ``rate_slots`` selects the sparse-exchange operand layout (compact
    subscribed-rate buffer + edge→slot remap instead of the (R, n) table).
    Not jitted here: it runs inside the engine's jitted shard_map."""
    if interpret is None:
        interpret = _interpret_default()
    return activity_window(state, in_edges, w_table, rates, bg_mean, bg_std,
                           chunk, rank, seed=seed, num_steps=num_steps,
                           izh=izh, ca_consts=ca_consts, stim=stim,
                           lesions=lesions, rate_slots=rate_slots,
                           interpret=interpret)
