"""Pallas TPU kernel: fused per-neuron state update — Izhikevich integration,
calcium trace, and synaptic-element growth in one VPU pass ("Actual activity
update" + "Update of synaptic elements" in paper Fig. 11, ~16% of the
optimized runtime; fusing them removes two HBM round-trips over the state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, u_ref, ca_ref, ax_ref, de_ref, inp_ref,
            v_o, u_o, ca_o, ax_o, de_o, sp_o, *, p):
    v = v_ref[...]
    u = u_ref[...]
    i_t = inp_ref[...]
    for _ in range(2):  # two half-ms Euler steps (Izhikevich reference impl)
        v = v + 0.5 * (0.04 * v * v + 5.0 * v + 140.0 - u + i_t)
    u = u + p["a"] * (p["b"] * v - u)
    spiked = v >= 30.0
    v = jnp.where(spiked, p["c"], v)
    u = jnp.where(spiked, u + p["d"], u)
    ca = ca_ref[...]
    ca = ca + (-ca * p["ca_decay"] + p["ca_beta"] * spiked)
    drive = p["nu"] * (1.0 - ca / p["eps"])
    v_o[...] = v
    u_o[...] = u
    ca_o[...] = ca
    ax_o[...] = jnp.maximum(ax_ref[...] + drive, 0.0)
    de_o[...] = jnp.maximum(de_ref[...] + drive, 0.0)
    sp_o[...] = spiked


def neuron_step(v, u, ca, ax, de, inp, cfg, *, block=1024, interpret=False):
    """All inputs (N,) f32. Returns (v, u, ca, ax, de, spiked)."""
    n = v.shape[0]
    b = min(block, n)
    while n % b:
        b -= 1
    p = {"a": cfg.izh_a, "b": cfg.izh_b, "c": cfg.izh_c, "d": cfg.izh_d,
         "ca_decay": cfg.calcium_decay, "ca_beta": cfg.calcium_beta,
         "nu": cfg.element_growth_rate, "eps": cfg.target_calcium}
    spec = pl.BlockSpec((b,), lambda i: (i,))
    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_kernel, p=p),
        grid=(n // b,),
        in_specs=[spec] * 6,
        out_specs=[spec] * 6,
        out_shape=[jax.ShapeDtypeStruct((n,), f32)] * 5
        + [jax.ShapeDtypeStruct((n,), jnp.bool_)],
        interpret=interpret,
    )(v, u, ca, ax, de, inp)
