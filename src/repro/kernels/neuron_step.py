"""Pallas TPU kernel: fused per-neuron state update — Izhikevich integration,
calcium trace, and synaptic-element growth in one VPU pass ("Actual activity
update" + "Update of synaptic elements" in paper Fig. 11, ~16% of the
optimized runtime; fusing them removes two HBM round-trips over the state).

Heterogeneous populations (repro.scenarios.populations) make the Izhikevich
constants a/b/c/d, the growth rate nu, and the calcium target eps per-neuron
``(n,)`` arrays; they stream through the same block pipeline as the state so
mixed RS/FS/CH/IB sheets cost one fused pass too. The homogeneous path keeps
every constant compile-time (no extra HBM reads). The global calcium
kinetics (decay, beta) are always compile-time scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _integrate(v, u, ca, ax, de, i_t, a, b, c, d, nu, eps, p):
    """Shared math; a..eps are scalars or blocks matching v."""
    for _ in range(2):  # two half-ms Euler steps (Izhikevich reference impl)
        v = v + 0.5 * (0.04 * v * v + 5.0 * v + 140.0 - u + i_t)
    u = u + a * (b * v - u)
    spiked = v >= 30.0
    v = jnp.where(spiked, c, v)
    u = jnp.where(spiked, u + d, u)
    ca = ca + (-ca * p["ca_decay"] + p["ca_beta"] * spiked)
    drive = nu * (1.0 - ca / eps)
    return v, u, ca, jnp.maximum(ax + drive, 0.0), \
        jnp.maximum(de + drive, 0.0), spiked


def _kernel_homog(v_ref, u_ref, ca_ref, ax_ref, de_ref, inp_ref,
                  v_o, u_o, ca_o, ax_o, de_o, sp_o, *, p):
    out = _integrate(v_ref[...], u_ref[...], ca_ref[...], ax_ref[...],
                     de_ref[...], inp_ref[...],
                     p["a"], p["b"], p["c"], p["d"], p["nu"], p["eps"], p)
    for ref, val in zip((v_o, u_o, ca_o, ax_o, de_o, sp_o), out):
        ref[...] = val


def _kernel_hetero(v_ref, u_ref, ca_ref, ax_ref, de_ref, inp_ref,
                   a_ref, b_ref, c_ref, d_ref, nu_ref, eps_ref,
                   v_o, u_o, ca_o, ax_o, de_o, sp_o, *, p):
    out = _integrate(v_ref[...], u_ref[...], ca_ref[...], ax_ref[...],
                     de_ref[...], inp_ref[...],
                     a_ref[...], b_ref[...], c_ref[...], d_ref[...],
                     nu_ref[...], eps_ref[...], p)
    for ref, val in zip((v_o, u_o, ca_o, ax_o, de_o, sp_o), out):
        ref[...] = val


def neuron_step(v, u, ca, ax, de, inp, cfg, *, params=None, block=1024,
                interpret=False):
    """All inputs (N,) f32. Returns (v, u, ca, ax, de, spiked).

    ``params`` is an optional NeuronParams. Python-scalar entries (or
    params=None, the homogeneous BrainConfig constants) stay compile-time;
    per-neuron arrays stream through the block pipeline.

    n that is not a multiple of the block is padded up to it (zero lanes
    integrate harmlessly and are sliced off) — shrinking the block to a
    divisor would degrade to block=1 for prime n."""
    n = v.shape[0]
    b = min(block, n)
    n_pad = -(-n // b) * b

    def pad(x):
        return jnp.pad(x, (0, n_pad - n)) if n_pad != n else x

    v, u, ca, ax, de, inp = (pad(x) for x in (v, u, ca, ax, de, inp))
    if params is None:
        vals = (cfg.izh_a, cfg.izh_b, cfg.izh_c, cfg.izh_d,
                cfg.element_growth_rate, cfg.target_calcium)
    else:
        vals = (params.izh_a, params.izh_b, params.izh_c, params.izh_d,
                params.growth_rate, params.target_calcium)
    p = {"ca_decay": cfg.calcium_decay, "ca_beta": cfg.calcium_beta}
    spec = pl.BlockSpec((b,), lambda i: (i,))
    f32 = jnp.float32
    out_shape = [jax.ShapeDtypeStruct((n_pad,), f32)] * 5 \
        + [jax.ShapeDtypeStruct((n_pad,), jnp.bool_)]
    homogeneous = all(not hasattr(x, "ndim") or x.ndim == 0 for x in vals)
    if homogeneous:
        p.update(dict(zip(("a", "b", "c", "d", "nu", "eps"),
                          (float(x) for x in vals))))
        outs = pl.pallas_call(
            functools.partial(_kernel_homog, p=p),
            grid=(n_pad // b,), in_specs=[spec] * 6, out_specs=[spec] * 6,
            out_shape=out_shape, interpret=interpret,
        )(v, u, ca, ax, de, inp)
    else:
        per_neuron = [pad(jnp.broadcast_to(jnp.asarray(x, f32), (n,)))
                      for x in vals]
        outs = pl.pallas_call(
            functools.partial(_kernel_hetero, p=p),
            grid=(n_pad // b,), in_specs=[spec] * 12, out_specs=[spec] * 6,
            out_shape=out_shape, interpret=interpret,
        )(v, u, ca, ax, de, inp, *per_neuron)
    return tuple(o[:n] for o in outs) if n_pad != n else outs
