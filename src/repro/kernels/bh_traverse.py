"""Pallas TPU kernel: phase-B Barnes-Hut traversal — frontier expansion +
Gumbel-max sampling + leaf member selection in one pass per query block.

Paper Fig. 11 attributes ~55% of the optimized runtime to Barnes-Hut
computation; this kernel keeps its whole working set — the stacked subtree
levels (counts + centroids), the leaf membership table, and the subtree's
neuron data — VMEM-resident while a block of queries runs the full restart
loop, instead of re-streaming (Q, F) frontier temporaries through HBM every
expansion round like the reference lowering does.

The kernel body executes ``repro.connectome.traverse.phase_b_core`` — the
same jnp math as the reference path, including the ``bh_gauss`` MXU distance
identity (|x|^2+|y|^2-2<x,y> over 8 zero-padded lanes) for node and member
probabilities, and the counter-based Threefry Gumbel stream keyed by
``(seed, chunk, source_gid, round, draw)`` (kernels/hash.py). Every op is
row-independent over queries, so blocking cannot change results:
``connectivity_impl='fused'`` is bit-identical to ``'reference'``
(tests/test_connectome.py). Like the other kernels here, CPU containers run
it with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.connectome.traverse import phase_b_core


def _kernel(counts_ref, cents_ref, members_ref, npos_ref, vac_ref, x_ref,
            start_ref, gid_ref, valid_ref, scal_ref, tgt_ref, ok_ref,
            depth_ref, *, seed, sizes, theta, sigma, frontier, n_levels):
    chunk = scal_ref[0]
    gid_base = scal_ref[1]
    tgt, ok, depth = phase_b_core(
        counts_ref[...], cents_ref[...], members_ref[...], npos_ref[...],
        vac_ref[...], x_ref[...], start_ref[...], gid_ref[...],
        valid_ref[...], chunk, gid_base, seed=seed, sizes=sizes, theta=theta,
        sigma=sigma, frontier=frontier, n_levels=n_levels)
    tgt_ref[...] = tgt.astype(jnp.int32)
    ok_ref[...] = ok
    depth_ref[...] = depth.astype(jnp.int32)


def bh_traverse(counts, cents, members, npos, vac, x, start_cell, src_gid,
                valid, chunk, gid_base, *, seed: int, sizes, theta: float,
                sigma: float, frontier: int, n_levels: int, block_q: int = 128,
                interpret: bool = False):
    """Phase-B search for Q queries against one subtree.

    counts: (L, C) f32; cents: (L, C, 3) f32; members: (n_leaf, M) i32;
    npos: (N, 3) f32; vac: (N,) f32; x: (Q, 3); start_cell/src_gid: (Q,)
    i32; valid: (Q,) bool; chunk/gid_base: traced i32 scalars; sizes: static
    per-level cell edge lengths. Returns (target_gid (Q,) i32, valid (Q,),
    depth (Q,) i32 restart rounds — the telemetry frontier-depth signal).

    Q that is not a multiple of the block is padded up to it (padded rows
    carry valid=False and are sliced off — same fix as ``neuron_step``)."""
    q = x.shape[0]
    bq = min(block_q, q)
    qp = -(-q // bq) * bq
    if qp != q:
        pad = qp - q
        x = jnp.pad(x, ((0, pad), (0, 0)))
        start_cell = jnp.pad(start_cell, (0, pad))
        src_gid = jnp.pad(src_gid, (0, pad), constant_values=-2)
        valid = jnp.pad(valid, (0, pad))
    scal = jnp.stack([jnp.asarray(chunk, jnp.int32),
                      jnp.asarray(gid_base, jnp.int32)])
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)  # noqa: E731
    row = pl.BlockSpec((bq,), lambda i: (i,))
    kern = functools.partial(_kernel, seed=seed, sizes=tuple(sizes),
                             theta=theta, sigma=sigma, frontier=frontier,
                             n_levels=n_levels)
    tgt, ok, depth = pl.pallas_call(
        kern,
        grid=(qp // bq,),
        in_specs=[full(counts), full(cents), full(members), full(npos),
                  full(vac), pl.BlockSpec((bq, 3), lambda i: (i, 0)),
                  row, row, row, pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[row, row, row],
        out_shape=[jax.ShapeDtypeStruct((qp,), jnp.int32),
                   jax.ShapeDtypeStruct((qp,), jnp.bool_),
                   jax.ShapeDtypeStruct((qp,), jnp.int32)],
        interpret=interpret,
    )(counts, cents, members, npos, vac, x, start_cell, src_gid, valid, scal)
    return (tgt[:q], ok[:q], depth[:q]) if qp != q else (tgt, ok, depth)


def traverse_hbm_bytes(n_levels: int, c_max: int, n_leaf: int,
                       members_cap: int, n: int, q: int) -> int:
    """Analytic HBM traffic of one fused phase-B on TPU: the tree arrays,
    membership table, and neuron data stream HBM->VMEM once (constant index
    maps keep them block-resident across the query grid), queries stream in
    once, the two outputs stream out once — the per-round (Q, F) frontier
    state never leaves VMEM. Compare with the roofline-counted bytes of the
    reference lowering (benchmarks/bench_connectivity.py)."""
    tree = n_levels * c_max * 4 + n_levels * c_max * 3 * 4
    leaf = n_leaf * members_cap * 4
    neurons = n * 3 * 4 + n * 4
    queries = q * 3 * 4 + q * 4 + q * 4 + q + 8
    outs = q * 4 + q + q * 4   # target gid + valid + telemetry depth
    return tree + leaf + neurons + queries + outs
