"""Pallas TPU kernels: the fused synapse-table apply and the deletion-routing
buffer build (registry domain "apply", ``BrainConfig.apply_impl='fused'``).

The reference apply is three jnp passes over the (n, s_max) edge table —
``remove_edges_by_messages`` (a lexsort over n*s_max + q items plus a
full-length kill scatter), ``compact``, and ``accept_requests`` (another
sort + rank scatter) — and the deletion routing adds one more
``positions_within`` + scatter over all n*s_max flattened edges. On CPU XLA
each of those 32K-element scatters serializes into a per-element while loop
that the trip-count-aware roofline prices at ~4.3 GB *per scatter* at
n=1024 (benchmarks/bench_connectivity.py); on TPU they are real HBM
round-trips of the whole table between stages.

``synapse_apply`` runs the SAME shared cores (``remove_edges_by_messages``
-> ``compact`` -> ``accept_core``) in one ``pallas_call`` with the table,
messages, and requests VMEM-resident — the table crosses HBM once in, once
out. Either stage can be disabled by passing no valid messages/requests
(the cores are then exact identities on a compacted table), which is how
``apply_impl='fused'`` maps the two call sites in ``connectome/update.py``
and ``connectome/routing.py`` onto one kernel. ``route_build`` runs
``routing.route_build_core`` with the per-bucket cumsum ``bucket_ranks``
standing in for ``positions_within`` (integer-identical stable ranks). Float priorities
are computed OUTSIDE the kernels by the same expressions the reference
uses, so both impls are bit-identical (tests/test_radix_sort.py,
tests/test_connectome.py, tests/test_multidevice.py). Like the other
kernels here, CPU containers run them with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.connectome import routing
from repro.connectome import synapses as syn
from repro.kernels.radix_sort import bucket_ranks


def _apply_kernel(edges_ref, mlid_ref, mgid_ref, mvalid_ref, rlid_ref,
                  rsrc_ref, rvalid_ref, rprio_ref, vac_ref, out_ref, acc_ref):
    edges = syn.remove_edges_by_messages(edges_ref[...], mlid_ref[...],
                                         mgid_ref[...], mvalid_ref[...])
    edges = syn.compact(edges)
    accept, edges = syn.accept_core(rlid_ref[...], rsrc_ref[...],
                                    rvalid_ref[...], vac_ref[...], edges,
                                    rprio_ref[...])
    out_ref[...] = edges
    acc_ref[...] = accept


def synapse_apply(edges, msg_lid, msg_gid, msg_valid, req_lid, req_src,
                  req_valid, req_prio, vacant_d, *, interpret: bool = False):
    """One VMEM-resident remove -> compact -> accept pass over one edge
    table. edges: (n, s_max) i32; msg_*: (qm,) deletion messages; req_*:
    (qr,) formation requests with precomputed priorities; vacant_d: (n,)
    f32. Returns (new_edges, accept (qr,) bool)."""
    n, s_max = edges.shape
    qm, qr = msg_lid.shape[0], req_lid.shape[0]
    full1 = lambda m: pl.BlockSpec((m,), lambda i: (0,))      # noqa: E731
    tbl = pl.BlockSpec((n, s_max), lambda i: (0, 0))
    return pl.pallas_call(
        _apply_kernel,
        grid=(1,),
        in_specs=[tbl, full1(qm), full1(qm), full1(qm),
                  full1(qr), full1(qr), full1(qr), full1(qr), full1(n)],
        out_specs=[tbl, full1(qr)],
        out_shape=[jax.ShapeDtypeStruct((n, s_max), jnp.int32),
                   jax.ShapeDtypeStruct((qr,), jnp.bool_)],
        interpret=interpret,
    )(edges, msg_lid.astype(jnp.int32), msg_gid.astype(jnp.int32), msg_valid,
      req_lid.astype(jnp.int32), req_src.astype(jnp.int32), req_valid,
      req_prio, vacant_d)


def _route_kernel(other_ref, mine_ref, buf_ref, drop_ref, *, n, num_ranks,
                  cap):
    buf, dropped = routing.route_build_core(
        other_ref[...], mine_ref[...], n, num_ranks, cap,
        lambda ids, buckets: bucket_ranks(ids, buckets))
    buf_ref[...] = buf
    drop_ref[...] = dropped[None]


def route_build(flat_other, flat_mine, *, n: int, num_ranks: int, cap: int,
                interpret: bool = False):
    """Deletion-notification buffer build over the flattened (n*s_max,)
    (partner gid, my gid) pairs, VMEM-resident. Returns (buf (num_ranks,
    cap, 2) i32, dropped (1,) f32) — bit-identical to the pre-collective
    half of ``routing.route_deletions``."""
    m = flat_other.shape[0]
    kern = functools.partial(_route_kernel, n=n, num_ranks=num_ranks, cap=cap)
    row = pl.BlockSpec((m,), lambda i: (0,))
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[row, row],
        out_specs=[pl.BlockSpec((num_ranks, cap, 2), lambda i: (0, 0, 0)),
                   pl.BlockSpec((1,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((num_ranks, cap, 2), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        interpret=interpret,
    )(flat_other.astype(jnp.int32), flat_mine.astype(jnp.int32))


def apply_hbm_bytes(n: int, s_max: int, qm: int, qr: int) -> int:
    """Analytic HBM traffic of one fused ``synapse_apply`` on TPU: the table
    in and out once, messages/requests/vacancies in once, the accept mask
    out once — every inter-stage table state stays in VMEM."""
    table = 2 * n * s_max * 4
    msgs = qm * (4 + 4 + 1)
    reqs = qr * (4 + 4 + 1 + 4) + qr
    return table + msgs + reqs + n * 4


def route_build_hbm_bytes(n: int, s_max: int, num_ranks: int,
                          cap: int) -> int:
    """Analytic HBM traffic of one fused ``route_build`` on TPU: the two
    flattened gid streams in once, the buffer + drop count out once."""
    return 2 * n * s_max * 4 + num_ranks * cap * 2 * 4 + 4
