"""Checkpointing: atomic, keep-k, async, and elastic (restore reshards onto a
different mesh / device count — the recovery path for node failures).

Layout:  <dir>/step_<n>/
           manifest.json    tree structure, shapes, dtypes, step, metadata
           <leaf-id>.npy    one file per leaf (full logical array)

Writes go to ``step_<n>.tmp`` and are atomically renamed — a crash mid-write
never corrupts the latest checkpoint. ``AsyncCheckpointer`` overlaps the
host-side write with the next training step (device->host copy is synchronous,
disk I/O is not).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree, metadata: Optional[dict] = None):
    """Synchronous atomic save of full logical arrays."""
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``; ``shardings`` (same
    structure) reshards onto the CURRENT mesh — elastic restarts load a
    checkpoint written on 256 devices onto 128 or 512 without conversion."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)
    out = []
    for i, (key, leaf) in enumerate(leaves):
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, info["file"]))
        if arr.dtype.kind == "V":  # np.load returns void for ml_dtypes (bf16)
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, info["dtype"])))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i][1]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def gc_old(ckpt_dir: str, keep: int):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Overlap disk writes with training; device->host copy happens inline."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, metadata=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.dir, step, host_tree, metadata)
            gc_old(self.dir, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def restore_latest(self, target_tree, shardings=None):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None, None
        tree, manifest = restore(self.dir, step, target_tree, shardings)
        return step, tree, manifest
