"""Checkpointing: atomic, keep-k, async, verified, and elastic (restore
reshards onto a different mesh / device count — the recovery path for node
failures).

Layout:  <dir>/step_<n>/
           manifest.json    tree structure, shapes, dtypes, crc32s, step,
                            metadata
           <leaf-id>.npy    one file per leaf (full logical array)

Writes go to ``step_<n>.tmp`` and are atomically renamed — a crash mid-write
never corrupts the latest checkpoint. Every leaf's crc32 is recorded in the
manifest and verified on ``restore``; any mismatch (or a missing/truncated
file, or an unreadable manifest) raises the typed ``CorruptCheckpointError``
so a runner can skip to the previous step instead of loading garbage.
``AsyncCheckpointer`` overlaps the host-side write with the next training
step (device->host copy is synchronous, disk I/O is not) and its
``restore_latest`` walks steps newest-first past corrupt ones.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, List, Optional

import jax
import numpy as np


class CorruptCheckpointError(RuntimeError):
    """A checkpoint failed verification: checksum mismatch, missing or
    truncated leaf file, or unreadable manifest. Distinct from structure
    mismatches (KeyError/ValueError), which mean the checkpoint is valid
    but does not fit the requested target tree."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save(ckpt_dir: str, step: int, tree, metadata: Optional[dict] = None):
    """Synchronous atomic save of full logical arrays."""
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "crc32": _crc(arr)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def steps_available(ckpt_dir: str) -> List[int]:
    """All finalized checkpoint steps under ``ckpt_dir``, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                  if (m := re.fullmatch(r"step_(\d+)", d)))


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = steps_available(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """Load and minimally validate a step's manifest.
    Raises CorruptCheckpointError if missing or unparseable."""
    path = os.path.join(ckpt_dir, f"step_{step}", "manifest.json")
    try:
        with open(path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(
            f"unreadable manifest for step {step}: {e}") from e
    if "leaves" not in manifest:
        raise CorruptCheckpointError(
            f"manifest for step {step} has no leaves table")
    return manifest


def load_arrays(ckpt_dir: str, step: int):
    """Load every leaf of a checkpoint as raw host arrays, verifying
    checksums: ``({key: np.ndarray}, manifest)``. The elastic restore
    path uses this to re-derive rank-local sharding from the logically
    global arrays without needing a matching target tree."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest = read_manifest(ckpt_dir, step)
    arrays = {}
    for key, info in manifest["leaves"].items():
        arrays[key] = _load_leaf(path, key, info, step)
    return arrays, manifest


def _load_leaf(path: str, key: str, info: dict, step: int) -> np.ndarray:
    try:
        arr = np.load(os.path.join(path, info["file"]))
    except (OSError, ValueError) as e:
        raise CorruptCheckpointError(
            f"step {step} leaf {key!r}: unreadable ({e})") from e
    crc = info.get("crc32")
    if crc is not None and _crc(arr) != crc:
        raise CorruptCheckpointError(
            f"step {step} leaf {key!r}: crc32 mismatch")
    if arr.dtype.kind == "V":  # np.load returns void for ml_dtypes (bf16)
        import ml_dtypes
        arr = arr.view(np.dtype(getattr(ml_dtypes, info["dtype"])))
    return arr


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``; ``shardings`` (same
    structure) reshards onto the CURRENT mesh — elastic restarts load a
    checkpoint written on 256 devices onto 128 or 512 without conversion.
    Verifies every leaf's crc32 (CorruptCheckpointError on mismatch)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest = read_manifest(ckpt_dir, step)
    leaves, treedef = _flatten(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)
    out = []
    for i, (key, leaf) in enumerate(leaves):
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _load_leaf(path, key, info, step)
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i][1]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def gc_old(ckpt_dir: str, keep: int):
    steps = steps_available(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Overlap disk writes with training; device->host copy happens inline."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, metadata=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.dir, step, host_tree, metadata)
            gc_old(self.dir, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def restore_latest(self, target_tree, shardings=None):
        """Restore the newest checkpoint that passes verification,
        walking past corrupt steps (newest-first)."""
        self.wait()
        for step in reversed(steps_available(self.dir)):
            try:
                tree, manifest = restore(self.dir, step, target_tree,
                                         shardings)
            except CorruptCheckpointError:
                continue
            return step, tree, manifest
        return None, None, None
