"""Per-neuron parameter tables: heterogeneous neuron populations.

The seed simulation drives every neuron with the same scalar constants from
``BrainConfig``. Here a scenario declares a tuple of ``PopulationSpec``s
(mixed Izhikevich types RS/FS/CH/IB/LTS, per-population calcium targets,
growth rates, and synapse weights) and ``build_table`` compiles them into
``(n,)`` arrays — one value per local neuron — that are threaded through
``core/neuron.py``, ``core/engine.py`` and the fused Pallas kernel.

Assignment is deterministic by local id (contiguous blocks, excitatory
populations first by convention of the spec order): every rank derives the
SAME table from (cfg, populations, n), so a neuron's synapse weight and sign
can be looked up anywhere from ``gid % n`` — the same replicated-derivation
trick the engine already uses for excitatory/inhibitory signs.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.msp_brain import BrainConfig

# Izhikevich (2003) canonical parameter sets.
IZHIKEVICH_PRESETS = {
    "RS": dict(izh_a=0.02, izh_b=0.2, izh_c=-65.0, izh_d=8.0),   # regular
    "IB": dict(izh_a=0.02, izh_b=0.2, izh_c=-55.0, izh_d=4.0),   # bursting
    "CH": dict(izh_a=0.02, izh_b=0.2, izh_c=-50.0, izh_d=2.0),   # chattering
    "FS": dict(izh_a=0.1, izh_b=0.2, izh_c=-65.0, izh_d=2.0),    # fast spike
    "LTS": dict(izh_a=0.02, izh_b=0.25, izh_c=-65.0, izh_d=2.0),  # low-thresh
}


@dataclass(frozen=True)
class PopulationSpec:
    """One homogeneous sub-population. ``None`` fields inherit BrainConfig."""
    name: str
    fraction: float
    izh_a: float = 0.02
    izh_b: float = 0.2
    izh_c: float = -65.0
    izh_d: float = 8.0
    is_excitatory: bool = True
    target_calcium: Optional[float] = None
    element_growth_rate: Optional[float] = None
    synapse_weight: Optional[float] = None   # magnitude; sign from excitatory


def population(name: str, fraction: float, kind: str = "RS",
               **overrides) -> PopulationSpec:
    """Spec factory from an Izhikevich preset, e.g.
    ``population('inh', 0.2, 'FS', is_excitatory=False)``."""
    spec = PopulationSpec(name=name, fraction=fraction,
                          **IZHIKEVICH_PRESETS[kind])
    return replace(spec, **overrides) if overrides else spec


def default_populations(cfg: BrainConfig) -> Tuple[PopulationSpec, ...]:
    """The seed model as a 2-population table: RS excitatory/inhibitory split
    at cfg.fraction_excitatory — bitwise-identical to the scalar path."""
    izh = dict(izh_a=cfg.izh_a, izh_b=cfg.izh_b, izh_c=cfg.izh_c,
               izh_d=cfg.izh_d)
    pops = [PopulationSpec(name="exc", fraction=cfg.fraction_excitatory,
                           is_excitatory=True, **izh)]
    if cfg.fraction_excitatory < 1.0:
        pops.append(PopulationSpec(name="inh",
                                   fraction=1.0 - cfg.fraction_excitatory,
                                   is_excitatory=False, **izh))
    return tuple(pops)


class PopulationTable(NamedTuple):
    """Per-neuron parameter arrays, all shape (n,). Identical on every rank;
    index with ``gid % n`` for any neuron in the global simulation."""
    pop_id: jnp.ndarray             # i32
    izh_a: jnp.ndarray              # f32
    izh_b: jnp.ndarray
    izh_c: jnp.ndarray
    izh_d: jnp.ndarray
    target_calcium: jnp.ndarray
    growth_rate: jnp.ndarray
    synapse_weight: jnp.ndarray     # SIGNED: +magnitude exc / -magnitude inh
    is_excitatory: jnp.ndarray      # bool


def population_sizes(n: int, pops: Sequence[PopulationSpec]) -> np.ndarray:
    """Block size per population: cumulative-floor so sizes sum to n and the
    first boundary equals the legacy ``int(n * fraction_excitatory)``."""
    fr = np.asarray([p.fraction for p in pops], np.float64)
    if not np.isclose(fr.sum(), 1.0, atol=1e-6):
        raise ValueError(f"population fractions must sum to 1, got {fr.sum()}")
    bounds = np.floor(np.cumsum(fr) * n).astype(np.int64)
    bounds[-1] = n
    return np.diff(np.concatenate([[0], bounds]))


def build_table(cfg: BrainConfig, pops: Sequence[PopulationSpec],
                n: int) -> PopulationTable:
    sizes = population_sizes(n, pops)

    def col(field, default, signed=False):
        vals = []
        for p, sz in zip(pops, sizes):
            v = getattr(p, field)
            v = default if v is None else v
            if signed:
                v = v if p.is_excitatory else -v
            vals.append(np.full(int(sz), v, np.float32))
        return jnp.asarray(np.concatenate(vals))

    pop_id = jnp.asarray(np.repeat(np.arange(len(pops), dtype=np.int32),
                                   sizes))
    exc = jnp.asarray(np.repeat(np.asarray([p.is_excitatory for p in pops]),
                                sizes))
    return PopulationTable(
        pop_id=pop_id,
        izh_a=col("izh_a", cfg.izh_a),
        izh_b=col("izh_b", cfg.izh_b),
        izh_c=col("izh_c", cfg.izh_c),
        izh_d=col("izh_d", cfg.izh_d),
        target_calcium=col("target_calcium", cfg.target_calcium),
        growth_rate=col("element_growth_rate", cfg.element_growth_rate),
        synapse_weight=col("synapse_weight", cfg.synapse_weight, signed=True),
        is_excitatory=exc)


def table_for(cfg: BrainConfig, scenario, n: int) -> PopulationTable:
    """The table a scenario implies (scenario None or without populations ->
    the BrainConfig-equivalent default table)."""
    pops = getattr(scenario, "populations", ()) or default_populations(cfg)
    return build_table(cfg, pops, n)
