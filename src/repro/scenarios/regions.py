"""Named spatial regions of the simulation domain.

Regions are axis-aligned boxes in the unit cube — the same [0,1]^3 the
Morton decomposition partitions — so a region is rank-agnostic: each rank
evaluates its own neurons' membership from their positions, and global
(gid-indexed) region tables come from the same cheap all-gather the engine
already performs for rates.

``region_connectome`` turns the edge tables into a region x region synapse
count matrix entirely on-device (one scatter-add over the out-edge table).
The last bucket (index ``len(regions)``) is the implicit "rest" region for
neurons outside every named box.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.configs.msp_brain import BrainConfig


@dataclass(frozen=True)
class Region:
    """Axis-aligned box [lo, hi) in the unit cube, with optional per-region
    background-drive overrides (None inherits BrainConfig)."""
    name: str
    lo: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    hi: Tuple[float, float, float] = (1.0, 1.0, 1.0)
    bg_mean: Optional[float] = None
    bg_std: Optional[float] = None


def region_mask(positions, region: Region):
    """(n, 3) positions -> (n,) bool membership."""
    lo = jnp.asarray(region.lo, jnp.float32)
    hi = jnp.asarray(region.hi, jnp.float32)
    return jnp.all((positions >= lo) & (positions < hi), axis=-1)


def num_buckets(regions: Sequence[Region]) -> int:
    """Named regions + the trailing 'rest' bucket."""
    return len(regions) + 1


def assign_regions(positions, regions: Sequence[Region]):
    """(n,) region id per neuron; first matching region wins, neurons outside
    every box land in the 'rest' bucket (id == len(regions))."""
    rid = jnp.full((positions.shape[0],), len(regions), jnp.int32)
    for i in reversed(range(len(regions))):
        rid = jnp.where(region_mask(positions, regions[i]), i, rid)
    return rid


def background_tables(positions, regions: Sequence[Region],
                      cfg: BrainConfig):
    """Per-neuron background drive (mean, std) honoring region overrides.
    Returns scalars when no region overrides anything (keeps the default
    trace identical to the seed engine)."""
    if not any(r.bg_mean is not None or r.bg_std is not None
               for r in regions):
        return cfg.background_mean, cfg.background_std
    mean = jnp.full((positions.shape[0],), cfg.background_mean, jnp.float32)
    std = jnp.full((positions.shape[0],), cfg.background_std, jnp.float32)
    for i, r in enumerate(regions):
        if r.bg_mean is None and r.bg_std is None:
            continue
        m = region_mask(positions, r)
        if r.bg_mean is not None:
            mean = jnp.where(m, r.bg_mean, mean)
        if r.bg_std is not None:
            std = jnp.where(m, r.bg_std, std)
    return mean, std


def region_counts(region_ids, nb: int):
    """(nb,) neuron count per region bucket."""
    return jnp.zeros((nb,), jnp.int32).at[region_ids].add(1)


def region_connectome(out_edges, src_region_ids, region_of_gid, nb: int):
    """Region x region synapse-count matrix from an out-edge table.

    out_edges: (rows, S) target gids (-1 empty); src_region_ids: (rows,)
    region of each source row; region_of_gid: (N_global,) region of every
    neuron in the simulation (e.g. the all-gathered per-rank assignment).
    Returns (nb, nb) float32: [src_region, tgt_region] -> #synapses."""
    valid = out_edges >= 0
    safe = jnp.clip(out_edges, 0, region_of_gid.shape[0] - 1)
    tgt_r = region_of_gid[safe]                              # (rows, S)
    src_r = jnp.broadcast_to(src_region_ids[:, None], out_edges.shape)
    mat = jnp.zeros((nb, nb), jnp.float32)
    return mat.at[jnp.where(valid, src_r, 0),
                  jnp.where(valid, tgt_r, 0)].add(
        valid.astype(jnp.float32))
