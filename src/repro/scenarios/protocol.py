"""Declarative stimulus/lesion protocols, compiled trace-stably.

A protocol is a static tuple of events over *global* step time (1 step =
1 ms, rate_period steps per chunk). Because the event list is a Python
constant, compiling it against a traced step index unrolls into a fixed
stack of masked adds/ands — the jitted ``sim_chunk`` stays trace-stable
(one XLA program for the whole run, no per-event recompiles).

Semantics inside the engine:

  Stimulate(region, amplitude, t0, t1)  extra input current ``amplitude``
      to every neuron in ``region`` for steps t0 <= t < t1 (on top of the
      background N(mean, std) drive).
  Lesion(region, t)  neurons in ``region`` die at step t: no spikes, zero
      advertised rate, synaptic elements forced to zero (which retracts all
      their synapses at the next connectivity update and notifies partners),
      excluded from Barnes-Hut search and from accepting new synapses.
  Recover(region, t)  the region's neurons come back online at step t
      (vacant elements regrow from zero via the homeostatic rule).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.scenarios.regions import Region, region_mask

_NEVER = 1 << 30   # "end of time" for lesions without a matching Recover


@dataclass(frozen=True)
class Stimulate:
    region: str
    amplitude: float
    t0: int
    t1: int


@dataclass(frozen=True)
class Lesion:
    region: str
    t: int


@dataclass(frozen=True)
class Recover:
    region: str
    t: int


@dataclass(frozen=True)
class Scenario:
    """A runnable experiment: who the neurons are (populations), where they
    live (regions), and what happens to them (events)."""
    name: str
    populations: Tuple = ()     # () -> BrainConfig-default populations
    regions: Tuple[Region, ...] = ()
    events: Tuple = ()
    num_chunks: int = 20        # suggested run length (chunks of rate_period)


def _region(regions: Sequence[Region], name: str) -> Region:
    for r in regions:
        if r.name == name:
            return r
    raise KeyError(f"protocol references unknown region {name!r}; "
                   f"have {[r.name for r in regions]}")


def has_lesions(scenario) -> bool:
    return scenario is not None and any(
        isinstance(e, Lesion) for e in scenario.events)


def stim_drive(events, regions: Sequence[Region], positions, step):
    """(n,) extra input current at traced global ``step``; 0.0 scalar when
    the protocol has no stimulation events."""
    drive = jnp.zeros((), jnp.float32)
    for ev in events:
        if not isinstance(ev, Stimulate):
            continue
        mask = region_mask(positions, _region(regions, ev.region))
        active = ((step >= ev.t0) & (step < ev.t1)).astype(jnp.float32)
        drive = drive + ev.amplitude * active * mask
    return drive


def _lesion_windows(events, regions: Sequence[Region]):
    """Per Lesion event: (region, t_dead, t_recover). A Recover for the same
    region at a later time closes the window (earliest such Recover wins)."""
    windows = []
    for ev in events:
        if not isinstance(ev, Lesion):
            continue
        t1 = min((r.t for r in events
                  if isinstance(r, Recover) and r.region == ev.region
                  and r.t > ev.t), default=_NEVER)
        windows.append((_region(regions, ev.region), ev.t, t1))
    return windows


def stim_tables(events, regions: Sequence[Region], positions):
    """Compile Stimulate events into activity-kernel operands:
    ``((E, n) f32 region masks, ((amplitude, t0, t1), ...))`` with the time
    windows static — the kernel/reference step evaluates
    ``amplitude * (t0 <= gstep < t1) * mask`` per event, which is exactly
    ``stim_drive`` unrolled. Returns None when the protocol never
    stimulates."""
    evs = [e for e in events if isinstance(e, Stimulate)]
    if not evs:
        return None
    masks = jnp.stack([
        region_mask(positions, _region(regions, e.region)).astype(jnp.float32)
        for e in evs])
    meta = tuple((float(e.amplitude), int(e.t0), int(e.t1)) for e in evs)
    return masks, meta


def lesion_tables(events, regions: Sequence[Region], positions):
    """Compile lesion windows into activity-kernel operands:
    ``((W, n) bool region masks, ((t_dead, t_recover), ...))`` — the
    kernel/reference step rebuilds ``alive_mask`` from them at each traced
    step. Returns None when the protocol never lesions."""
    windows = _lesion_windows(events, regions)
    if not windows:
        return None
    masks = jnp.stack([region_mask(positions, r) for r, _, _ in windows])
    meta = tuple((int(t0), int(t1)) for _, t0, t1 in windows)
    return masks, meta


def alive_mask(events, regions: Sequence[Region], positions, step):
    """(n,) bool at traced global ``step``: False while inside any lesion
    window. Returns None when the protocol never lesions (legacy fast path)."""
    windows = _lesion_windows(events, regions)
    if not windows:
        return None
    alive = jnp.ones((positions.shape[0],), bool)
    for region, t0, t1 in windows:
        dead = region_mask(positions, region) & (step >= t0) & (step < t1)
        alive = alive & ~dead
    return alive
