"""End-to-end scenario library + runner.

Three canonical MSP experiments (the interventions behind the paper's
Figs. 8/9 quality discussion):

  baseline_growth    heterogeneous sheet (RS / CH excitatory + FS
                     inhibitory) growing from an empty connectome toward
                     the calcium target — the seed demo, now with mixed
                     Izhikevich types.
  focal_stimulation  extra input current to a focal region mid-run; the
                     region overshoots its calcium target, retracts
                     elements, and the connectome tilts toward/away from
                     the stimulated population.
  lesion_rewiring    a region dies mid-run: its synapses are retracted
                     (partners notified), then the surviving network
                     regrows connectivity among itself.

``run_scenario`` drives any of them on the engine and returns the final
global state plus the flushed per-region recorder history.
"""
from __future__ import annotations

import dataclasses

from repro.configs.msp_brain import SMOKE_CONFIG, BrainConfig
from repro.scenarios import observables
from repro.scenarios.populations import population
from repro.scenarios.protocol import Lesion, Scenario, Stimulate
from repro.scenarios.regions import Region
from repro.sim.api import Simulator

# smoke-scale default: overflow-free buffers so every run is exactly the MSP
# dynamics (tests/benchmarks compare old vs new bitwise)
SMOKE_SCENARIO_CONFIG = dataclasses.replace(
    SMOKE_CONFIG, requests_cap_factor=1000)


def baseline_growth() -> Scenario:
    return Scenario(
        name="baseline_growth",
        populations=(
            population("exc-rs", 0.6, "RS"),
            population("exc-ch", 0.2, "CH"),
            population("inh-fs", 0.2, "FS", is_excitatory=False,
                       synapse_weight=30.0),
        ),
        regions=(),
        events=(),
        num_chunks=20)


def focal_stimulation() -> Scenario:
    return Scenario(
        name="focal_stimulation",
        regions=(Region("focus", lo=(0.0, 0.0, 0.0), hi=(0.5, 0.5, 1.0)),),
        events=(Stimulate("focus", amplitude=4.0, t0=500, t1=1500),),
        num_chunks=20)


def lesion_rewiring() -> Scenario:
    return Scenario(
        name="lesion_rewiring",
        regions=(Region("core", lo=(0.0, 0.0, 0.0), hi=(0.5, 1.0, 1.0)),),
        events=(Lesion("core", t=1000),),
        num_chunks=24)


SCENARIOS = {
    "baseline_growth": baseline_growth,
    "focal_stimulation": focal_stimulation,
    "lesion_rewiring": lesion_rewiring,
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]()
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}") from None


def run_scenario(scenario: Scenario, cfg: BrainConfig = None,
                 num_chunks: int = None, mesh=None, recorder_cap: int = None):
    """Run a scenario end-to-end — a thin wrapper over the ``Simulator``
    facade's fused multi-chunk driver (the recorder rows are written inside
    the same jitted scan). Returns (final_state, history) where history is
    the flushed observables dict (oldest chunk first)."""
    cfg = cfg or SMOKE_SCENARIO_CONFIG
    num_chunks = num_chunks or scenario.num_chunks
    sim = Simulator.from_config(cfg, scenario=scenario, mesh=mesh)
    rec = observables.init_recorder(recorder_cap or num_chunks,
                                    len(scenario.regions) + 1)
    st, rec = sim.run(num_chunks, recorder=rec)
    return st, observables.flush(rec)
