"""Device-side scenario recorder: a per-chunk ring buffer.

``record`` is pure jnp over the *global* BrainState arrays (positions,
calcium, rate, out_edges) — call it under jit right after each ``chunk``
step and nothing leaves the device until ``flush``. The ring has a static
capacity, so recording is trace-stable and donation-friendly; when more
chunks than ``cap`` are recorded the oldest entries are overwritten.

Per chunk it stores, per region bucket (named regions + 'rest'):
mean calcium, mean advertised rate, synapse counts (by source region), the
full region x region connectome, and a global rate histogram.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.scenarios import regions as regions_mod

RATE_HIST_MAX = 0.5   # rates are spikes/ms; 0.5 == 500 Hz ceiling


class Recorder(NamedTuple):
    idx: jnp.ndarray          # scalar i32: total chunks recorded
    calcium: jnp.ndarray      # (cap, nb) mean calcium per region
    rate: jnp.ndarray         # (cap, nb) mean rate per region
    synapses: jnp.ndarray     # (cap, nb) out-synapses per source region
    alive: jnp.ndarray        # (cap, nb) neurons alive per region
    connectome: jnp.ndarray   # (cap, nb, nb) region x region synapse counts
    rate_hist: jnp.ndarray    # (cap, bins) global rate histogram


def init_recorder(cap: int, nb: int, bins: int = 16) -> Recorder:
    z = functools.partial(jnp.zeros, dtype=jnp.float32)
    return Recorder(jnp.zeros((), jnp.int32), z((cap, nb)), z((cap, nb)),
                    z((cap, nb)), z((cap, nb)), z((cap, nb, nb)),
                    z((cap, bins)))


def _segment_mean(values, rid, nb):
    s = jnp.zeros((nb,), jnp.float32).at[rid].add(values)
    c = jnp.zeros((nb,), jnp.float32).at[rid].add(1.0)
    return s / jnp.maximum(c, 1.0)


@functools.partial(jax.jit, static_argnames=("regions",))
def record(rec: Recorder, positions, calcium, rate, out_edges,
           regions: Sequence, alive=None) -> Recorder:
    """Append one chunk worth of observables. All inputs are the global
    (concatenated-over-ranks) state arrays; ``regions`` is the scenario's
    static region tuple; ``alive`` an optional (N,) bool mask."""
    nb = regions_mod.num_buckets(regions)
    rid = regions_mod.assign_regions(positions, regions)
    cap = rec.calcium.shape[0]
    slot = rec.idx % cap
    alive_f = jnp.ones(rid.shape, jnp.float32) if alive is None \
        else alive.astype(jnp.float32)
    conn = regions_mod.region_connectome(out_edges, rid, rid, nb)
    bins = rec.rate_hist.shape[1]
    hist = jnp.zeros((bins,), jnp.float32).at[
        jnp.clip((rate / RATE_HIST_MAX * bins).astype(jnp.int32),
                 0, bins - 1)].add(1.0)
    return Recorder(
        idx=rec.idx + 1,
        calcium=rec.calcium.at[slot].set(_segment_mean(calcium, rid, nb)),
        rate=rec.rate.at[slot].set(_segment_mean(rate, rid, nb)),
        synapses=rec.synapses.at[slot].set(jnp.sum(conn, axis=1)),
        alive=rec.alive.at[slot].set(
            jnp.zeros((nb,), jnp.float32).at[rid].add(alive_f)),
        connectome=rec.connectome.at[slot].set(conn),
        rate_hist=rec.rate_hist.at[slot].set(hist))


def flush(rec: Recorder) -> dict:
    """Move the ring to host, oldest chunk first. Returns numpy arrays of
    leading length min(idx, cap)."""
    idx = int(rec.idx)
    cap = rec.calcium.shape[0]
    kept = min(idx, cap)
    order = (np.arange(idx - kept, idx) % cap) if kept else np.arange(0)
    out = {"num_recorded": idx}
    for name in ("calcium", "rate", "synapses", "alive", "connectome",
                 "rate_hist"):
        out[name] = np.asarray(getattr(rec, name))[order]
    return out
