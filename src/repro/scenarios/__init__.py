"""Scenario subsystem: heterogeneous populations, named regions, and
declarative stimulus/lesion protocols for the MSP brain (DESIGN.md §3).

The single hard-coded simulation (homogeneous RS sheet under uniform
N(5,1) drive) becomes a library of runnable experiments:

  populations.py  per-neuron parameter tables (mixed Izhikevich types,
                  per-population calcium targets / growth rates / weights)
  regions.py      named spatial regions of the Morton domain, per-region
                  background drive, region x region connectome matrices
  protocol.py     declarative event schedules (Stimulate / Lesion /
                  Recover) + the Scenario container, compiled into
                  trace-stable per-step drive and alive masks
  observables.py  device-side ring-buffer recorder (rates, per-region
                  synapse counts, calcium traces)
  library.py      end-to-end scenarios (baseline_growth,
                  focal_stimulation, lesion_rewiring) and run_scenario()

``library`` imports the engine, which imports the other modules here, so it
is intentionally NOT imported at package-import time — use
``from repro.scenarios import library``.
"""
from repro.scenarios.populations import (IZHIKEVICH_PRESETS, PopulationSpec,
                                         PopulationTable, build_table,
                                         default_populations, population,
                                         table_for)
from repro.scenarios.protocol import (Lesion, Recover, Scenario, Stimulate,
                                      alive_mask, has_lesions, lesion_tables,
                                      stim_drive, stim_tables)
from repro.scenarios.regions import (Region, assign_regions,
                                     background_tables, num_buckets,
                                     region_connectome, region_mask)

__all__ = [
    "IZHIKEVICH_PRESETS", "PopulationSpec", "PopulationTable", "build_table",
    "default_populations", "population", "table_for",
    "Lesion", "Recover", "Scenario", "Stimulate", "alive_mask",
    "has_lesions", "lesion_tables", "stim_drive", "stim_tables",
    "Region", "assign_regions", "background_tables", "num_buckets",
    "region_connectome", "region_mask",
]
