"""Serving driver: batched prefill + decode with the KV-cache machinery.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model
from repro.parallel import sharding as shd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    da, mo = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((da, mo), ("data", "model"))
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    max_seq = args.prompt_len + args.gen

    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(api, mesh),
                      static_argnames=())
    decode = jax.jit(make_decode_step(api, mesh), donate_argnums=(1,))

    t0 = time.time()
    with shd.use_mesh(mesh):
        logits, state = api.prefill(params, batch, mesh,
                                    pad_cache_to=max_seq)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen - 1} steps in {t_dec:.2f}s "
          f"({(args.gen - 1) * args.batch / max(t_dec, 1e-9):.1f} tok/s)")
    print("sample generations:", gen[:2].tolist())


if __name__ == "__main__":
    main()
