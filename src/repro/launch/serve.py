"""Serving driver for the multi-tenant brain simulation service
(repro.service; DESIGN.md §12): spin up a ``SimulationService``, submit a
workload of tenant requests (one seed each), drive it to idle, and print
per-tenant outcomes + service lifecycle counters.

  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve \
      --slots 4 --tenants 8 --chunks 5 --neurons 128

``--poison-slot N`` runs the chaos demo: one tenant's lane is NaN-poisoned
mid-run and must be quarantined, rolled back, and finished via retry while
the co-tenants complete untouched.
"""
from __future__ import annotations

import argparse

from repro import telemetry
from repro.configs.msp_brain import BrainConfig
from repro.runtime import chaos
from repro.service import ServiceConfig, SimRequest, SimulationService


def build_config(args) -> BrainConfig:
    return BrainConfig(
        neurons_per_rank=args.neurons,
        local_levels=args.levels,
        frontier_cap=args.neurons,
        max_synapses=8,
        rate_period=10,
        requests_cap_factor=100,
        subs_cap_factor=100,
        rate_exchange=args.exchange)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="multi-tenant brain simulation service driver")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed workload (2 slots, 3 tenants)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--chunks", type=int, default=5,
                    help="chunk budget per tenant")
    ap.add_argument("--neurons", type=int, default=128,
                    help="neurons per rank")
    ap.add_argument("--levels", type=int, default=4,
                    help="local octree levels")
    ap.add_argument("--exchange", default="dense",
                    choices=("dense", "sparse"))
    ap.add_argument("--queue-cap", type=int, default=16)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline")
    ap.add_argument("--poison-slot", type=int, default=None,
                    help="chaos demo: NaN-poison this slot mid-run")
    ap.add_argument("--heartbeat", default=None,
                    help="heartbeat JSON path")
    args = ap.parse_args(argv)
    if args.smoke:
        args.slots, args.tenants, args.chunks = 2, 3, 3
        args.neurons, args.levels = 32, 3

    cfg = build_config(args)
    svc = SimulationService(
        cfg, ServiceConfig(num_slots=args.slots,
                           queue_cap=args.queue_cap,
                           heartbeat_path=args.heartbeat))
    if args.poison_slot is not None:
        svc.chaos_hooks.append(
            chaos.poison_slot_nan(args.poison_slot, after_chunk=1))

    handles = [svc.submit(SimRequest(seed=100 + i, chunks=args.chunks,
                                     priority=i % 2,
                                     deadline_s=args.deadline_s,
                                     tag=f"tenant{i}"))
               for i in range(args.tenants)]
    with telemetry.span("serve.drive", tenants=args.tenants):
        svc.run_until_idle()

    for h in handles:
        r = h.result
        print(f"  {h.request.tag:>10}  seed={h.request.seed}  "
              f"{r.status.value:<18} chunks={r.chunks_done}/"
              f"{h.request.chunks}  retries={r.retries}")
    stats = svc.stats()
    print("service:", {k: v for k, v in sorted(stats.items()) if v})
    done = sum(1 for h in handles
               if h.result is not None and h.result.status.name == "DONE")
    print(f"{done}/{len(handles)} tenants DONE")
    return 0 if done == len(handles) or args.poison_slot is not None \
        else 1


if __name__ == "__main__":
    raise SystemExit(main())
