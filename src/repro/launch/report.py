"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSONs written by repro.launch.dryrun.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1.0:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def load(dirname):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def roofline_table(recs, mesh="16x16"):
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "frac | useful | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("overrides"):
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                         f"| - | N/A: {r['reason'][:42]} |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                         f"| - | FAILED |")
            continue
        note = ""
        mv = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r.get('t_compute_s'))} | "
            f"{fmt_s(r.get('t_memory_s'))} | {fmt_s(r.get('t_collective_s'))} "
            f"| {r.get('dominant', '-')} | "
            f"{r.get('roofline_fraction', 0):.3f} | "
            f"{mv:.2f} | {note} |")
    return "\n".join(lines)


def dryrun_table(recs):
    rows = []
    for r in recs:
        if r.get("overrides"):
            continue
        status = "SKIP" if r.get("skipped") else (
            "ok" if r.get("ok") else "FAIL")
        fl = r.get("hlo_dot_flops_per_dev")
        cb = r.get("collective_bytes_per_dev")
        pb = r.get("param_bytes_per_dev")
        rows.append("| {} | {} | {} | {} | {} | {} | {} | {} |".format(
            r["arch"], r["shape"], r.get("mesh", "-"),
            r.get("compile_s", "-"),
            f"{fl / 1e12:.2f}T" if fl else "-",
            fmt_bytes(cb), fmt_bytes(pb), status))
    hdr = ["| arch | shape | mesh | compile_s | HLO dot flops/dev | "
           "coll wire/dev | param bytes/dev | status |",
           "|---|---|---|---|---|---|---|---|"]
    return "\n".join(hdr + rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
