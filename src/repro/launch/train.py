"""Training driver: config -> mesh -> data -> fault-tolerant loop.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 100 --mesh 1x1 --ckpt /tmp/run1

``--smoke`` selects the reduced config (CPU-runnable); the full configs are
exercised via the dry-run. Resumes from the latest checkpoint automatically.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step, opt_config_for
from repro.models import build_model
from repro.optim.optimizer import init_opt_state
from repro.parallel import sharding as shd
from repro.runtime.fault_tolerance import RunnerConfig, TrainingRunner


def build_everything(cfg, mesh, global_batch, seq_len, seed=0, steps=1000):
    api = build_model(cfg)
    params = jax.device_put(
        api.init(jax.random.key(seed)),
        shd.make_param_shardings(jax.eval_shape(api.init, jax.random.key(0)),
                                 mesh))
    opt_cfg = opt_config_for(cfg, steps=steps)
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(api, mesh, opt_cfg), donate_argnums=(0, 1))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    data = TokenPipeline(dcfg, sharding=shd.batch_sharding(
        mesh, 2, batch_size=global_batch))
    return api, params, opt_state, step, data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    da, mo = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((da, mo), ("data", "model"))
    api, params, opt, step, data = build_everything(
        cfg, mesh, args.batch, args.seq)

    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=max(args.steps // 4, 10)),
        step, params, opt, data)
    if runner.try_resume():
        print(f"resumed from step {runner.step}")

    t0 = time.time()
    n0 = runner.step
    status = runner.run(args.steps)
    dt = time.time() - t0
    losses = runner.history
    print(f"status={status} steps={runner.step - n0} "
          f"wall={dt:.1f}s ({dt / max(runner.step - n0, 1):.3f}s/step)")
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"loss first10={np.mean(losses[:k]):.4f} "
              f"last10={np.mean(losses[-k:]):.4f}")
    data.close()


if __name__ == "__main__":
    main()
