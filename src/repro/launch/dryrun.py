import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, print memory/cost analysis, and emit the roofline JSON.

The two lines above MUST stay the first statements in this file: jax locks the
device count at first initialization, and the dry-run needs 512 placeholder
host devices to build the production mesh. Never set this flag globally —
smoke tests and benchmarks see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch msp-brain --shape brain_64k
  ... [--multi-pod] [--out experiments/dryrun] [--set moe_strategy=move_data ...]
"""
import argparse
import dataclasses
import json
import math
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, get_shape
from repro.configs.base import applicable_shapes, supports_long_context
from repro.launch import roofline as rl
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, opt_config_for)
from repro.models import build_model, decode_state_specs, input_specs
from repro.models.decode import state_shardings
from repro.optim.optimizer import init_opt_state
from repro.parallel import sharding as shd


def _apply_overrides(cfg, sets):
    par_fields = {f.name for f in dataclasses.fields(cfg.parallel)}
    cfg_fields = {f.name for f in dataclasses.fields(cfg)}
    for kv in sets or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if k in par_fields:
            cfg = cfg.replace(parallel=cfg.parallel.replace(**{k: v}))
        elif k in cfg_fields:
            cfg = cfg.replace(**{k: v})
        else:
            raise KeyError(k)
    return cfg


def batch_shardings(cfg, batch_specs, mesh):
    out = {}
    for k, v in batch_specs.items():
        out[k] = shd.batch_sharding(mesh, len(v.shape), batch_size=v.shape[0],
                                    layout=cfg.parallel.layout)
    return out


def analytic_flops(cfg, shape):
    """MODEL_FLOPS: 6*N*D (train, dense) / 6*N_active*D (MoE); 2*N*D fwd-only."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * d_tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: one token per sequence


def lower_cell(arch, shape_name, multi_pod, sets=None):
    t0 = time.time()
    if arch == "msp-brain":
        return lower_brain_cell(shape_name, multi_pod, sets)
    cfg = _apply_overrides(get_config(arch), sets)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = math.prod(mesh.shape.values())
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(str(s) for s in mesh.shape.values()),
              "multi_pod": multi_pod, "kind": shape.kind,
              "overrides": sets or [], "ok": False}

    if shape.name == "long_500k" and not supports_long_context(cfg):
        record.update(ok=True, skipped=True,
                      reason="full-attention arch: quadratic over 512k "
                             "(see DESIGN.md §4)")
        return record

    api = build_model(cfg)
    specs = input_specs(cfg, shape)
    key = jax.random.key(0)
    layout = cfg.parallel.layout
    params_sds = jax.eval_shape(api.init, key)
    pshard = shd.make_param_shardings(params_sds, mesh, layout=layout)
    bshard = batch_shardings(cfg, specs, mesh)

    with shd.use_mesh(mesh, layout):
        if shape.kind == "train":
            opt_sds = jax.eval_shape(
                lambda p: init_opt_state(p, opt_config_for(cfg)), params_sds)
            oshard = {
                "m": shd.make_param_shardings(opt_sds["m"], mesh,
                                              opt_state=True, layout=layout),
                "v": shd.make_param_shardings(opt_sds["v"], mesh,
                                              opt_state=True, layout=layout),
                "step": shd.replicated(mesh)}
            step = make_train_step(api, mesh, opt_config_for(cfg))
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(api, mesh)
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_sds, specs)
        else:  # decode
            state_sds = decode_state_specs(cfg, shape)
            sshard = state_shardings(cfg, state_sds, mesh, shape.global_batch)
            tshard = shd.batch_sharding(mesh, 1, batch_size=shape.global_batch)
            step = make_decode_step(api, mesh)
            jitted = jax.jit(step, in_shardings=(pshard, sshard, tshard),
                             out_shardings=(None, sshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, state_sds,
                                   specs["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ----- analyses -----
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, f):
                mem[f] = getattr(ma, f)
        print("memory_analysis:", mem or ma)
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": repr(e)}
        print("memory_analysis unavailable:", e)
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
        print("cost_analysis flops:", cost.get("flops"),
              "bytes:", cost.get("bytes accessed"))
    except Exception as e:
        cost = {"error": repr(e)}

    hlo = compiled.as_text()
    ana = rl.analyze_hlo(hlo, ndev)

    mf = analytic_flops(cfg, shape)
    flops_dev = ana["dot_flops"]
    # memory term: analytic HBM traffic (CPU cost analysis is not fusion-aware;
    # model documented in EXPERIMENTS.md §Roofline):
    #   train   = params r/w + grads r/w + opt m,v r/w + act traffic (12x)
    #   prefill = params read + act traffic (6x)
    #   decode  = params read + decode-state read/write
    def tree_bytes(t):
        return sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(t))
    pbytes = tree_bytes(params_sds) / ndev
    tok_dev = shape.global_batch * shape.seq_len / ndev
    act = tok_dev * cfg.d_model * 2 * cfg.num_layers
    if shape.kind == "train":
        obytes = tree_bytes(opt_sds) / ndev
        mem_bytes_dev = 4 * pbytes + 2 * obytes + 12 * act
    elif shape.kind == "prefill":
        mem_bytes_dev = pbytes + 6 * act
    else:
        sbytes = tree_bytes(state_sds) / ndev
        mem_bytes_dev = pbytes + 2 * sbytes

    terms = rl.roofline_terms(flops_dev, mem_bytes_dev,
                              ana["collective_bytes_total"])
    record.update(
        ok=True, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory_analysis=mem, cost_analysis=cost,
        hlo_bytes=len(hlo),
        collectives=ana["collective_wire_bytes"],
        collective_logical=ana["collective_logical_bytes"],
        collective_bytes_per_dev=ana["collective_bytes_total"],
        hlo_dot_flops_per_dev=flops_dev,
        model_flops_global=mf,
        model_flops_per_dev=mf / ndev,
        useful_flops_ratio=(mf / ndev) / max(flops_dev, 1.0),
        mem_bytes_per_dev=mem_bytes_dev,
        param_bytes_per_dev=pbytes,
        **terms,
    )
    return record


def lower_brain_cell(shape_name, multi_pod, sets=None):
    """The paper's own workload as a dry-run row (ranks = all mesh devices)."""
    from repro.configs.msp_brain import CONFIG as BRAIN
    from repro.core import engine as brain_engine
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = math.prod(mesh.shape.values())
    n_per = int(shape_name.split("_")[-1].replace("k", "")) * 1024 \
        if "_" in shape_name else BRAIN.neurons_per_rank
    cfg = dataclasses.replace(BRAIN, neurons_per_rank=n_per)
    for kv in sets or []:
        k, v = kv.split("=", 1)
        cfg = dataclasses.replace(cfg, **{k: (int(v) if v.isdigit() else v)})
    t0 = time.time()
    lowered = brain_engine.lower_sim_step(cfg, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    hlo = compiled.as_text()
    ana = rl.analyze_hlo(hlo, ndev)
    terms = rl.roofline_terms(ana["dot_flops"], max(ana["dot_flops"], 1.0),
                              ana["collective_bytes_total"])
    return {"arch": "msp-brain", "shape": shape_name, "multi_pod": multi_pod,
            "mesh": "x".join(str(s) for s in mesh.shape.values()),
            "kind": "brain", "ok": True, "overrides": sets or [],
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "collectives": ana["collective_wire_bytes"],
            "collective_bytes_per_dev": ana["collective_bytes_total"],
            "hlo_dot_flops_per_dev": ana["dot_flops"], **terms}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (model or parallel field)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    try:
        rec = lower_cell(args.arch, args.shape, args.multi_pod, args.set)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multi_pod, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:],
               "overrides": args.set}
    import os as _os
    _os.makedirs(args.out, exist_ok=True)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    tag = f"__{args.tag}" if args.tag else ""
    path = f"{args.out}/{args.arch}__{args.shape}__{mesh_tag}{tag}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback", "cost_analysis",
                                   "memory_analysis")},
                     indent=1, default=str))
    sys.exit(0 if rec.get("ok") else 1)


if __name__ == "__main__":
    main()
