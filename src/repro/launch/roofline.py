"""Roofline analysis from compiled HLO (no hardware required).

Parses the post-SPMD optimized HLO text (``compiled.as_text()``, per-device
shapes) and derives:

  * collective bytes by op kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), with while-loop bodies multiplied by
    their trip counts (scan-over-layers!), and converted to *wire bytes* with
    ring-algorithm factors over the parsed replica-group size;
  * dot FLOPs (trip-count aware, so scanned layers count L times);
  * the three roofline terms in seconds per step on TPU v5e constants.

The memory term uses ``compiled.cost_analysis()`` "bytes accessed" when the
backend reports it, corrected for loop trip counts by the same multiplier
machinery, with an analytic floor of one full parameter+optimizer sweep.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, Optional

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?([%\w\.\-]+)\s*=\s*(.*)$")


def _split_def(clean_line: str):
    """'%x = <shape> <opcode>(...)' -> (name, shape, opcode) or None.
    Handles tuple shapes by paren matching."""
    m = _ASSIGN_RE.match(clean_line)
    if not m:
        return None
    name, rhs = m.groups()
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        shape, rest = rhs[:end], rhs[end:]
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape, rest = rhs[:sp], rhs[sp:]
    rest = rest.strip()
    par = rest.find("(")
    if par <= 0:
        return None
    kind = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", kind):
        return None
    return name.lstrip("%"), shape, kind
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|true_computation|calls|"
                        r"false_computation)=([%\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([%\w\.\-, ]+)\}")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_REPL_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class HloOp:
    name: str
    shape: str
    kind: str
    line: str


def _parse_computations(hlo: str):
    """Split module text into computations: name -> list[HloOp].

    Computation headers sit at column 0 (optionally prefixed ENTRY) and end
    with '{'; ops are indented. Block comments (/*index=N*/) are stripped
    before op parsing — tuple shapes embed '=' inside them.
    """
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line[0].isspace() and line.rstrip().endswith("{"):
            tok = line.split()
            name = tok[1] if tok[0] == "ENTRY" else tok[0]
            cur = name.lstrip("%")
            comps[cur] = []
            continue
        if cur is None:
            continue
        clean = _COMMENT_RE.sub("", line)
        d = _split_def(clean)
        if d:
            name, shape, kind = d
            comps[cur].append(HloOp(name, shape, kind, clean.strip()))
    return comps


def _trip_count(comps, cond_name: str) -> int:
    """Largest integer-typed constant in the while condition computation
    (scan lowers to `compare(counter, constant(L))`)."""
    best = 1
    for op in comps.get(cond_name, []):
        if op.kind == "constant" and re.match(r"^\(?[su](8|16|32|64)\[",
                                              op.shape.strip()):
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _called_comps(op: HloOp):
    out = [m.group(1).lstrip("%") for m in _CALLED_RE.finditer(op.line)]
    for m in _BRANCHES_RE.finditer(op.line):
        out.extend(n.strip().lstrip("%") for n in m.group(1).split(","))
    return out


def _multipliers(comps) -> Dict[str, float]:
    """Execution-count multiplier per computation (entry=1; while bodies x trip)."""
    mult: Dict[str, float] = defaultdict(float)
    entries = set(comps)
    called = set()
    for ops in comps.values():
        for op in ops:
            for c in _called_comps(op):
                called.add(c)
    roots = entries - called
    for r in roots:
        mult[r] = max(mult[r], 1.0)

    # propagate in passes (call graph is a DAG of modest depth)
    for _ in range(32):
        changed = False
        for cname, ops in comps.items():
            base = mult.get(cname, 0.0)
            if base <= 0:
                continue
            for op in ops:
                if op.kind == "while":
                    mcond = re.search(r"condition=([%\w\.\-]+)", op.line)
                    mbody = re.search(r"body=([%\w\.\-]+)", op.line)
                    cond = mcond.group(1).lstrip("%") if mcond else None
                    trip = _trip_count(comps, cond) if cond else 1
                    for c in _called_comps(op):
                        nm = base * trip
                        if nm > mult.get(c, 0.0):
                            mult[c] = nm
                            changed = True
                else:
                    for c in _called_comps(op):
                        if base > mult.get(c, 0.0):
                            mult[c] = base
                            changed = True
        if not changed:
            break
    return mult


def _group_size(line: str, default: int) -> int:
    m = _REPL_GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPL_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).strip("{}").split(",") if x.strip()])
    return default


def wire_factor(kind: str, n: int) -> float:
    """Ring-algorithm bytes-on-the-wire per participant, as a fraction of the
    op's result bytes."""
    if n <= 1:
        return 0.0
    if kind.startswith("all-reduce"):
        return 2.0 * (n - 1) / n
    if kind.startswith("all-gather"):
        return (n - 1) / n
    if kind.startswith("reduce-scatter"):
        return (n - 1) / n      # relative to the (larger) input; see below
    if kind.startswith("all-to-all"):
        return (n - 1) / n
    if kind.startswith("collective-permute"):
        return 1.0
    return 1.0


def analyze_hlo(hlo: str, num_devices: int):
    """Returns dict with collective bytes (logical + wire), dot flops, by-kind
    breakdown — all per device, trip-count aware."""
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)

    # name -> shape within each computation for operand lookup
    coll_logical = defaultdict(float)
    coll_wire = defaultdict(float)
    dot_flops = 0.0
    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        shapes = {op.name: op.shape for op in ops}
        for op in ops:
            if op.kind in COLLECTIVES:
                base = op.kind.replace("-start", "")
                nbytes = shape_bytes(op.shape)
                if base == "reduce-scatter":
                    # wire cost relative to the unscattered input
                    grp = _group_size(op.line, num_devices)
                    coll_logical[base] += m * nbytes
                    coll_wire[base] += m * nbytes * (grp - 1)
                else:
                    grp = _group_size(op.line, num_devices)
                    coll_logical[base] += m * nbytes
                    coll_wire[base] += m * nbytes * wire_factor(base, grp)
            elif op.kind == "dot":
                dt, out_dims = shape_elems(op.shape)
                operands = re.search(r"dot\(([^)]*)\)", op.line)
                contracted = 1
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
                if operands and cdims:
                    first = operands.group(1)
                    # operands may be typed ('f32[8,8]{1,0} %x') — shapes
                    # embed commas, so find the inline shape or the %name
                    # instead of splitting on ','
                    mshape = _SHAPE_RE.search(first)
                    mname = re.search(r"%([\w\.\-]+)", first)
                    if mshape and first.lstrip().startswith(mshape.group(1)):
                        lhs_shape = mshape.group(0)
                    else:
                        lhs = mname.group(1) if mname else \
                            first.split(",")[0].strip().lstrip("%")
                        lhs_shape = shapes.get(lhs)
                    if lhs_shape:
                        _, ldims = shape_elems(lhs_shape)
                        for ci in cdims.group(1).split(","):
                            if ci and int(ci) < len(ldims):
                                contracted *= ldims[int(ci)]
                out_elems = 1
                for d in out_dims or []:
                    out_elems *= d
                dot_flops += m * 2.0 * out_elems * contracted
    return {
        "collective_logical_bytes": dict(coll_logical),
        "collective_wire_bytes": dict(coll_wire),
        "collective_bytes_total": float(sum(coll_wire.values())),
        "dot_flops": float(dot_flops),
        "n_computations": len(comps),
    }


# ops whose result is a view / control construct, not an HBM buffer write
_NON_MATERIAL = {
    "parameter", "constant", "iota", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "custom-call", "after-all", "domain",
    "partition-id", "replica-id", "rng-get-and-update-state",
}


def materialized_bytes(hlo: str) -> float:
    """Trip-count-aware sum of result-buffer bytes over every materializing
    op in the optimized HLO — a proxy for HBM write traffic of the lowering
    (each buffer is also read at least once downstream, so relative
    comparisons of two lowerings track total traffic).

    Ops inside fusion computations are skipped (the fusion's own result is
    the only materialized buffer); while bodies are multiplied by their trip
    counts, so a scan-over-steps counts every per-step temporary."""
    comps = _parse_computations(hlo)
    mult = _multipliers(comps)
    fused = set()
    for ops in comps.values():
        for op in ops:
            if op.kind == "fusion":
                fused.update(_called_comps(op))
    total = 0.0
    for cname, ops in comps.items():
        if cname in fused:
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for op in ops:
            if op.kind in _NON_MATERIAL:
                continue
            total += m * shape_bytes(op.shape)
    return total


def roofline_terms(dot_flops_per_dev: float, mem_bytes_per_dev: float,
                   coll_bytes_per_dev: float, ici_links: float = 4.0):
    """Three roofline terms in seconds (per device, per step)."""
    t_compute = dot_flops_per_dev / HW["peak_flops_bf16"]
    t_memory = mem_bytes_per_dev / HW["hbm_bw"]
    t_coll = coll_bytes_per_dev / (HW["ici_bw"] * ici_links)
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant[1],
            "roofline_fraction": t_compute / max(
                t_compute, t_memory, t_coll, 1e-30)}
