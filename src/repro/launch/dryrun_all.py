"""Drive the full dry-run matrix: every (arch x shape) cell on both production
meshes, one subprocess per cell (clean device state; resumable via existing
JSON files).

  PYTHONPATH=src python -m repro.launch.dryrun_all [--force] [--timeout 900]
  PYTHONPATH=src python -m repro.launch.dryrun_all --only qwen2-7b
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = ["moonshot-v1-16b-a3b", "arctic-480b", "qwen2-7b", "starcoder2-15b",
         "qwen3-14b", "chatglm3-6b", "whisper-base", "llava-next-34b",
         "xlstm-125m", "recurrentgemma-2b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_cell(arch, shape, multi_pod, out, timeout, force=False, sets=(),
             tag=""):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tagsfx = f"__{tag}" if tag else ""
    path = f"{out}/{arch}__{shape}__{mesh_tag}{tagsfx}.json"
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            return rec, "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    for s in sets:
        cmd += ["--set", s]
    if tag:
        cmd += ["--tag", tag]
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
        status = "ok" if proc.returncode == 0 else "fail"
    except subprocess.TimeoutExpired:
        status = "timeout"
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "multi_pod": multi_pod,
                       "ok": False, "error": f"timeout>{timeout}s"}, f)
    rec = None
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
    return rec, f"{status} ({time.time()-t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    pods = [False] if args.single_pod_only else [False, True]
    cells = [(a, s, mp) for a in ARCHS for s in SHAPES for mp in pods
             if args.only is None or args.only == a]
    t0 = time.time()
    n_ok = n_fail = 0
    for i, (arch, shape, mp) in enumerate(cells):
        rec, status = run_cell(arch, shape, mp, args.out, args.timeout,
                               args.force)
        ok = bool(rec and rec.get("ok"))
        n_ok += ok
        n_fail += not ok
        dom = rec.get("dominant", "-") if rec else "-"
        frac = rec.get("roofline_fraction") if rec else None
        frac = f"{frac:.3f}" if isinstance(frac, float) else "-"
        skip = " SKIP" if rec and rec.get("skipped") else ""
        print(f"[{i+1}/{len(cells)}] {arch:22s} {shape:12s} "
              f"{'2x16x16' if mp else '16x16':8s} {status:12s} "
              f"dom={dom:10s} frac={frac}{skip}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed, {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
