"""jit-able step functions (train / prefill / decode) shared by the dry-run,
the training driver, and the benchmarks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.optim.optimizer import OptimizerConfig, adamw_update, init_opt_state
from repro.parallel import sharding as shd


def make_train_step(api, mesh, opt_cfg: OptimizerConfig):
    layout = api.cfg.parallel.layout

    def train_step(params, opt_state, batch):
        with shd.use_mesh(mesh, layout):
            def lf(p):
                loss, metrics = api.loss(p, batch, mesh)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt_state, stats = adamw_update(params, grads, opt_state,
                                                    opt_cfg)
        out = dict(metrics)
        out.update(stats)
        out["loss"] = loss
        return params, opt_state, out
    return train_step


def make_prefill_step(api, mesh):
    def prefill_step(params, batch):
        with shd.use_mesh(mesh):
            return api.prefill(params, batch, mesh)
    return prefill_step


def make_decode_step(api, mesh):
    def decode_step(params, state, tokens):
        with shd.use_mesh(mesh):
            return api.decode_step(params, state, tokens, mesh)
    return decode_step


def opt_config_for(cfg: ModelConfig, *, steps: int = 10_000) -> OptimizerConfig:
    warm = max(min(steps // 10, 100), 5)
    return OptimizerConfig(state_dtype=cfg.parallel.opt_state_dtype,
                           lr=1e-3, warmup_steps=warm, total_steps=steps)
