"""Automated §Perf hillclimbing driver: encode the hypothesis->change->
measure->validate loop over config overrides for one (arch, shape) cell.

For each candidate change it (a) napkin-maths the predicted delta on the
dominant roofline term, (b) compiles the cell in a subprocess, (c) records
confirmed/refuted. Greedy: applies the best confirmed change and repeats
until three consecutive candidates improve the dominant term by <5%.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch qwen2-7b \
      --shape train_4k --rounds 3
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# candidate changes with a one-line hypothesis + which term they attack
CANDIDATES = [
    (["layout=fsdp"], "collective",
     "TP all-reduces activations every layer; FSDP trades them for bf16 "
     "weight gathers ~3x params/dev"),
    (["remat=dots_saveable"], "compute",
     "full remat recomputes every dot in bwd; saving dot outputs removes "
     "the recompute flops"),
    (["moe_strategy=move_compute"], "collective",
     "paper's location-aware dispatch: tokens move, not expert weights"),
    (["moe_strategy=move_data"], "collective",
     "inverse: weights move once per layer; wins when T_dev*k*d > E*3*d*ff"),
    (["capacity_factor=1.0"], "compute",
     "MoE capacity padding is 25% wasted expert flops"),
    (["ce_mode=vocab_parallel"], "collective",
     "compute partial CE on each vocab shard; psum scalars instead of "
     "gathering (B,S,V) logits"),
]


def run_cell(arch, shape, sets, tag, out="experiments/hillclimb",
             timeout=900):
    os.makedirs(out, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out, "--tag", tag]
    for s in sets:
        cmd += ["--set", s]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    subprocess.run(cmd, capture_output=True, timeout=timeout, env=env)
    path = f"{out}/{arch}__{shape}__16x16__{tag}.json"
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def dominant_term(rec):
    return {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
            "collective": rec["t_collective_s"]}[rec["dominant"]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--rounds", type=int, default=4)
    args = ap.parse_args()

    base = run_cell(args.arch, args.shape, [], "hc_base")
    if not base or not base.get("ok"):
        sys.exit(f"baseline failed: {base and base.get('error')}")
    applied: list = []
    print(f"baseline: dominant={base['dominant']} "
          f"t={dominant_term(base):.3f}s frac={base['roofline_fraction']:.3f}")
    stale = 0
    for rnd in range(args.rounds):
        if stale >= 3:
            print("stopping: 3 consecutive <5% improvements")
            break
        best = None
        for i, (sets, term, hyp) in enumerate(CANDIDATES):
            if any(s in applied for s in sets):
                continue
            if term != base["dominant"] and base["roofline_fraction"] < 0.9:
                continue  # attack the dominant term first
            rec = run_cell(args.arch, args.shape, applied + sets,
                           f"hc_r{rnd}_c{i}")
            if not rec or not rec.get("ok"):
                print(f"  [{'+'.join(sets)}] FAILED to compile — refuted")
                continue
            t_new = dominant_term(base)
            t_after = {"compute": rec["t_compute_s"],
                       "memory": rec["t_memory_s"],
                       "collective": rec["t_collective_s"]}[base["dominant"]]
            gain = 1 - t_after / t_new
            verdict = "CONFIRMED" if gain > 0.05 else "refuted(<5%)"
            print(f"  [{'+'.join(sets)}] {hyp[:60]}... "
                  f"{base['dominant']} {t_new:.3f}->{t_after:.3f}s "
                  f"({gain * 100:+.0f}%) {verdict}")
            if gain > 0.05 and (best is None or gain > best[0]):
                best = (gain, sets, rec)
        if best is None:
            stale += 1
            continue
        stale = 0
        applied += best[1]
        base = best[2]
        print(f"round {rnd}: applied {best[1]} -> dominant={base['dominant']} "
              f"frac={base['roofline_fraction']:.3f}")
    print(f"final: overrides={applied} frac={base['roofline_fraction']:.3f} "
          f"dominant={base['dominant']}")


if __name__ == "__main__":
    main()
