"""Production mesh construction.

Importing this module never touches jax device state; meshes are built only
inside the factory functions. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax import
(see dryrun.py lines 1-2).
"""
from __future__ import annotations

from repro import compat
from repro.compat import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ('data','model').
    Multi-pod: 2x16x16 = 512 chips ('pod','data','model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=(AxisType.Auto,) * len(shape))


def make_mesh(shape, axes):
    """Arbitrary test mesh, e.g. ((2,4), ('data','model')) on host devices."""
    return compat.make_mesh(tuple(shape), tuple(axes),
                            axis_types=(AxisType.Auto,) * len(shape))


# TPU v5e hardware model for the roofline (targets, not the CPU runtime)
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link (~4 links usable per chip)
    "hbm_bytes": 16e9,
}
