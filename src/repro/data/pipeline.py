"""Deterministic synthetic token pipeline: sharded, double-buffered, seekable.

Production shape: each DP shard materializes only its slice of the global
batch; ``state = (seed, step)`` makes the stream exactly resumable from a
checkpoint (data order survives restarts AND elastic resharding, because
sample identity depends only on (seed, global step, global row index)).

The generator is a structured Zipf-ish Markov stream (not iid uniform) so
cross-entropy actually decreases during the example training runs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Markov structure: tok_{t+1} = (a * tok_t + drift) % V with noise
    noise_p: float = 0.15


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 — stateless hash, so sample identity depends only
    on (seed, step, row, t): sharding/elastic-resume reproduce exact streams."""
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        x = ((x ^ (x >> np.uint64(30)))
             * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
        x = ((x ^ (x >> np.uint64(27)))
             * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
        return x ^ (x >> np.uint64(31))


PATTERN_LEN = 8


def _batch_for_step(cfg: DataConfig, step: int, rows: np.ndarray):
    """Deterministic rows of the global batch (row identity is global).

    Each row repeats a per-(row, step) pattern of PATTERN_LEN tokens with
    noise_p corruption — learnable by induction (copy from t-8), so example
    training runs show real loss curves down to the noise floor."""
    v = cfg.vocab_size
    rows = rows.astype(np.uint64)
    base = (np.uint64(cfg.seed) * np.uint64(0x1000003)
            + np.uint64(step) * np.uint64(0x10001)).astype(np.uint64)
    pi = np.arange(PATTERN_LEN, dtype=np.uint64)
    pattern = _splitmix64(base[None] + rows[:, None] * np.uint64(7919)
                          + pi[None, :] * np.uint64(104_729)) % np.uint64(v)
    ts = np.arange(cfg.seq_len, dtype=np.uint64)
    h = _splitmix64(base[None] + rows[:, None] * np.uint64(65_537)
                    + ts[None, :] * np.uint64(257))
    noise = (h % np.uint64(10_000)) < np.uint64(int(cfg.noise_p * 10_000))
    rand = _splitmix64(h) % np.uint64(v)
    toks = pattern[:, (np.arange(cfg.seq_len) % PATTERN_LEN)]
    toks = np.where(noise, rand, toks)
    return toks.astype(np.int32)


class TokenPipeline:
    """Iterator of {'tokens': (B_local, S)} with background prefetch."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1, start_step: int = 0, prefetch: int = 2,
                 sharding=None):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.rows = np.arange(cfg.global_batch)[
            shard_index::num_shards] if num_shards > 1 else \
            np.arange(cfg.global_batch)
        self.step = start_step
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = _batch_for_step(self.cfg, step, self.rows)
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        arr = jnp.asarray(batch)
        if self.sharding is not None:
            arr = jax.device_put(arr, self.sharding)
        return {"tokens": arr}

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step}

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
