"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The gated linear recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t)
is evaluated with ``jax.lax.associative_scan`` — the TPU-native parallel form
(log-depth, MXU/VPU friendly) instead of a sequential loop. Decode carries an
O(1) state (h plus a width-4 conv tail), so recurrentgemma runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of, init_rmsnorm, apply_rmsnorm

_C = 8.0  # decay sharpness constant from the Griffin paper


def _lin(key, shape, scale, dt):
    return (jax.random.normal(key, shape) * scale).astype(dt)


def init_rglru(key, cfg: ModelConfig, d: int):
    w = d  # lru width = d_model (recurrentgemma-2b)
    ks = jax.random.split(key, 7)
    dt = dtype_of(cfg)
    s = d ** -0.5
    # Lambda init so decay a in [0.9, 0.999] at r=1 (griffin appendix)
    u = jax.random.uniform(ks[5], (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))  # softplus^-1
    return {
        "norm": init_rmsnorm(d),
        "w_x": _lin(ks[0], (d, w), s, dt),          # recurrent branch in-proj
        "w_g": _lin(ks[1], (d, w), s, dt),          # gate branch in-proj
        "conv": _lin(ks[2], (cfg.rglru_conv_width, w), 0.3, jnp.float32),
        "w_ir": _lin(ks[3], (w, 2 * w), s, jnp.float32),  # input & recurrence gates
        "b_ir": jnp.zeros((2 * w,), jnp.float32),
        "lambda": lam,
        "w_out": _lin(ks[4], (w, d), s, dt),
        "conv_bias": jnp.zeros((w,), jnp.float32),
    }


def _conv1d_causal(x, kernel, bias):
    """Depthwise causal conv. x: (B,S,W), kernel: (K,W)."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * kernel[i] for i in range(k))
    return out + bias


def _gates(p, xc):
    """xc: (..., W) f32 -> (log_a, in_gate)."""
    ir = xc @ p["w_ir"] + p["b_ir"]
    w = p["lambda"].shape[0]
    i_g = jax.nn.sigmoid(ir[..., :w])
    r_g = jax.nn.sigmoid(ir[..., w:])
    log_a = -_C * r_g * jax.nn.softplus(p["lambda"])
    return log_a, i_g


def rglru_forward(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (B,S,d), full-sequence parallel form."""
    xn = apply_rmsnorm(p["norm"], x, cfg.norm_eps)
    xb = (xn @ p["w_x"]).astype(jnp.float32)
    gate = jax.nn.gelu((xn @ p["w_g"]).astype(jnp.float32))
    xc = _conv1d_causal(xb, p["conv"], p["conv_bias"])
    log_a, i_g = _gates(p, xc)
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_g * xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    out = (h * gate).astype(x.dtype) @ p["w_out"]
    return x + out


def rglru_init_state(cfg: ModelConfig, batch: int, d: int):
    w = d
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv_tail": jnp.zeros((batch, cfg.rglru_conv_width - 1, w),
                                   jnp.float32)}


def rglru_step(p, cfg: ModelConfig, x_t, state):
    """x_t: (B,d) -> (y, new_state)."""
    xn = apply_rmsnorm(p["norm"], x_t, cfg.norm_eps)
    xb = (xn @ p["w_x"]).astype(jnp.float32)
    gate = jax.nn.gelu((xn @ p["w_g"]).astype(jnp.float32))
    hist = jnp.concatenate([state["conv_tail"], xb[:, None, :]], axis=1)
    xc = jnp.sum(hist * p["conv"], axis=1) + p["conv_bias"]
    log_a, i_g = _gates(p, xc)
    a = jnp.exp(log_a)
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_g * xc)
    y = (h * gate).astype(x_t.dtype) @ p["w_out"]
    new = {"h": h, "conv_tail": hist[:, 1:, :]}
    return x_t + y, new
