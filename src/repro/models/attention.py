"""Attention: chunked (flash-style, online-softmax) full/causal/local attention in
pure JAX, GQA, decode attention, and the split-KV sharded decode combine.

Memory model: scores are never materialized beyond (q_chunk x kv_chunk) tiles, so
32k-token prefill fits HBM without a fused kernel; the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU-optimized version of the same math
(validated against ``repro.kernels.ref``).

``split_kv_decode`` is the paper's move-compute pattern applied to serving: each
model-axis shard computes partial attention over its slice of the KV cache and
only the tiny (o, m, l) triple crosses the interconnect — the 9-byte-response
analogue — instead of gathering the multi-GB cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_heads(q, num_kv_heads):
    """(B, Hq, S, D) -> (B, Hkv, G, S, D) for GQA."""
    b, hq, s, d = q.shape
    g = hq // num_kv_heads
    return q.reshape(b, num_kv_heads, g, s, d)


def _softcap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


def _mask_bias(q_pos, kv_pos, causal: bool, window: int):
    """(Sq, Skv) additive bias from position vectors."""
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok &= kv_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= q_pos[:, None] - kv_pos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF)


def chunked_attention(q, k, v, *, causal=True, window=0, q_positions=None,
                      kv_positions=None, q_chunk=1024, kv_chunk=1024,
                      softcap=0.0):
    """Online-softmax attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Returns (B, Hq, Sq, D).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = jnp.arange(skv)

    def _fit(s, c):  # largest chunk <= c that divides s (1500 -> 750, ...)
        c = min(c, s)
        while s % c:
            c -= 1
        return c
    q_chunk = _fit(sq, q_chunk)
    kv_chunk = _fit(skv, kv_chunk)
    nq, nk = sq // q_chunk, skv // kv_chunk

    qg = _split_heads(q, hkv)                       # (B,Hkv,G,Sq,D)
    g = qg.shape[2]
    scale = d ** -0.5
    qg = (qg.astype(jnp.float32) * scale).astype(q.dtype)

    # chunk layouts
    qg = qg.reshape(b, hkv, g, nq, q_chunk, d)
    qpos = q_positions.reshape(nq, q_chunk)
    kc = k.reshape(b, hkv, nk, kv_chunk, d)
    vc = v.reshape(b, hkv, nk, kv_chunk, d)
    kpos = kv_positions.reshape(nk, kv_chunk)

    def one_q_chunk(args):
        qi, qp = args                               # (B,Hkv,G,qc,D), (qc,)

        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kp = kv
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki,
                           preferred_element_type=jnp.float32)
            s = _softcap(s, softcap)
            s = s + _mask_bias(qp, kp, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0), kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)                  # (B,Hkv,G,qc,D)

    outs = jax.lax.map(one_q_chunk, (jnp.moveaxis(qg, 3, 0), qpos))
    # (nq,B,Hkv,G,qc,D) -> (B,Hq,Sq,D)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, sq, d)
    return out.reshape(b, hq, sq, d)


def decode_attention(q, k, v, kv_positions, cache_len, *, window=0, softcap=0.0):
    """Single-position attention against a (possibly partial/ring) KV cache.

    q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_positions: (S,) global position of
    each cache slot (-1 = never written); cache_len: scalar int (= current
    position + 1). Returns (out (B,Hq,D), m (B,Hq), l (B,Hq)) — partial-softmax
    stats so callers can combine across split-KV shards.
    """
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    qg = q.reshape(b, hkv, hq // hkv, d)
    scale = d ** -0.5
    s_ = jnp.einsum("bhgd,bhkd->bhgk", (qg.astype(jnp.float32) * scale).astype(q.dtype),
                    k, preferred_element_type=jnp.float32)
    s_ = _softcap(s_, softcap)
    kv_pos = kv_positions
    ok = (kv_pos[None, None, None, :] < cache_len) & (kv_pos >= 0)[None, None, None, :]
    if window and window > 0:
        ok &= kv_pos[None, None, None, :] > cache_len - 1 - window
    s_ = jnp.where(ok, s_, NEG_INF)
    m = jnp.max(s_, axis=-1)
    p = jnp.exp(s_ - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return (out.reshape(b, hq, d), m.reshape(b, hq), l.reshape(b, hq))


def combine_partial(out, m, l, axis_name):
    """Combine split-KV partial attention (out = unnormalized p@v, m, l) across
    ``axis_name`` with a numerically-stable softmax merge. Only (o, m, l)
    crosses the link — never the KV cache itself."""
    m_g = jax.lax.pmax(m, axis_name)
    w = jnp.exp(m - m_g)
    out = jax.lax.psum(out * w[..., None], axis_name)
    l = jax.lax.psum(l * w, axis_name)
    return out / jnp.maximum(l, 1e-30)[..., None]


def finalize_partial(out, m, l):
    """Single-shard finalize (no combine)."""
    del m
    return out / jnp.maximum(l, 1e-30)[..., None]
