"""Shared building blocks: norms, RoPE, MLPs, embeddings.

Params are plain dicts of jnp arrays; every init_* has a matching apply_*.
Weights are stored in cfg.dtype (bf16 by default); norms/logits accumulate f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- norms
def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_rmsnorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_layernorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def init_norm(cfg: ModelConfig, d: int):
    # whisper/starcoder2-style models use LayerNorm; the rest RMSNorm
    if cfg.family == "audio" or not cfg.mlp_gated and cfg.family == "dense" \
            and cfg.name.startswith("starcoder2"):
        return init_layernorm(d)
    return init_rmsnorm(d)


def apply_norm(cfg: ModelConfig, p, x):
    if "bias" in p:
        return apply_layernorm(p, x, cfg.norm_eps)
    return apply_rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, rotary_pct: float, theta: float):
    rot = int(head_dim * rotary_pct) // 2 * 2
    if rot == 0:
        return None
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv)  # (rot/2,)


def apply_rope(x, positions, cfg: ModelConfig):
    """x: (..., S, head_dim); positions: (..., S) int32. Half-split convention,
    applied to the first rotary_pct of head_dim (chatglm3: 0.5)."""
    inv = rope_freqs(x.shape[-1], cfg.rotary_pct, cfg.rope_theta)
    if inv is None:
        return x
    rot = inv.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(seq: int, d: int, offset=0):
    """Whisper-style fixed sinusoidal embeddings (frontend stub uses these too)."""
    pos = np.arange(seq)[:, None] + 0
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


# ---------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = d_ff ** -0.5
    dt = dtype_of(cfg)
    p = {"w_up": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dt),
         "w_down": (jax.random.normal(k2, (d_ff, d)) * s_out).astype(dt)}
    if cfg.mlp_gated:
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * s_in).astype(dt)
    return p


def apply_mlp(p, cfg: ModelConfig, x):
    up = x @ p["w_up"]
    if cfg.mlp_gated:
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------- embeddings
def init_embedding(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    emb = (jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)
    return {"table": emb}


def embed_tokens(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def init_lm_head(key, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    dt = dtype_of(cfg)
    w = (jax.random.normal(key, (cfg.d_model, cfg.vocab_size))
         * cfg.d_model ** -0.5).astype(dt)
    return {"w": w}


def lm_logits(head_p, embed_p, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return x @ embed_p["table"].T
    return x @ head_p["w"]
