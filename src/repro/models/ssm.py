"""xLSTM blocks: mLSTM (matrix memory, exponential gating) and sLSTM (scalar
memory, block-diagonal recurrence). Faithful recurrent forms via lax.scan;
decode carries O(1) state => xlstm runs the long_500k shape.

State layout (per block):
  mlstm: C (B,H,hd,hd), n (B,H,hd), m (B,H)
  slstm: h,c,n (B,H,hd), m (B,H)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of, init_rmsnorm, apply_rmsnorm


def _lin(key, shape, scale, dt):
    return (jax.random.normal(key, shape) * scale).astype(dt)


# =============================================================== mLSTM
def init_mlstm(key, cfg: ModelConfig, d: int):
    h = cfg.num_heads
    hd = cfg.head_dim
    inner = h * hd
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    s = d ** -0.5
    si = inner ** -0.5
    return {
        "norm": init_rmsnorm(d),
        "w_up": _lin(ks[0], (d, 2 * inner), s, dt),       # -> x_m, z(gate)
        "w_q": _lin(ks[1], (inner, inner), si, dt),
        "w_k": _lin(ks[2], (inner, inner), si, dt),
        "w_v": _lin(ks[3], (inner, inner), si, dt),
        "w_if": _lin(ks[4], (inner, 2 * h), si, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]),
        "w_down": _lin(ks[5], (inner, d), si, dt),
        "out_norm": init_rmsnorm(inner),
    }


def _mlstm_gates(p, xm, h):
    gf = xm.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_log, f_log = gf[..., :h], gf[..., h:]
    log_f = -jax.nn.softplus(-f_log)      # log sigmoid(f)
    return i_log, log_f


def mlstm_scan(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (B,S,d). Recurrent form, scan over time."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    inner = h * hd
    xn = apply_rmsnorm(p["norm"], x, cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, z = up[..., :inner], up[..., inner:]
    q = (xm @ p["w_q"]).reshape(b, s, h, hd)
    k = (xm @ p["w_k"]).reshape(b, s, h, hd) * hd ** -0.5
    v = (xm @ p["w_v"]).reshape(b, s, h, hd)
    i_log, log_f = _mlstm_gates(p, xm, h)                 # (B,S,H)

    def step(carry, t):
        c_st, n_st, m_st = carry
        qt, kt, vt, it, ft = t
        m_new = jnp.maximum(ft + m_st, it)
        fs = jnp.exp(ft + m_st - m_new)[..., None]
        is_ = jnp.exp(it - m_new)[..., None]
        c_new = fs[..., None] * c_st + is_[..., None] * (
            kt[..., :, None] * vt[..., None, :])
        n_new = fs * n_st + is_ * kt
        denom = jnp.maximum(jnp.abs(jnp.sum(n_new * qt, -1)),
                            jnp.exp(-m_new))[..., None]
        ht = jnp.einsum("bhd,bhde->bhe", qt, c_new) / denom
        return (c_new, n_new, m_new), ht.astype(x.dtype)

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    xs = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
          jnp.moveaxis(k.astype(jnp.float32), 1, 0),
          jnp.moveaxis(v.astype(jnp.float32), 1, 0),
          jnp.moveaxis(i_log, 1, 0), jnp.moveaxis(log_f, 1, 0))
    _, hs = jax.lax.scan(step, (c0, n0, m0), xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, inner)       # (B,S,H*hd)
    hs = apply_rmsnorm(p["out_norm"], hs, cfg.norm_eps)
    out = (hs * jax.nn.silu(z)) @ p["w_down"]
    return x + out


def mlstm_init_state(cfg: ModelConfig, batch: int):
    h, hd = cfg.num_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def mlstm_step(p, cfg: ModelConfig, x_t, state):
    """x_t: (B,d) single token. Returns (y (B,d), new state)."""
    b, d = x_t.shape
    h, hd = cfg.num_heads, cfg.head_dim
    inner = h * hd
    xn = apply_rmsnorm(p["norm"], x_t, cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, z = up[..., :inner], up[..., inner:]
    q = (xm @ p["w_q"]).reshape(b, h, hd).astype(jnp.float32)
    k = ((xm @ p["w_k"]).reshape(b, h, hd) * hd ** -0.5).astype(jnp.float32)
    v = (xm @ p["w_v"]).reshape(b, h, hd).astype(jnp.float32)
    it, ft = _mlstm_gates(p, xm, h)
    m_new = jnp.maximum(ft + state["m"], it)
    fs = jnp.exp(ft + state["m"] - m_new)[..., None]
    is_ = jnp.exp(it - m_new)[..., None]
    c_new = fs[..., None] * state["C"] + is_[..., None] * (
        k[..., :, None] * v[..., None, :])
    n_new = fs * state["n"] + is_ * k
    denom = jnp.maximum(jnp.abs(jnp.sum(n_new * q, -1)),
                        jnp.exp(-m_new))[..., None]
    ht = jnp.einsum("bhd,bhde->bhe", q, c_new) / denom
    hs = apply_rmsnorm(p["out_norm"], ht.reshape(b, inner).astype(x_t.dtype),
                       cfg.norm_eps)
    y = (hs * jax.nn.silu(z)) @ p["w_down"]
    return x_t + y, {"C": c_new, "n": n_new, "m": m_new}


# =============================================================== sLSTM
def init_slstm(key, cfg: ModelConfig, d: int):
    h = cfg.sslstm_heads
    hd = d // h
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    return {
        "norm": init_rmsnorm(d),
        "w_x": _lin(ks[0], (d, 4 * d), d ** -0.5, jnp.float32),  # i,f,z,o
        "r_h": _lin(ks[1], (h, hd, 4 * hd), hd ** -0.5, jnp.float32),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_down": _lin(ks[2], (d, d), d ** -0.5, dt),
        "out_norm": init_rmsnorm(d),
    }


def _slstm_cell(p, cfg, wx_t, carry):
    """wx_t: (B, 4d) precomputed input proj; carry: dict of (B,H,hd)."""
    h_heads = cfg.sslstm_heads
    hprev = carry["h"]
    b = hprev.shape[0]
    hd = hprev.shape[-1]
    rec = jnp.einsum("bhd,hde->bhe", hprev, p["r_h"])      # (B,H,4hd)
    gates = wx_t.reshape(b, h_heads, 4 * hd) + rec
    i_l, f_l, z_l, o_l = jnp.split(gates, 4, axis=-1)
    log_f = -jax.nn.softplus(-f_l)
    m_new = jnp.maximum(log_f + carry["m"][..., None],
                        i_l).max(-1)                        # (B,H) shared stabilizer
    fs = jnp.exp(log_f + carry["m"][..., None] - m_new[..., None])
    is_ = jnp.exp(i_l - m_new[..., None])
    c_new = fs * carry["c"] + is_ * jnp.tanh(z_l)
    n_new = fs * carry["n"] + is_
    h_new = jax.nn.sigmoid(o_l) * c_new / jnp.maximum(n_new, 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_scan(p, cfg: ModelConfig, x):
    b, s, d = x.shape
    h = cfg.sslstm_heads
    hd = d // h
    xn = apply_rmsnorm(p["norm"], x, cfg.norm_eps)
    wx = xn.astype(jnp.float32) @ p["w_x"] + p["b"]        # (B,S,4d)

    def step(carry, wx_t):
        new = _slstm_cell(p, cfg, wx_t, carry)
        return new, new["h"]

    carry0 = slstm_init_state_inner(cfg, b, hd)
    _, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    hs = apply_rmsnorm(p["out_norm"], hs, cfg.norm_eps)
    return x + hs @ p["w_down"]


def slstm_init_state_inner(cfg, batch, hd):
    h = cfg.sslstm_heads
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": jnp.zeros((batch, h), jnp.float32)}


def slstm_init_state(cfg: ModelConfig, batch: int, d: int):
    return slstm_init_state_inner(cfg, batch, d // cfg.sslstm_heads)


def slstm_step(p, cfg: ModelConfig, x_t, state):
    b, d = x_t.shape
    xn = apply_rmsnorm(p["norm"], x_t, cfg.norm_eps)
    wx = xn.astype(jnp.float32) @ p["w_x"] + p["b"]
    new = _slstm_cell(p, cfg, wx, state)
    hs = new["h"].reshape(b, d).astype(x_t.dtype)
    hs = apply_rmsnorm(p["out_norm"], hs, cfg.norm_eps)
    return x_t + hs @ p["w_down"], new
