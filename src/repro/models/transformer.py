"""Decoder-only LM assembled from blocks (attn / moe / mlstm / slstm / rglru).

Uniform architectures scan over stacked layer params (HLO compression — one
layer body compiled once regardless of depth); heterogeneous patterns unroll.
Decode carries a per-layer state pytree (KV cache / ring window / recurrent
state) with static shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_mlp, apply_norm, apply_rope, dtype_of,
                                 embed_tokens, init_embedding, init_lm_head,
                                 init_mlp, init_norm, lm_logits,
                                 sinusoidal_positions)
from repro.parallel import sharding as shd


# ================================================================ init
def init_attn_weights(key, cfg: ModelConfig, d: int):
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    s = d ** -0.5
    so = cfg.q_dim ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, cfg.q_dim)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, cfg.kv_dim)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, cfg.kv_dim)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (cfg.q_dim, d)) * so).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def init_layer(key, cfg: ModelConfig, kind: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "mlstm":
        return {"kind_mlstm": ssm_lib.init_mlstm(k1, cfg, cfg.d_model)}
    if kind == "slstm":
        return {"kind_slstm": ssm_lib.init_slstm(k1, cfg, cfg.d_model)}
    p = {"ln2": init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["ln1"] = init_norm(cfg, cfg.d_model)
        p["attn"] = init_attn_weights(k1, cfg, cfg.d_model)
    elif kind == "rglru":
        p["rec"] = rglru_lib.init_rglru(k1, cfg, cfg.d_model)  # owns its norm
    else:
        raise ValueError(kind)
    if cfg.d_ff:
        if cfg.moe and kind == "attn":
            p["moe"] = moe_lib.init_moe(k2, cfg, cfg.d_model)
        else:
            p["mlp"] = init_mlp(k2, cfg, cfg.d_model, cfg.d_ff)
    return p


def init_params(key, cfg: ModelConfig):
    ke, kh, kl, kf = jax.random.split(key, 4)
    pattern = cfg.pattern()
    params = {"embed": init_embedding(ke, cfg),
              "final_norm": init_norm(cfg, cfg.d_model),
              "head": init_lm_head(kh, cfg)}
    if cfg.scan_layers and len(set(pattern)) == 1 and pattern[0] == "attn":
        keys = jax.random.split(kl, cfg.num_layers)
        params["layers_stacked"] = jax.vmap(
            lambda k: init_layer(k, cfg, "attn"))(keys)
    else:
        keys = jax.random.split(kl, cfg.num_layers)
        params["layers"] = [init_layer(keys[i], cfg, pattern[i])
                            for i in range(cfg.num_layers)]
    return params


# ================================================================ blocks
def _project_qkv(p, cfg: ModelConfig, x, positions):
    """x: (B,S,d) -> q (B,Hq,S,hd), k, v (B,Hkv,S,hd) with rope + qk_norm."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = (q.astype(jnp.float32) + p["bq"]).astype(x.dtype)
        k = (k.astype(jnp.float32) + p["bk"]).astype(x.dtype)
        v = (v.astype(jnp.float32) + p["bv"]).astype(x.dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    if cfg.rotary_pct > 0:
        q = apply_rope(q, positions[None, None, :], cfg)
        k = apply_rope(k, positions[None, None, :], cfg)
    q = shd.constrain(q, ("batch", "model", None, None))
    k = shd.constrain(k, ("batch", None, None, None))
    v = shd.constrain(v, ("batch", None, None, None))
    return q, k, v


def _rms_head(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def attn_block_full(p, cfg: ModelConfig, x, positions):
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = _project_qkv(p["attn"], cfg, h, positions)
    o = attn_lib.chunked_attention(
        q, k, v, causal=True, window=cfg.attn_window,
        q_positions=positions, kv_positions=positions,
        softcap=cfg.attn_logit_softcap)
    b, hq, s, hd = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return x + o @ p["attn"]["wo"]


def ffn_block(p, cfg: ModelConfig, x, mesh):
    h = apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        y, aux = moe_lib.apply_moe(p["moe"], cfg, h, mesh=mesh)
    else:
        y, aux = apply_mlp(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    return x + y, aux


def apply_layer_full(p, cfg: ModelConfig, kind: str, x, positions, mesh):
    """One layer, full-sequence. Returns (x, aux)."""
    if kind == "mlstm":
        return ssm_lib.mlstm_scan(p["kind_mlstm"], cfg, x), jnp.zeros(())
    if kind == "slstm":
        return ssm_lib.slstm_scan(p["kind_slstm"], cfg, x), jnp.zeros(())
    if kind == "attn":
        x = attn_block_full(p, cfg, x, positions)
    elif kind == "rglru":
        x = rglru_lib.rglru_forward(p["rec"], cfg, x)  # block owns its norm
    if cfg.d_ff:
        x, aux = ffn_block(p, cfg, x, mesh)
    else:
        aux = jnp.zeros(())
    return x, aux


# ================================================================ forward
def _remat(fn, cfg: ModelConfig):
    mode = cfg.parallel.remat
    if mode == "none":
        return fn
    if mode == "dots_saveable":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None, mesh=None,
            return_hidden=False):
    """tokens: (B, S_text) int32; extra_embeds: (B, P, d) prepended (vlm stub).
    Returns (logits (B,S,V) in bf16, aux_loss scalar); with return_hidden=True
    the first element is the final hidden state (B,S,d) instead (vocab-parallel
    CE computes the logits shard-locally — see DESIGN.md §3)."""
    x = embed_tokens(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, d = x.shape
    positions = jnp.arange(s)
    if cfg.rotary_pct == 0:
        x = (x.astype(jnp.float32)
             + sinusoidal_positions(s, d)).astype(x.dtype)
    x = shd.constrain(x, ("batch", None, None))
    aux_total = jnp.zeros((), jnp.float32)

    if "layers_stacked" in params:
        def body(carry, layer_p):
            xc, aux = carry
            xn, a = apply_layer_full(layer_p, cfg, "attn", xc, positions, mesh)
            xn = shd.constrain(xn, ("batch", None, None))
            return (xn, aux + a), None
        body = _remat(body, cfg)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["layers_stacked"])
    else:
        pattern = cfg.pattern()
        for i, layer_p in enumerate(params["layers"]):
            kind = pattern[i]
            # mesh is a static closure, never a traced operand of checkpoint
            fn = _remat(
                lambda x_, pos_, p_=layer_p, k_=kind:
                apply_layer_full(p_, cfg, k_, x_, pos_, mesh), cfg)
            x, a = fn(x, positions)
            aux_total = aux_total + a
    x = apply_norm(cfg, params["final_norm"], x)
    if return_hidden:
        return x, aux_total
    logits = lm_logits(params["head"], params["embed"], cfg, x)
    logits = shd.constrain(logits, ("batch", None, "model"))
    return logits, aux_total


# ================================================================ loss
def cross_entropy(logits, labels, mask=None):
    """Dense CE in f32. logits (B,S,V), labels (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def vocab_parallel_cross_entropy(x, embed_p, head_p, cfg: ModelConfig, labels,
                                 mesh, mask=None):
    """Move-compute CE: per-shard partial max / logsumexp / target-dot over the
    vocab shard; only scalars cross the link (9-byte-response analogue) instead
    of gathering (B,S,V) logits."""
    w = head_p["w"] if not cfg.tie_embeddings else embed_p["table"].T
    baxes = shd.batch_axes(mesh)
    # inside a partial shard_map (Delta-periodic pod loop) the batch is
    # already sliced over the manual axes — the nested shard_map's specs may
    # only mention the still-automatic ones (pmean below still sees all)
    manual = compat.manual_axes()
    spec_b = tuple(a for a in baxes if a not in manual)

    def body(x_, w_, labels_):
        v_loc = w_.shape[1]
        idx = jax.lax.axis_index("model")
        logits = (x_ @ w_).astype(jnp.float32)            # (B,S,Vloc)
        m = jax.lax.pmax(jnp.max(logits, -1), "model")
        lse_loc = jnp.sum(jnp.exp(logits - m[..., None]), -1)
        lse = jnp.log(jax.lax.psum(lse_loc, "model")) + m
        lo = idx * v_loc
        inshard = (labels_ >= lo) & (labels_ < lo + v_loc)
        tgt_loc = jnp.where(
            inshard,
            jnp.take_along_axis(
                logits, jnp.clip(labels_ - lo, 0, v_loc - 1)[..., None],
                axis=-1)[..., 0],
            0.0)
        tgt = jax.lax.psum(tgt_loc, "model")
        nll = lse - tgt
        nll = jax.lax.pmean(nll, baxes)
        return jnp.mean(nll)[None]

    out = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(spec_b, None, None), P(None, "model"), P(spec_b, None)),
        out_specs=P(None), check_vma=False)(x, w, labels)
    return out[0]
