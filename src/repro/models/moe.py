"""Mixture-of-Experts with paper-mapped dispatch strategies.

The paper's contribution is *moving computation to where the data lives* instead
of downloading data to the computation (location-aware Barnes-Hut), and this is
precisely the expert-parallel design choice:

  * ``move_data``    — the "old" algorithm: all-gather the expert weights onto
                       every token's shard (RMA-download analogue).
  * ``move_compute`` — the "new" algorithm: all_to_all the *tokens* (the 42-byte
                       request analogue) to the shard owning the expert, compute
                       there, all_to_all the results back (9-byte response).
  * ``local``        — experts replicated (single device / smoke tests).
  * ``auto``         — napkin-math chooser: pick whichever strategy moves fewer
                       bytes for this (arch, shape, mesh) — the paper's principle
                       generalized into a cost model (see DESIGN.md §3).

All strategies share one sort-based local dispatch engine and produce identical
outputs when capacity is not exceeded (tested in tests/test_moe.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of, init_mlp, apply_mlp


# ------------------------------------------------------------ params
def init_moe(key, cfg: ModelConfig, d: int):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt = dtype_of(cfg)
    e, ff = cfg.num_experts, cfg.d_ff
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "w_up": (jax.random.normal(k2, (e, d, ff)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k3, (e, ff, d)) * s_out).astype(dt),
    }
    if cfg.mlp_gated:
        p["w_gate"] = (jax.random.normal(k4, (e, d, ff)) * s_in).astype(dt)
    if cfg.moe_dense_residual:
        p["dense"] = init_mlp(k5, cfg, d, cfg.d_ff)
    return p


# ------------------------------------------------------------ routing
def topk_routing(router_w, x2d, k: int):
    """x2d: (T, d) -> gates (T, k) f32 (renormalized), expert ids (T, k) i32,
    plus the load-balancing aux loss (Switch-style)."""
    logits = x2d.astype(jnp.float32) @ router_w          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    e = router_w.shape[1]
    # aux: mean prob per expert x fraction of tokens routed to expert
    frac_prob = jnp.mean(probs, axis=0)
    onehot_top1 = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    frac_tok = jnp.mean(onehot_top1, axis=0)
    aux = e * jnp.sum(frac_prob * frac_tok)
    return gates, experts, aux


def positions_within(ids, num_buckets: int):
    """Rank of each element within its bucket (stable, sort-based).
    ids: (N,) int32 in [0, num_buckets). Returns (N,) int32."""
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    first = jnp.searchsorted(sorted_ids, jnp.arange(num_buckets), side="left")
    ranks = jnp.arange(n, dtype=jnp.int32) - first[sorted_ids].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks)


def _capacity(n_tokens: int, k: int, buckets: int, factor: float, minimum=4):
    c = int(math.ceil(n_tokens * k / buckets * factor))
    return max(minimum, -(-c // 8) * 8)  # round up to 8 lanes


# ------------------------------------------------------------ local engine
def _expert_ffn(w_gate, w_up, w_down, cfg: ModelConfig, buf):
    """buf: (E, C, d) -> (E, C, d)."""
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    if cfg.mlp_gated:
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * up
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, w_down)


def moe_local(p_router, w_gate, w_up, w_down, cfg: ModelConfig, x2d,
              capacity_factor=None):
    """All experts resident locally. x2d: (T, d) -> (T, d), aux."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    gates, experts, aux = topk_routing(p_router, x2d, k)
    cap = _capacity(t, k, e, cf)

    flat_e = experts.reshape(-1)                          # (T*k,)
    pos = positions_within(flat_e, e)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                     # OOB scatter -> dropped
    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    buf = jnp.zeros((e, cap, d), x2d.dtype)
    buf = buf.at[flat_e, pos_c].set(x2d[tok_idx], mode="drop")
    out_buf = _expert_ffn(w_gate, w_up, w_down, cfg, buf)
    y_tok = out_buf.at[flat_e, pos_c].get(mode="fill", fill_value=0.0)
    y_tok = y_tok * keep[:, None]
    y = jnp.sum((y_tok.reshape(t, k, d).astype(jnp.float32)
                 * gates[..., None]), axis=1)
    return y.astype(x2d.dtype), aux


# ------------------------------------------------------------ sharded engines
def _gather_over(axis_name, w, axis):
    """FSDP all-gather of a weight slice along ``axis`` over mesh axis."""
    if w is None:
        return None
    return jax.lax.all_gather(w, axis_name, axis=axis, tiled=True)


def moe_move_data(p, cfg: ModelConfig, x2d, *, model_axis="model",
                  data_axes=("data",)):
    """Paper's OLD pattern inside shard_map: all-gather expert weights to every
    shard (download the data), then compute locally."""
    # weights arrive sharded (E/model, d/data, ff); gather both axes fully
    def g(w, shard_axis):
        if w is None:
            return None
        w = jax.lax.all_gather(w, model_axis, axis=0, tiled=True)
        for ax in data_axes:
            w = jax.lax.all_gather(w, ax, axis=shard_axis, tiled=True)
        return w
    w_up = g(p["w_up"], 1)
    w_down = g(p["w_down"], 1)
    w_gate = g(p.get("w_gate"), 1)
    return moe_local(p["router"], w_gate, w_up, w_down, cfg, x2d)


def moe_move_compute(p, cfg: ModelConfig, x2d, *, model_axis="model",
                     data_axes=("data",)):
    """Paper's NEW pattern: ship tokens (requests) to the expert's owner shard,
    compute there, ship results (responses) back. Two all_to_alls, no weight
    movement across the model axis."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.top_k
    p_sz = compat.axis_size(model_axis)
    e_loc = e // p_sz
    assert e % p_sz == 0, (e, p_sz)

    # local experts: undo fsdp sharding over data axes only (E_loc slice stays)
    def g(w):
        if w is None:
            return None
        for ax in data_axes:
            w = jax.lax.all_gather(w, ax, axis=1, tiled=True)
        return w
    w_up, w_down, w_gate = g(p["w_up"]), g(p["w_down"]), g(p.get("w_gate"))

    gates, experts, aux = topk_routing(p["router"], x2d, k)

    # ---- build per-peer request buffers (the 42-byte request analogue) ----
    flat_e = experts.reshape(-1).astype(jnp.int32)        # (N=T*k,)
    peer = flat_e // e_loc                                # owning shard
    cap_p = _capacity(t, k, p_sz, cfg.capacity_factor)
    pos_p = positions_within(peer, p_sz)
    keep = pos_p < cap_p
    pos_pc = jnp.where(keep, pos_p, cap_p)
    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    send_tok = jnp.zeros((p_sz, cap_p, d), x2d.dtype)
    send_tok = send_tok.at[peer, pos_pc].set(x2d[tok_idx], mode="drop")
    send_e = jnp.full((p_sz, cap_p), -1, jnp.int32)
    send_e = send_e.at[peer, pos_pc].set(flat_e % e_loc, mode="drop")

    recv_tok = jax.lax.all_to_all(send_tok, model_axis, 0, 0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, model_axis, 0, 0, tiled=True)

    # ---- owner-side computation (the "calculation request" handler) ----
    r_tok = recv_tok.reshape(p_sz * cap_p, d)
    r_e = recv_e.reshape(p_sz * cap_p)
    valid = r_e >= 0
    r_e_c = jnp.where(valid, r_e, 0)
    cap_e = _capacity(p_sz * cap_p, 1, e_loc, cfg.capacity_factor)
    pos_e = positions_within(jnp.where(valid, r_e_c, e_loc), e_loc + 1)
    keep_e = valid & (pos_e < cap_e)
    pos_ec = jnp.where(keep_e, pos_e, cap_e)
    buf = jnp.zeros((e_loc, cap_e, d), x2d.dtype)
    buf = buf.at[r_e_c, pos_ec].set(r_tok, mode="drop")
    out_buf = _expert_ffn(w_gate, w_up, w_down, cfg, buf)
    r_out = out_buf.at[r_e_c, pos_ec].get(mode="fill", fill_value=0.0)
    r_out = r_out * keep_e[:, None]

    # ---- responses travel back (the 9-byte response analogue) ----
    send_back = r_out.reshape(p_sz, cap_p, d)
    recv_back = jax.lax.all_to_all(send_back, model_axis, 0, 0, tiled=True)
    y_tok = recv_back.at[peer, pos_pc].get(mode="fill", fill_value=0.0)
    y_tok = y_tok * keep[:, None]
    y = jnp.sum((y_tok.reshape(t, k, d).astype(jnp.float32)
                 * gates[..., None]), axis=1)
    return y.astype(x2d.dtype), aux


# ------------------------------------------------------------ cost model
def moe_strategy_cost(cfg: ModelConfig, t_local: int, model_size: int,
                      bytes_per_el=2):
    """Bytes crossing the model axis per device per layer, fwd only.
    The 'auto' chooser (paper principle as a cost model) picks the min."""
    e = cfg.num_experts
    e_loc = max(1, e // max(model_size, 1))
    n_mats = 3 if cfg.mlp_gated else 2
    w_bytes = (e - e_loc) * n_mats * cfg.d_model * cfg.d_ff * bytes_per_el
    frac_remote = (model_size - 1) / max(model_size, 1)
    tok_bytes = 2 * t_local * cfg.top_k * cfg.d_model * bytes_per_el * frac_remote
    return {"move_data": w_bytes, "move_compute": tok_bytes}


def choose_strategy(cfg: ModelConfig, t_local: int, model_size: int) -> str:
    c = moe_strategy_cost(cfg, t_local, model_size)
    return "move_data" if c["move_data"] < c["move_compute"] else "move_compute"


# ------------------------------------------------------------ entry point
def apply_moe(p, cfg: ModelConfig, x, *, mesh=None, strategy=None):
    """x: (B, S, d) -> (y, aux). Dispatches per cfg.parallel.moe_strategy."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    strategy = strategy or cfg.parallel.moe_strategy
    model_size = 1
    axis_names = ()
    if mesh is not None:
        model_size = mesh.shape.get("model", 1)
        axis_names = tuple(mesh.axis_names)
    ndev = math.prod(mesh.shape.values()) if mesh is not None else 1
    if strategy == "auto":
        t_local = (b * s) // max(1, ndev)
        strategy = choose_strategy(cfg, t_local, model_size) \
            if model_size > 1 else "local"
    if mesh is None or model_size <= 1 or strategy == "local":
        y, aux = moe_local(p["router"], p.get("w_gate"), p["w_up"], p["w_down"],
                           cfg, x2d)
    else:
        data_axes = tuple(a for a in axis_names if a != "model")
        wspec2 = jax.sharding.PartitionSpec(
            "model", data_axes if data_axes else None, None)
        p_moe = {k: v for k, v in p.items() if k != "dense"}
        in_specs = {k: (jax.sharding.PartitionSpec() if k == "router" else wspec2)
                    for k in p_moe}
        fn = moe_move_data if strategy == "move_data" else moe_move_compute
        from repro.parallel import sharding as shd
        tok_axes = shd.batch_axes(mesh, cfg.parallel.layout)
        x_spec = jax.sharding.PartitionSpec(
            tok_axes if tok_axes else None, None)
        # tokens additionally split over the model axis INSIDE the body —
        # otherwise all model shards redundantly compute identical expert FFNs
        # (16x waste at 16-way TP). Done with slice + all_gather rather than a
        # jit-boundary reshard, which GSPMD handles pathologically (full
        # remat). In 'fsdp' layout tokens already arrive model-split.
        split_model = ("model" not in tok_axes
                       and (b * s) % ndev == 0 and model_size > 1)

        def body(p_, x2d_):
            x_in = x2d_
            if split_model:
                t_m = x2d_.shape[0] // model_size
                idx = jax.lax.axis_index("model")
                x_in = jax.lax.dynamic_slice_in_dim(x2d_, idx * t_m, t_m, 0)
            y_, aux_ = fn(p_, cfg, x_in, model_axis="model",
                          data_axes=data_axes)
            if split_model:
                y_ = jax.lax.all_gather(y_, "model", axis=0, tiled=True)
            for ax in mesh.axis_names:       # replicate aux across the mesh
                aux_ = jax.lax.pmean(aux_, ax)
            return y_, aux_

        y, aux = compat.shard_map(
            body, mesh=mesh, in_specs=(in_specs, x_spec),
            out_specs=(x_spec, jax.sharding.PartitionSpec()),
            check_vma=False)(p_moe, x2d)
    if cfg.moe_dense_residual:
        y = y + apply_mlp(p["dense"], cfg, x2d)
    return y.reshape(b, s, d), aux
