"""Whisper-style encoder-decoder backbone. The conv audio frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, d_model),
per the assignment sheet. Positions are sinusoidal (frontend-stub convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import (apply_mlp, apply_norm, dtype_of, embed_tokens,
                                 init_embedding, init_lm_head, init_mlp,
                                 init_norm, lm_logits, sinusoidal_positions)
from repro.models.transformer import init_attn_weights, _project_qkv
from repro.models.decode import _ring_positions
from repro.parallel import sharding as shd


def _mha(p, cfg, xq, xkv, causal):
    """Full attention between xq (B,Sq,d) and xkv (B,Skv,d)."""
    b, sq, _ = xq.shape
    skv = xkv.shape[1]
    q = (xq @ p["wq"]).reshape(b, sq, cfg.num_heads, cfg.head_dim)
    k = (xkv @ p["wk"]).reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    v = (xkv @ p["wv"]).reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    o = attn_lib.chunked_attention(q, k, v, causal=causal,
                                   q_positions=jnp.arange(sq),
                                   kv_positions=jnp.arange(skv))
    o = o.transpose(0, 2, 1, 3).reshape(b, sq, cfg.q_dim)
    return o @ p["wo"]


def init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg, cfg.d_model),
            "attn": init_attn_weights(k1, cfg, cfg.d_model),
            "ln2": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(k2, cfg, cfg.d_model, cfg.d_ff)}


def init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg, cfg.d_model),
            "attn": init_attn_weights(k1, cfg, cfg.d_model),
            "ln_x": init_norm(cfg, cfg.d_model),
            "xattn": init_attn_weights(k2, cfg, cfg.d_model),
            "ln2": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(k3, cfg, cfg.d_model, cfg.d_ff)}


def init_params(key, cfg: ModelConfig):
    ke, kh, kl, kd = jax.random.split(key, 4)
    return {
        "embed": init_embedding(ke, cfg),
        "head": init_lm_head(kh, cfg),
        "enc_layers": [init_enc_layer(k, cfg)
                       for k in jax.random.split(kl, cfg.encoder_layers)],
        "dec_layers": [init_dec_layer(k, cfg)
                       for k in jax.random.split(kd, cfg.num_layers)],
        "enc_norm": init_norm(cfg, cfg.d_model),
        "final_norm": init_norm(cfg, cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, S_enc, d) precomputed embeddings -> memory (B, S_enc, d)."""
    x = frames.astype(dtype_of(cfg))
    x = (x.astype(jnp.float32)
         + sinusoidal_positions(x.shape[1], x.shape[2])).astype(x.dtype)
    x = shd.constrain(x, ("batch", None, None))
    for p in params["enc_layers"]:
        h = apply_norm(cfg, p["ln1"], x)
        x = x + _mha(p["attn"], cfg, h, h, causal=False)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], cfg, h)
    return apply_norm(cfg, params["enc_norm"], x)


def forward(params, cfg: ModelConfig, frames, tokens, *, mesh=None):
    """Teacher-forced decoder over full token sequence. -> (logits, aux=0)."""
    mem = encode(params, cfg, frames)
    x = embed_tokens(params["embed"], tokens)
    x = (x.astype(jnp.float32)
         + sinusoidal_positions(x.shape[1], x.shape[2])).astype(x.dtype)
    x = shd.constrain(x, ("batch", None, None))
    for p in params["dec_layers"]:
        h = apply_norm(cfg, p["ln1"], x)
        x = x + _mha(p["attn"], cfg, h, h, causal=True)
        h = apply_norm(cfg, p["ln_x"], x)
        x = x + _mha(p["xattn"], cfg, h, mem, causal=False)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], cfg, h)
    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(params["head"], params["embed"], cfg, x), jnp.zeros(())


# ---------------------------------------------------------------- serving
def init_decode_state(params_or_none, cfg: ModelConfig, batch: int,
                      max_seq: int):
    dt = dtype_of(cfg)
    kv = (batch, cfg.num_kv_heads, max_seq, cfg.head_dim)
    xkv = (batch, cfg.num_kv_heads, cfg.encoder_seq, cfg.head_dim)
    layers = [{"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
               "xk": jnp.zeros(xkv, dt), "xv": jnp.zeros(xkv, dt)}
              for _ in range(cfg.num_layers)]
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers}


def prefill(params, cfg: ModelConfig, frames, tokens, *, mesh=None,
            pad_cache_to=0):
    """Encode audio + run decoder over the prompt, building all caches."""
    mem = encode(params, cfg, frames)
    b, s = tokens.shape
    smax = max(pad_cache_to, s)
    x = embed_tokens(params["embed"], tokens)
    x = (x.astype(jnp.float32) + sinusoidal_positions(s, x.shape[2])
         ).astype(x.dtype)
    layers = []
    for p in params["dec_layers"]:
        h = apply_norm(cfg, p["ln1"], x)
        k = (h @ p["attn"]["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim
                                          ).transpose(0, 2, 1, 3)
        v = (h @ p["attn"]["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim
                                          ).transpose(0, 2, 1, 3)
        x = x + _mha(p["attn"], cfg, h, h, causal=True)
        h = apply_norm(cfg, p["ln_x"], x)
        xk = (mem @ p["xattn"]["wk"]).reshape(b, -1, cfg.num_kv_heads,
                                              cfg.head_dim).transpose(0, 2, 1, 3)
        xv = (mem @ p["xattn"]["wv"]).reshape(b, -1, cfg.num_kv_heads,
                                              cfg.head_dim).transpose(0, 2, 1, 3)
        x = x + _mha(p["xattn"], cfg, h, mem, causal=False)
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], cfg, h)
        pad = ((0, 0), (0, 0), (0, smax - s), (0, 0))
        layers.append({"k": jnp.pad(k, pad), "v": jnp.pad(v, pad),
                       "xk": xk, "xv": xv})
    x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    logits = lm_logits(params["head"], params["embed"], cfg, x)[:, 0, :]
    return logits, {"pos": jnp.asarray(s, jnp.int32), "layers": layers}


def decode_step(params, cfg: ModelConfig, state, tokens, *, mesh=None):
    pos = state["pos"]
    b = tokens.shape[0]
    x = embed_tokens(params["embed"], tokens)
    d = x.shape[-1]
    # position embedding at `pos` via dynamic slice of a static table
    table = sinusoidal_positions(state["layers"][0]["k"].shape[2], d)
    pe = jax.lax.dynamic_slice_in_dim(table, pos, 1, 0)[0]
    x = (x.astype(jnp.float32) + pe).astype(x.dtype)
    new_layers = []
    for p, lstate in zip(params["dec_layers"], state["layers"]):
        h = apply_norm(cfg, p["ln1"], x[:, None, :])
        q, k, v = _project_qkv(p["attn"], cfg, h, pos[None])
        q = q[:, :, 0, :]
        nk = jax.lax.dynamic_update_slice(lstate["k"], k, (0, 0, pos, 0))
        nv = jax.lax.dynamic_update_slice(lstate["v"], v, (0, 0, pos, 0))
        kv_pos = jnp.arange(nk.shape[2])
        o, m, l = attn_lib.decode_attention(q, nk, nv, kv_pos, pos + 1)
        o = attn_lib.finalize_partial(o, m, l)
        x = x + (o.reshape(b, cfg.q_dim).astype(x.dtype) @ p["attn"]["wo"])
        # cross attention against fixed encoder K/V
        h = apply_norm(cfg, p["ln_x"], x[:, None, :])
        qx = (h @ p["xattn"]["wq"]).reshape(b, cfg.num_heads, cfg.head_dim)
        ox, mx, lx = attn_lib.decode_attention(
            qx, lstate["xk"], lstate["xv"], jnp.arange(lstate["xk"].shape[2]),
            jnp.asarray(lstate["xk"].shape[2], jnp.int32))
        ox = attn_lib.finalize_partial(ox, mx, lx)
        x = x + (ox.reshape(b, cfg.q_dim).astype(x.dtype) @ p["xattn"]["wo"])
        h = apply_norm(cfg, p["ln2"], x)
        x = x + apply_mlp(p["mlp"], cfg, h)
        new_layers.append({"k": nk, "v": nv, "xk": lstate["xk"],
                           "xv": lstate["xv"]})
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(params["head"], params["embed"], cfg, x)
    return logits, {"pos": pos + 1, "layers": new_layers}
