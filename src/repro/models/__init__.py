from repro.models.model import (ModelAPI, build_model, decode_state_specs,
                                input_specs, param_specs)
