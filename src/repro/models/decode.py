"""Serving: prefill (full-sequence forward producing state) and single-token
decode steps for every block kind.

State layouts (static shapes):
  attn (full)    : k, v (B, Hkv, S_max, hd)          slot = position
  attn (window)  : k, v (B, Hkv, W, hd)  ring buffer  slot = position % W
  rglru          : h (B, W), conv_tail (B, K-1, W)
  mlstm / slstm  : recurrent dicts from repro.models.ssm

``decode_attention='split_kv'`` shards the full KV cache's sequence axis over
the model axis and combines per-shard partial softmax stats with a psum — the
paper's move-compute pattern (ship the tiny (o,m,l) response, not the cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_mlp, apply_norm, apply_rope, dtype_of,
                                 embed_tokens, lm_logits, sinusoidal_positions)
from repro.models.transformer import _project_qkv, ffn_block, _rms_head
from repro.parallel import sharding as shd


# ================================================================ state init
def _attn_cache(cfg: ModelConfig, batch: int, max_seq: int):
    s = cfg.attn_window if cfg.attn_window else max_seq
    dt = dtype_of(cfg)
    shape = (batch, cfg.num_kv_heads, s, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def init_layer_state(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind == "attn":
        return _attn_cache(cfg, batch, max_seq)
    if kind == "rglru":
        return rglru_lib.rglru_init_state(cfg, batch, cfg.d_model)
    if kind == "mlstm":
        return ssm_lib.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return ssm_lib.slstm_init_state(cfg, batch, cfg.d_model)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    pattern = cfg.pattern()
    if cfg.scan_layers and len(set(pattern)) == 1 and pattern[0] == "attn":
        one = _attn_cache(cfg, batch, max_seq)
        layers = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one)
    else:
        layers = [init_layer_state(cfg, k, batch, max_seq) for k in pattern]
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers}


def state_shardings(cfg: ModelConfig, state_shapes, mesh, batch: int):
    """Sharding rules for the decode state (dry-run in_shardings)."""
    import math as _math
    baxes = shd.batch_axes(mesh)
    bsize = _math.prod(mesh.shape[a] for a in baxes) if baxes else 1
    stacked = not isinstance(state_shapes.get("layers"), list)
    split_kv = cfg.parallel.decode_attention == "split_kv" and \
        mesh.shape.get("model", 1) > 1 and not cfg.attn_window

    def one(path, leaf):
        name = shd._path_str(path)
        nd = len(leaf.shape)
        if name.endswith("pos"):
            return NamedSharding(mesh, P())
        off = 1 if (stacked and name.startswith("layers")) else 0
        spec = [None] * nd
        if nd > off and leaf.shape[off] % max(bsize, 1) == 0 and \
                leaf.shape[off] >= bsize:
            spec[off] = baxes
        if split_kv and (name.endswith("/k") or name.endswith("/v")) and \
                nd == off + 4 and leaf.shape[off + 2] % mesh.shape["model"] == 0:
            spec[off + 2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shapes)


# ================================================================ attn decode
def _ring_positions(cfg: ModelConfig, pos, cache_slots: int):
    """Global position held by each cache slot after writing position ``pos``."""
    slots = jnp.arange(cache_slots)
    if cfg.attn_window:
        w = cache_slots
        return pos - ((pos - slots) % w)
    return slots


def attn_block_decode(p, cfg: ModelConfig, x_t, cache, pos, mesh):
    """x_t: (B, d); cache k/v (B,Hkv,S,hd); pos scalar. -> (y, new cache)."""
    b, d = x_t.shape
    h = apply_norm(cfg, p["ln1"], x_t[:, None, :])
    q, k, v = _project_qkv(p["attn"], cfg, h, pos[None])
    q = q[:, :, 0, :]                                    # (B,Hq,hd)
    s_cache = cache["k"].shape[2]
    slot = pos % s_cache if cfg.attn_window else pos
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
    kv_pos = _ring_positions(cfg, pos, s_cache)
    use_split = (cfg.parallel.decode_attention == "split_kv" and mesh is not None
                 and mesh.shape.get("model", 1) > 1 and not cfg.attn_window
                 and s_cache % mesh.shape["model"] == 0)
    if use_split:
        import math as _math
        baxes = shd.batch_axes(mesh)
        bsize = _math.prod(mesh.shape[a] for a in baxes) if baxes else 1
        bspec = baxes if (bsize > 0 and b % bsize == 0) else None

        def body(q_, k_, v_):
            s_loc = k_.shape[2]
            off = jax.lax.axis_index("model") * s_loc
            kvp = off + jnp.arange(s_loc)
            o, m, l = attn_lib.decode_attention(
                q_, k_, v_, kvp, pos + 1, window=cfg.attn_window,
                softcap=cfg.attn_logit_softcap)
            return attn_lib.combine_partial(o, m, l, "model")

        o = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None, "model", None),
                      P(bspec, None, "model", None)),
            out_specs=P(bspec, None, None), check_vma=False)(q, new_k, new_v)
    else:
        o, m, l = attn_lib.decode_attention(
            q, new_k, new_v, kv_pos, pos + 1, window=cfg.attn_window,
            softcap=cfg.attn_logit_softcap)
        o = attn_lib.finalize_partial(o, m, l)
    y = (o.reshape(b, cfg.q_dim).astype(x_t.dtype) @ p["attn"]["wo"])
    return x_t + y, {"k": new_k, "v": new_v}


def apply_layer_decode(p, cfg: ModelConfig, kind, x_t, lstate, pos, mesh):
    if kind == "mlstm":
        return ssm_lib.mlstm_step(p["kind_mlstm"], cfg, x_t, lstate)
    if kind == "slstm":
        return ssm_lib.slstm_step(p["kind_slstm"], cfg, x_t, lstate)
    if kind == "attn":
        x_t, lstate = attn_block_decode(p, cfg, x_t, lstate, pos, mesh)
    elif kind == "rglru":
        x_t, lstate = rglru_lib.rglru_step(p["rec"], cfg, x_t, lstate)
    if cfg.d_ff:
        x3, _ = ffn_block(p, cfg, x_t[:, None, :], mesh)
        x_t = x3[:, 0, :]
    return x_t, lstate


def decode_step(params, cfg: ModelConfig, state, tokens, *, mesh=None):
    """One token for every sequence. tokens: (B,) int32 -> (logits (B,V), state)."""
    pos = state["pos"]
    x = embed_tokens(params["embed"], tokens)            # (B, d)
    if cfg.rotary_pct == 0:
        d = x.shape[-1]
        pe = sinusoidal_positions(1, d, 0)[0]            # static stub table
        x = (x.astype(jnp.float32) + pe).astype(x.dtype)
    x = shd.constrain(x, ("batch", None))

    if "layers_stacked" in params:
        def body(x_c, xs):
            layer_p, layer_s = xs
            x_n, s_n = apply_layer_decode(layer_p, cfg, "attn", x_c, layer_s,
                                          pos, mesh)
            return x_n, s_n
        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers_stacked"],
                                      state["layers"]))
    else:
        pattern = cfg.pattern()
        new_layers = []
        for i, layer_p in enumerate(params["layers"]):
            x, s_n = apply_layer_decode(layer_p, cfg, pattern[i], x,
                                        state["layers"][i], pos, mesh)
            new_layers.append(s_n)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(params["head"], params["embed"], cfg, x)
    logits = shd.constrain(logits, ("batch", "model"))
    return logits, {"pos": pos + 1, "layers": new_layers}


# ================================================================ prefill
def _attn_prefill(p, cfg: ModelConfig, x, positions):
    from repro.models.transformer import attn_block_full
    h = apply_norm(cfg, p["ln1"], x)
    q, k, v = _project_qkv(p["attn"], cfg, h, positions)
    o = attn_lib.chunked_attention(
        q, k, v, causal=True, window=cfg.attn_window,
        q_positions=positions, kv_positions=positions,
        softcap=cfg.attn_logit_softcap)
    b, hq, s, hd = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    x = x + o @ p["attn"]["wo"]
    if cfg.attn_window:
        w = cfg.attn_window
        s_len = positions.shape[0]
        if s_len >= w:
            # last w positions; position p = s-w+i sits at slot p % w
            k, v = k[:, :, -w:, :], v[:, :, -w:, :]
            roll = s_len % w
            k = jnp.roll(k, roll, axis=2)
            v = jnp.roll(v, roll, axis=2)
        else:
            # prompt shorter than the window: slots == positions, pad the ring
            pad = ((0, 0), (0, 0), (0, w - s_len), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return x, {"k": k, "v": v}


def _rglru_prefill(p, cfg, x):
    y = rglru_lib.rglru_forward(p["rec"], cfg, x)
    # recompute final state cheaply: run last conv window through step form
    xn = apply_norm(cfg, p["rec"]["norm"], x)
    xb = (xn @ p["rec"]["w_x"]).astype(jnp.float32)
    xc = rglru_lib._conv1d_causal(xb, p["rec"]["conv"], p["rec"]["conv_bias"])
    log_a, i_g = rglru_lib._gates(p["rec"], xc)
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_g * xc)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    kw = cfg.rglru_conv_width - 1
    state = {"h": h[:, -1, :], "conv_tail": xb[:, -kw:, :]}
    return y, state


def prefill(params, cfg: ModelConfig, tokens, *, extra_embeds=None, mesh=None,
            pad_cache_to=0):
    """Full-sequence forward that also returns the decode state.
    Returns (last-position logits (B,V), state)."""
    x = embed_tokens(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, d = x.shape
    positions = jnp.arange(s)
    if cfg.rotary_pct == 0:
        x = (x.astype(jnp.float32) + sinusoidal_positions(s, d)).astype(x.dtype)
    x = shd.constrain(x, ("batch", None, None))
    pattern = cfg.pattern()

    def run_layer(layer_p, kind, xc):
        if kind == "attn":
            xc, st = _attn_prefill(layer_p, cfg, xc, positions)
        elif kind == "rglru":
            xc, st = _rglru_prefill(layer_p, cfg, xc)
        elif kind == "mlstm":
            # run full scan then recompute state from scratch (scan w/ carry out)
            xc2 = ssm_lib.mlstm_scan(layer_p["kind_mlstm"], cfg, xc)
            st = _mlstm_final_state(layer_p["kind_mlstm"], cfg, xc)
            xc = xc2
        elif kind == "slstm":
            xc2 = ssm_lib.slstm_scan(layer_p["kind_slstm"], cfg, xc)
            st = _slstm_final_state(layer_p["kind_slstm"], cfg, xc)
            xc = xc2
        else:
            raise ValueError(kind)
        if cfg.d_ff and kind in ("attn", "rglru"):
            xc, _ = ffn_block(layer_p, cfg, xc, mesh)
        return shd.constrain(xc, ("batch", None, None)), st

    def pad_full_cache(st, stacked):
        """Grow full (non-ring) KV caches to pad_cache_to slots."""
        if not pad_cache_to or cfg.attn_window:
            return st
        kv_dim = 3 if stacked else 2

        def padk(c):
            if c.ndim == kv_dim + 2 and c.shape[kv_dim] < pad_cache_to:
                width = [(0, 0)] * c.ndim
                width[kv_dim] = (0, pad_cache_to - c.shape[kv_dim])
                return jnp.pad(c, width)
            return c
        return jax.tree.map(padk, st)

    if "layers_stacked" in params:
        def body(xc, layer_p):
            xn, st = run_layer(layer_p, "attn", xc)
            return xn, st
        x, states = jax.lax.scan(body, x, params["layers_stacked"])
        layers = pad_full_cache(states, stacked=True)
    else:
        layers = []
        for i, layer_p in enumerate(params["layers"]):
            x, st = run_layer(layer_p, pattern[i], x)
            if pattern[i] == "attn" and not cfg.attn_window:
                st = pad_full_cache(st, stacked=False)
            layers.append(st)
    x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    logits = lm_logits(params["head"], params["embed"], cfg, x)[:, 0, :]
    return logits, {"pos": jnp.asarray(s, jnp.int32), "layers": layers}


def _mlstm_final_state(p, cfg, x):
    st = ssm_lib.mlstm_init_state(cfg, x.shape[0])
    # replay through step form via scan to obtain the carry

    def step(carry, x_t):
        _, new = ssm_lib.mlstm_step(p, cfg, x_t, carry)
        return new, None
    st, _ = jax.lax.scan(step, st, jnp.moveaxis(x, 1, 0))
    return st


def _slstm_final_state(p, cfg, x):
    st = ssm_lib.slstm_init_state(cfg, x.shape[0], x.shape[-1])

    def step(carry, x_t):
        _, new = ssm_lib.slstm_step(p, cfg, x_t, carry)
        return new, None
    st, _ = jax.lax.scan(step, st, jnp.moveaxis(x, 1, 0))
    return st
