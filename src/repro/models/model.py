"""Public model API: build_model(cfg) -> ModelAPI with init / loss / prefill /
decode, plus input_specs() producing ShapeDtypeStruct stand-ins for the
multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode as decode_lib
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.models.layers import dtype_of

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    loss: Callable            # (params, batch, mesh) -> (loss, metrics)
    prefill: Callable         # (params, batch, mesh) -> (logits, state)
    decode_step: Callable     # (params, state, tokens, mesh) -> (logits, state)
    init_decode_state: Callable  # (batch, max_seq) -> state


def _split_batch(cfg: ModelConfig, batch: Dict[str, Any]):
    tokens = batch["tokens"]
    extra = None
    if cfg.family == "vlm":
        extra = batch["patch_embeds"]
    return tokens, extra


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "audio":
        return _build_encdec(cfg)

    def init(key):
        return tfm.init_params(key, cfg)

    def loss(params, batch, mesh=None):
        tokens, extra = _split_batch(cfg, batch)
        n_patch = 0 if extra is None else extra.shape[1]
        # old XLA cannot nest the vocab-parallel shard_map inside a partial
        # manual region (Delta-periodic pod loop) — fall back to dense CE
        nested_ok = compat.PARTIAL_MANUAL_CONSTRAINT_OK \
            or not compat.manual_axes()
        if cfg.parallel.ce_mode == "vocab_parallel" and mesh is not None \
                and mesh.shape.get("model", 1) > 1 \
                and cfg.parallel.layout == "tp" and nested_ok:
            hidden, aux = tfm.forward(params, cfg, tokens, extra_embeds=extra,
                                      mesh=mesh, return_hidden=True)
            h = hidden[:, n_patch:-1, :]
            ce = tfm.vocab_parallel_cross_entropy(
                h, params["embed"], params["head"], cfg, tokens[:, 1:], mesh)
        else:
            logits, aux = tfm.forward(params, cfg, tokens, extra_embeds=extra,
                                      mesh=mesh)
            ce = tfm.cross_entropy(logits[:, n_patch:-1, :], tokens[:, 1:])
        total = ce + AUX_WEIGHT * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(params, batch, mesh=None, pad_cache_to=0):
        tokens, extra = _split_batch(cfg, batch)
        return decode_lib.prefill(params, cfg, tokens, extra_embeds=extra,
                                  mesh=mesh, pad_cache_to=pad_cache_to)

    def dstep(params, state, tokens, mesh=None):
        return decode_lib.decode_step(params, cfg, state, tokens, mesh=mesh)

    def dstate(batch, max_seq):
        return decode_lib.init_decode_state(cfg, batch, max_seq)

    return ModelAPI(cfg, init, loss, prefill, dstep, dstate)


def _build_encdec(cfg: ModelConfig) -> ModelAPI:
    def init(key):
        return encdec_lib.init_params(key, cfg)

    def loss(params, batch, mesh=None):
        logits, aux = encdec_lib.forward(params, cfg, batch["frames"],
                                         batch["tokens"], mesh=mesh)
        ce = tfm.cross_entropy(logits[:, :-1, :], batch["tokens"][:, 1:])
        return ce, {"ce": ce, "aux": aux}

    def prefill(params, batch, mesh=None, pad_cache_to=0):
        return encdec_lib.prefill(params, cfg, batch["frames"],
                                  batch["tokens"], mesh=mesh,
                                  pad_cache_to=pad_cache_to)

    def dstep(params, state, tokens, mesh=None):
        return encdec_lib.decode_step(params, cfg, state, tokens, mesh=mesh)

    def dstate(batch, max_seq):
        return encdec_lib.init_decode_state(None, cfg, batch, max_seq)

    return ModelAPI(cfg, init, loss, prefill, dstep, dstate)


# ================================================================ input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the entry point
    implied by shape.kind ('train'/'prefill' -> batch dict; 'decode' -> the
    token batch; decode state comes from eval_shape of init_decode_state)."""
    b, s = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg)
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            p = cfg.num_patches
            return {"tokens": sd((b, s - p), i32),
                    "patch_embeds": sd((b, p, cfg.d_model), dt)}
        if cfg.family == "audio":
            return {"frames": sd((b, cfg.encoder_seq, cfg.d_model), dt),
                    "tokens": sd((b, s), i32)}
        return {"tokens": sd((b, s), i32)}
    # decode: one new token against a seq_len-deep state
    return {"tokens": sd((b,), i32)}


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract decode state (no allocation) via eval_shape."""
    api = build_model(cfg)
    return jax.eval_shape(
        lambda: api.init_decode_state(shape.global_batch, shape.seq_len))


def param_specs(cfg: ModelConfig):
    api = build_model(cfg)
    return jax.eval_shape(lambda: api.init(jax.random.key(0)))
