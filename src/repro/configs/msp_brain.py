"""The paper's own workload: MSP structural-plasticity brain simulation.

Default numbers follow the paper's quality experiment (§V-D): target calcium
0.7, element growth rate 1e-3, background activity N(5,1), Delta=100,
connectivity update every 100 steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class BrainConfig:
    name: str = "msp-brain"
    neurons_per_rank: int = 1024
    # --- neuron / plasticity model (paper §III-A, §V-D) ---
    fraction_excitatory: float = 0.8
    target_calcium: float = 0.7        # epsilon (paper §V-D)
    # calcium equilibrium = (beta/decay) * rate; calibrated for Izhikevich so
    # background N(5,1) (~10 Hz) gives ~0.23 and ~30 Hz reaches the 0.7 target
    # (the paper's rate-model constants do not transfer to Izhikevich directly)
    calcium_decay: float = 1e-4        # c += -c*decay + beta*spiked
    calcium_beta: float = 2.4e-3
    element_growth_rate: float = 1e-3  # nu (paper §V-D)
    background_mean: float = 5.0       # N(5,1) background input (paper §V-D)
    background_std: float = 1.0
    initial_vacant_low: float = 1.1    # paper: 1.1..1.5 vacant elements at t=0
    initial_vacant_high: float = 1.5
    synapse_weight: float = 15.0       # EPSP per spike (inhibitory: negative)
    # Izhikevich RS parameters
    izh_a: float = 0.02
    izh_b: float = 0.2
    izh_c: float = -65.0
    izh_d: float = 8.0
    # --- structural update cadence ---
    plasticity_period: int = 100       # connectivity update every 100 steps
    rate_period: int = 100             # Delta: firing-rate exchange period (new alg)
    # --- Barnes-Hut ---
    theta: float = 0.3                 # acceptance criterion
    sigma: float = 0.25                # Gaussian kernel width (domain units)
    local_levels: int = 4              # octree levels below the branch level
    frontier_cap: int = 64             # static BH frontier size
    max_synapses: int = 32             # S_max per neuron (out and in)
    requests_cap_factor: int = 2       # all_to_all request buffer head-room
    subs_cap_factor: int = 2           # sparse-exchange subscription head-room
    # measured per-rank unique-remote-source count the subscription registry
    # is sized from (subs_cap_factor stays the head-room multiplier on top).
    # None = the near-uniform synthetic default, n // num_ranks.
    # ``Simulator.from_connectome`` bakes the max-over-ranks count measured
    # on the loaded edge list here, so heavy-tailed real connectomes do not
    # start life overflowing the registry (DESIGN.md §13).
    subs_cap_base: Optional[int] = None
    # --- algorithm selection (old = paper baseline, new = paper contribution) ---
    connectivity_alg: str = "new"      # 'old' (move data) | 'new' (move compute)
    spike_alg: str = "new"             # 'old' (per-step IDs) | 'new' (rates + PRNG)
    # rate-exchange layout for spike_alg='new' (DESIGN.md §7):
    #   'dense'  all_gather every rank's full rate vector into a replicated
    #            (R, n) table — O(R*n) bytes per rank per Delta (reference);
    #   'sparse' demand-driven push: each rank subscribes to the unique
    #            remote sources of its in-edge table (registry rebuilt with
    #            the connectome) and owners push only those rates —
    #            O(unique remote sources) per Delta. Bit-identical to dense
    #            while stats['subscription_overflow'] stays zero: overflowed
    #            subscriptions read rate 0, so raise subs_cap_factor until
    #            it does (like requests_cap_factor).
    rate_exchange: str = "dense"
    # 'reference' = jnp scan (6 passes/step); 'fused' = one Pallas megakernel
    # per rate window, Delta-resident state (bit-identical; requires
    # spike_alg='new' and (s_max+16)*4*n bytes of VMEM — see DESIGN.md §5)
    activity_impl: str = "reference"
    # phase-B Barnes-Hut lowering: 'reference' = jnp frontier expansion;
    # 'fused' = the Pallas traversal kernel (kernels/bh_traverse.py) — the
    # whole restart loop per query block with the tree VMEM-resident,
    # bit-identical to the reference (shared core math + counter-hash PRNG;
    # DESIGN.md §6). Works with either connectivity_alg.
    connectivity_impl: str = "reference"
    # local octree build: 'reference' = jnp Morton encode + stable-argsort
    # slot ranks; 'fused' = the Pallas Morton + LSD radix-sort kernel
    # (kernels/radix_sort.py) — codes, leaf slots, and histogram state stay
    # VMEM-resident; integer ranks are computed by the same stable-rank
    # definition, so the build is bit-identical (DESIGN.md §11)
    tree_impl: str = "reference"
    # synapse-table apply: 'reference' = jnp segment-rank passes
    # (remove_edges_by_messages -> compact -> accept_requests, plus the
    # deletion-routing buffer build); 'fused' = one VMEM-resident Pallas
    # pass over the (n, s_max) edge table per stage
    # (kernels/synapse_apply.py), bit-identical (DESIGN.md §11)
    apply_impl: str = "reference"
    # length of the device-side per-chunk metrics ring (telemetry.metrics:
    # per-Delta counter increments at chunk % history; DESIGN.md §9)
    metrics_history: int = 64
    seed: int = 0

    def __post_init__(self):
        # eager validation through the phase registry: unknown variant
        # names and illegal combinations (e.g. fused activity with the old
        # spike algorithm) fail HERE, at construction, with the allowed
        # set listed — never mid-trace (repro/sim/registry.py)
        from repro.sim import registry
        registry.check_config(self)


SMOKE_CONFIG = BrainConfig(neurons_per_rank=64, local_levels=3, frontier_cap=32,
                           max_synapses=8)
CONFIG = BrainConfig(neurons_per_rank=65_536)
