"""starcoder2-15b — dense GQA + RoPE, plain-GELU MLP. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24_576, vocab_size=49_152,
    mlp_gated=False, qkv_bias=True, rope_theta=100_000.0, norm_eps=1e-5,
)

SMOKE_CONFIG = ModelConfig(
    name="starcoder2-15b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    mlp_gated=False, qkv_bias=True, scan_layers=False,
)
