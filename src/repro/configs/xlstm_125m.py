"""xlstm-125m — alternating sLSTM + mLSTM blocks; attention-free (runs long_500k).
d_ff=0: xLSTM blocks carry their own up/down projections. [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    scan_layers=False,  # heterogeneous 12-layer stack — unroll
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-125m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
    d_ff=0, vocab_size=512,
    block_pattern=("mlstm", "slstm"), scan_layers=False,
)
