"""qwen2-7b — dense GQA decoder with QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18_944, vocab_size=152_064,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    qkv_bias=True, scan_layers=False,
)
