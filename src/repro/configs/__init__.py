"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, ParallelConfig, ShapeConfig,
                                SHAPES, applicable_shapes, supports_long_context)

# arch id -> module name
_ARCH_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "arctic-480b":         "arctic_480b",
    "qwen2-7b":            "qwen2_7b",
    "starcoder2-15b":      "starcoder2_15b",
    "qwen3-14b":           "qwen3_14b",
    "chatglm3-6b":         "chatglm3_6b",
    "whisper-base":        "whisper_base",
    "llava-next-34b":      "llava_next_34b",
    "xlstm-125m":          "xlstm_125m",
    "recurrentgemma-2b":   "recurrentgemma_2b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE_CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
