"""arctic-480b — Snowflake Arctic: 128-expert top-2 MoE + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32_000,
    moe=True, num_experts=128, top_k=2, moe_dense_residual=True,
    rope_theta=10_000.0,
    # 480B params: bf16 optimizer state so param+m+v+grad fits 16GB/chip at 256-way
    parallel=ParallelConfig(opt_state_dtype="bfloat16"),
)

SMOKE_CONFIG = ModelConfig(
    name="arctic-480b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=48, vocab_size=512,
    moe=True, num_experts=8, top_k=2, moe_dense_residual=True,
    scan_layers=False,
)
