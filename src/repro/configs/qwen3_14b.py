"""qwen3-14b — dense GQA with per-head qk RMS-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17_408, vocab_size=151_936,
    qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-14b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    qk_norm=True, scan_layers=False,
)
