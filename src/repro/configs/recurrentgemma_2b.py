"""recurrentgemma-2b — Griffin: RG-LRU recurrent blocks + local attention, 2:1
pattern (rec, rec, attn). Sub-quadratic => runs long_500k. [arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    block_pattern=("rglru", "rglru", "attn"),
    attn_window=2048, attn_logit_softcap=0.0,
    scan_layers=False,  # heterogeneous pattern — unroll (26 small layers)
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    num_layers=3, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512,
    block_pattern=("rglru", "rglru", "attn"), attn_window=16,
    scan_layers=False,
)
