"""llava-next-34b — VLM text backbone (Yi-34B-class); anyres tiling frontend is a
STUB: input_specs() provides precomputed patch embeddings (num_patches, d_model).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20_480, vocab_size=64_000,
    rope_theta=5_000_000.0,
    num_patches=2880,  # anyres: base 576 + 4 tiles x 576
)

SMOKE_CONFIG = ModelConfig(
    name="llava-next-34b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    num_patches=16, scan_layers=False,
)
