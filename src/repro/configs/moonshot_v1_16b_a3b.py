"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163_840,
    moe=True, num_experts=64, top_k=6,
    rope_theta=50_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=48, vocab_size=512,
    moe=True, num_experts=8, top_k=2,
    scan_layers=False,
)
