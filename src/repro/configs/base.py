"""Config system: architecture, shape, parallelism and run configs.

Every assigned architecture gets one module in ``repro/configs/`` exporting a
``CONFIG`` (full size, used only by the dry-run via ShapeDtypeStruct) and a
``SMOKE_CONFIG`` (reduced same-family config that runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ParallelConfig:
    """How a model is laid out on the mesh (see DESIGN.md §5)."""

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)

    # weight sharding
    fsdp_axis: str = "data"            # row-shard params over this axis when divisible
    tensor_axis: str = "model"         # col-shard params over this axis when divisible
    shard_params_fsdp: bool = True
    # 'tp'  : batch over DP axes, weights row x col sharded (Megatron-ish)
    # 'fsdp': batch over ALL axes, weights row-sharded over (data x model) —
    #         no TP activation all-reduces; per-layer bf16 weight gathers.
    #         MoE expert weights keep EP over 'model' in both layouts.
    layout: str = "tp"
    # MoE dispatch: 'move_data' | 'move_compute' | 'local' | 'auto' (cost model
    # picks whichever moves fewer bytes — the paper's principle generalized)
    moe_strategy: str = "auto"
    # decode attention: 'local' (batch-sharded KV) | 'split_kv' (seq-sharded +
    # psum combine — the move-compute pattern; also the only layout where a
    # 32k x 128 cache fits 16GB/chip for the big archs)
    decode_attention: str = "split_kv"
    # cross-entropy: 'dense' | 'vocab_parallel'
    ce_mode: str = "dense"
    # gradient sync period (paper's Delta; 1 = every step)
    grad_sync_period: int = 1
    grad_compression: str = "none"     # 'none' | 'int8'
    # remat policy for the scanned layer body: 'none'|'full'|'dots_saveable'
    remat: str = "full"
    # optimizer state dtype ('float32' | 'bfloat16'); bf16 lets 480B fit 16GB/chip
    opt_state_dtype: str = "float32"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention flavor ---
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0            # chatglm3: 0.5 (2d/partial rotary)
    qk_norm: bool = False              # qwen3
    qkv_bias: bool = False             # qwen2
    attn_window: int = 0               # >0 => local (sliding-window) attention
    attn_logit_softcap: float = 0.0
    # --- mlp flavor ---
    mlp_gated: bool = True             # SwiGLU (gated) vs plain GELU (starcoder2, whisper)
    # --- moe ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False   # arctic: parallel dense FFN + MoE
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # --- ssm / hybrid ---
    block_pattern: tuple = ()          # e.g. ('rglru','rglru','attn'); () => all 'attn'
    rglru_conv_width: int = 4
    sslstm_heads: int = 4              # xlstm sLSTM head count
    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0               # precomputed frame embeddings (frontend stub)
    # --- vlm (llava) ---
    num_patches: int = 0               # precomputed patch embeddings (frontend stub)
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    scan_layers: bool = True           # scan over stacked layer params (HLO compression)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)

    # ----- derived -----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def pattern(self) -> tuple:
        """Per-layer block kinds, length num_layers."""
        if not self.block_pattern:
            return ("attn",) * self.num_layers
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline + memory estimates)."""
        d, h = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.pattern():
            if kind == "attn":
                attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    attn += self.q_dim + 2 * self.kv_dim
            elif kind == "rglru":
                # linear recurrent block: in/out proj + conv + gates (griffin-like)
                w = self.d_ff if self.d_ff else d
                attn = 2 * d * w + w * self.rglru_conv_width + 3 * w + w * d
            elif kind in ("mlstm", "slstm"):
                # xlstm block: up-proj(2x), qkv-ish gates, down-proj
                up = 2 * d
                attn = d * up * 2 + 4 * up * h + up * d
            else:
                raise ValueError(kind)
            if self.moe:
                nff = 3 if self.mlp_gated else 2
                ff = self.num_experts * nff * d * self.d_ff + d * self.num_experts
                if self.moe_dense_residual:
                    ff += nff * d * self.d_ff
            elif self.d_ff:
                nff = 3 if self.mlp_gated else 2
                ff = nff * d * self.d_ff
            else:
                ff = 0
            total += attn + ff + 2 * d  # + norms
        if self.encoder_layers:
            enc = self.encoder_layers * (
                2 * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
                + (2 if not self.mlp_gated else 3) * d * self.d_ff + 4 * d)
            # decoder cross-attention adds one attn block per layer
            total += enc + self.num_layers * (d * self.q_dim + 2 * d * self.kv_dim
                                              + self.q_dim * d + d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        nff = 3 if self.mlp_gated else 2
        per_layer_all = self.num_experts * nff * self.d_model * self.d_ff
        per_layer_act = self.top_k * nff * self.d_model * self.d_ff
        return full - self.num_layers * (per_layer_all - per_layer_act)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

# archs whose every block attends over the full sequence (quadratic) skip long_500k
def supports_long_context(cfg: ModelConfig) -> bool:
    kinds = set(cfg.pattern())
    if kinds == {"attn"} and cfg.attn_window == 0:
        return False
    if "attn" in kinds and cfg.attn_window == 0 and cfg.family not in ("ssm", "hybrid"):
        return False
    return True


def applicable_shapes(cfg: ModelConfig):
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not supports_long_context(cfg):
            continue
        out.append(s)
    return out
