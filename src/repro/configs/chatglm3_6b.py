"""chatglm3-6b — dense GQA(kv=2), 2d/partial RoPE (rotary on half dims).
[arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2, head_dim=128,
    d_ff=13_696, vocab_size=65_024,
    rotary_pct=0.5, qkv_bias=True, norm_eps=1e-5,
)

SMOKE_CONFIG = ModelConfig(
    name="chatglm3-6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    rotary_pct=0.5, qkv_bias=True, scan_layers=False,
)
