"""whisper-base — encoder-decoder transformer backbone; conv audio frontend is a
STUB: input_specs() provides precomputed frame embeddings (1500, d_model).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51_865,
    mlp_gated=False, norm_eps=1e-5, rotary_pct=0.0,  # learned/absolute positions
    encoder_layers=6, encoder_seq=1500,
    scan_layers=False,  # 6 layers — unrolled HLO is small
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-base-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    mlp_gated=False, rotary_pct=0.0,
    encoder_layers=2, encoder_seq=64, scan_layers=False,
)
