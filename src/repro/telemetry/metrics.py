"""Device-side metrics: the structured ``Metrics`` pytree carried through
the jitted scan (DESIGN.md §9).

``Metrics`` replaces the engine's old flat ``stats`` dict of summed scalars
with three groups of small per-rank device buffers:

  counters   {name: (1,) f32}      monotone per-rank totals — the paper's
                                   byte-accounting counters plus per-phase
                                   work counters (see ``PHASE_OF``);
  per_chunk  {name: (1, H) f32}    a ring buffer of per-chunk (per-Delta)
                                   counter increments, indexed by
                                   ``chunk % H`` — per-Delta resolution is
                                   preserved on device instead of being
                                   lost to a running sum;
  hists      {name: (1, B) f32}    fixed-size histograms (spikes-per-step
                                   fraction, subscription occupancy,
                                   traversal restart depth);
  gauges     {name: (1,) f32}      last-written values (SET, not summed) —
                                   the device-side health verdict computed
                                   at the end of every ``sim_chunk`` inside
                                   the jitted scan (``GAUGE_KEYS``): a
                                   NaN/Inf census of the physical state,
                                   live synapse-table entry counts, and the
                                   psum'd ``health_flags`` bitmask the
                                   fault-tolerant runner polls each
                                   checkpoint interval (DESIGN.md §10).

Every leaf keeps its leading per-rank axis of size 1 so the whole tree
shards over the 'ranks' mesh axis like the old counters did
(``metrics_specs``); nothing is ``.sum()``-ed before the host asks for a
reduction (``Simulator.stats`` / ``Simulator.metrics``).

Bit-identity contract: all recording happens in plain jnp *outside* the
variant lowerings, on values both lowerings produce identically (the
per-step fired counts, the shared tree, the shared traversal depths), so
``activity_impl``/``connectivity_impl``/``rate_exchange`` variants commit
bit-identical physics counters (tests/test_telemetry.py). Bucket weights
are 0/1 and counts are small integers, so the f32 scatter-adds are exact
and order-independent.

This module is import-light (jax only) — the engine, kernels, and
connectome all import it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# the 11 legacy byte-accounting counters (paper Tables I/II) ...
LEGACY_KEYS = ("spikes_sent", "rates_sent", "subscription_requests",
               "subscription_overflow", "bh_requests", "bh_responses",
               "formation_requests", "synapses_formed", "synapses_deleted",
               "tree_nodes_downloaded", "request_overflow")
# ... plus the per-phase work counters added with the telemetry layer
EXTRA_KEYS = ("activity_steps", "activity_spikes", "tree_nodes_built",
              "bh_restarts")
COUNTER_KEYS = LEGACY_KEYS + EXTRA_KEYS

# counter -> phase of the three-phase loop it instruments; the report
# groups counters by these (telemetry/report.py)
PHASE_OF = {
    "activity_steps": "activity", "activity_spikes": "activity",
    "spikes_sent": "activity",
    "tree_nodes_built": "tree_build", "tree_nodes_downloaded": "tree_build",
    "bh_requests": "phase_b", "bh_responses": "phase_b",
    "bh_restarts": "phase_b", "formation_requests": "phase_b",
    "request_overflow": "phase_b",
    "synapses_formed": "synapse_update", "synapses_deleted": "synapse_update",
    "rates_sent": "exchange", "subscription_requests": "exchange",
    "subscription_overflow": "exchange",
}

# histogram -> bucket count. All fixed at trace time.
HIST_BUCKETS = {
    "spikes_per_step": 16,   # fraction of neurons firing per step, [0, 1)
    "subs_occupancy": 16,    # filled fraction of the subscription registry
    "frontier_depth": 8,     # Barnes-Hut restarts per phase-B query
}

# gauges: last-written (not summed) per-rank health values, refreshed at
# the end of every sim_chunk inside the jitted scan (sim/phases.py).
GAUGE_KEYS = (
    "health_flags",      # psum'd bitmask of HEALTH_* below (same on
                         # every rank; read with max(), never sum())
    "nonfinite_state",   # rank-local NaN/Inf count over v/u/calcium/
                         # rate/positions
    "out_edges_live",    # rank-local live out_edges entries (>= 0)
    "in_edges_live",     # rank-local live in_edges entries (>= 0)
)

# health_flags bits (DESIGN.md §10)
HEALTH_NONFINITE = 1     # NaN/Inf anywhere in the physical state
HEALTH_ASYMMETRY = 2     # sum(out_live) != sum(in_live) w/o overflow
HEALTH_CONSERVATION = 4  # live entries outside the [2F-2D, 2F-D] bound

# host-side runner lifecycle counters, merged into Simulator.stats() and
# the repro.telemetry/v1 report (runtime/sim_runner.py maintains them)
LIFECYCLE_KEYS = ("checkpoint_saves", "checkpoint_restores", "rollbacks",
                  "restarts", "degrade_events", "heartbeat_stale")

DEFAULT_HISTORY = 64         # per-chunk ring length (BrainConfig.metrics_history)


@dataclasses.dataclass(frozen=True)
class Metrics:
    """The device-side metrics tree (see module docstring). Immutable:
    every recording method returns a new ``Metrics``. ``m["key"]`` and
    ``m.items()`` delegate to ``counters`` so the old ``stats['key']``
    read idiom keeps working."""
    counters: Dict[str, Any]
    per_chunk: Dict[str, Any]
    hists: Dict[str, Any]
    gauges: Dict[str, Any]

    # -------------------------------------------------- dict-compat reads
    def __getitem__(self, key):
        return self.counters[key]

    def __contains__(self, key):
        return key in self.counters

    def keys(self):
        return self.counters.keys()

    def items(self):
        return self.counters.items()

    # -------------------------------------------------- recording
    def count(self, name: str, delta) -> "Metrics":
        """Add ``delta`` (scalar, any numeric dtype) to counter ``name``."""
        c = dict(self.counters)
        c[name] = c[name] + jnp.asarray(delta, jnp.float32)
        return dataclasses.replace(self, counters=c)

    def observe(self, name: str, bucket, weight=None) -> "Metrics":
        """Scatter-add ``weight`` (default 1.0 each) into histogram
        ``name`` at ``bucket`` (any-shape i32, pre-clipped by the
        caller)."""
        h = dict(self.hists)
        b = jnp.ravel(bucket)
        w = jnp.ones(b.shape, jnp.float32) if weight is None \
            else jnp.ravel(weight).astype(jnp.float32)
        h[name] = h[name].at[0, b].add(w)
        return dataclasses.replace(self, hists=h)

    def record_chunk(self, start_counters: Dict[str, Any],
                     chunk) -> "Metrics":
        """Write this chunk's counter increments (current - ``start``)
        into ring slot ``chunk % H``. Called once per ``sim_chunk`` with
        the counters snapshotted at chunk entry."""
        pc = dict(self.per_chunk)
        for k, ring in pc.items():
            slot = jnp.asarray(chunk, jnp.int32) % ring.shape[1]
            delta = self.counters[k][0] - start_counters[k][0]
            pc[k] = ring.at[0, slot].set(delta)
        return dataclasses.replace(self, per_chunk=pc)

    def set_gauges(self, updates: Dict[str, Any]) -> "Metrics":
        """Overwrite the named gauges with fresh scalar values (broadcast
        to the (1,) per-rank leaf). Gauges are levels, not totals."""
        g = dict(self.gauges)
        for k, v in updates.items():
            g[k] = jnp.reshape(jnp.asarray(v, jnp.float32), (1,))
        return dataclasses.replace(self, gauges=g)


def _flatten_with_keys(m: Metrics):
    K = jax.tree_util.DictKey
    return (((K("counters"), m.counters), (K("per_chunk"), m.per_chunk),
             (K("hists"), m.hists), (K("gauges"), m.gauges)), None)


jax.tree_util.register_pytree_with_keys(
    Metrics, _flatten_with_keys, lambda aux, ch: Metrics(*ch))


def init_metrics(history: int = DEFAULT_HISTORY) -> Metrics:
    """Fresh zeroed per-rank metrics ((1, ...) leaves, sharded P('ranks')
    in the engine's state specs)."""
    return Metrics(
        counters={k: jnp.zeros((1,), jnp.float32) for k in COUNTER_KEYS},
        per_chunk={k: jnp.zeros((1, history), jnp.float32)
                   for k in COUNTER_KEYS},
        hists={k: jnp.zeros((1, b), jnp.float32)
               for k, b in HIST_BUCKETS.items()},
        gauges={k: jnp.zeros((1,), jnp.float32) for k in GAUGE_KEYS})


def metrics_specs(m: Metrics) -> Metrics:
    """PartitionSpecs matching ``init_metrics`` leaf-for-leaf: everything
    is per-rank on its leading axis."""
    return Metrics(
        counters={k: P("ranks") for k in m.counters},
        per_chunk={k: P("ranks", None) for k in m.per_chunk},
        hists={k: P("ranks", None) for k in m.hists},
        gauges={k: P("ranks") for k in m.gauges})


# ==================================================================
# Recorder: the PhaseContext ``metrics`` handle. One object shared by
# every @register_phase implementation; it centralizes the recording
# *math* so each quantity is computed by exactly one jnp expression no
# matter which variant lowering produced its inputs (the bit-identity
# surface of DESIGN.md §9).
# ==================================================================
@dataclasses.dataclass(frozen=True)
class Recorder:
    """Static recording config for one rank's trace. ``n`` is
    neurons-per-rank (the spikes-per-step normalizer)."""
    n: int

    def activity_window(self, m: Metrics, spikes_per_step) -> Metrics:
        """Record one rate window from its (T,) per-step fired counts —
        produced identically by the reference scan (stacked ys) and the
        fused megakernel (the per-step output block)."""
        t = spikes_per_step.shape[0]
        m = m.count("activity_steps", jnp.float32(t))
        m = m.count("activity_spikes", jnp.sum(spikes_per_step))
        nb = HIST_BUCKETS["spikes_per_step"]
        frac = spikes_per_step / jnp.float32(self.n)
        bucket = jnp.clip((frac * nb).astype(jnp.int32), 0, nb - 1)
        return m.observe("spikes_per_step", bucket)

    def tree_built(self, m: Metrics, local_tree) -> Metrics:
        """Count the non-empty octree nodes of this chunk's local tree
        (all levels) — the 'new' algorithm's answer to the old
        algorithm's ``tree_nodes_downloaded``."""
        built = sum(jnp.sum((c > 0).astype(jnp.float32))
                    for c in local_tree.counts)
        return m.count("tree_nodes_built", built)

    def traversal(self, m: Metrics, depth, mask) -> Metrics:
        """Record phase-B restart depths for the queries in ``mask``:
        the ``bh_restarts`` total and the frontier-depth histogram. The
        depths come out of ``bh_search`` identically under both
        traversal lowerings."""
        w = mask.astype(jnp.float32)
        m = m.count("bh_restarts", jnp.sum(depth.astype(jnp.float32) * w))
        nb = HIST_BUCKETS["frontier_depth"]
        bucket = jnp.clip(depth, 0, nb - 1)
        return m.observe("frontier_depth", bucket, w)

    def subs_occupancy(self, m: Metrics, subs, no_sub) -> Metrics:
        """One histogram entry per chunk: the filled fraction of the
        sparse exchange's subscription registry (zeros stay zero under
        the dense layout)."""
        cap = subs.shape[0]
        frac = jnp.sum((subs != no_sub).astype(jnp.float32)) / cap
        nb = HIST_BUCKETS["subs_occupancy"]
        bucket = jnp.clip((frac * nb).astype(jnp.int32), 0, nb - 1)
        return m.observe("subs_occupancy", bucket[None])
