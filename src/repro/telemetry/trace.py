"""Host-side tracing: wall-clock spans + jax.profiler integration.

``span(name)`` is a context manager that (a) records a wall-clock span
(start, duration, nesting depth, parent) into a process-wide ring and
(b) opens a ``jax.profiler.TraceAnnotation`` so the same region shows up
as a named slice in a captured Perfetto/XPlane trace. The Simulator wraps
``from_config`` / ``init`` / ``step`` / ``run`` / ``lower`` / ``save`` /
``restore`` in spans; phase-level device-side annotation uses
``jax.named_scope`` inside the traced chunk (sim/phases.py).

``profile(log_dir)`` guards ``jax.profiler.trace``: a failure to start
(no backend support, a trace already active) degrades to a no-op with a
warning instead of killing the run — profiling is opt-in observability,
never a correctness dependency.
"""
from __future__ import annotations

import contextlib
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax

_MAX_SPANS = 4096
_records: "deque[Span]" = deque(maxlen=_MAX_SPANS)
_records_lock = threading.Lock()
_tls = threading.local()


@dataclass
class Span:
    """One completed (or in-flight) wall-clock span."""
    name: str
    start_s: float              # perf_counter at entry
    duration_ms: float = -1.0   # -1 while still open
    depth: int = 0
    parent: Optional[str] = None
    attrs: Dict[str, Any] = field(default_factory=dict)

    def asdict(self) -> dict:
        return {"name": self.name, "start_s": self.start_s,
                "duration_ms": self.duration_ms, "depth": self.depth,
                "parent": self.parent, "attrs": dict(self.attrs)}


def _stack() -> List[Span]:
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


@contextlib.contextmanager
def span(name: str, **attrs):
    """Record a named wall-clock span (and a profiler TraceAnnotation).
    Yields the Span record; callers may add ``attrs`` to it."""
    stack = _stack()
    rec = Span(name=name, start_s=time.perf_counter(), depth=len(stack),
               parent=stack[-1].name if stack else None, attrs=dict(attrs))
    stack.append(rec)
    try:
        with jax.profiler.TraceAnnotation(name):
            yield rec
    finally:
        stack.pop()
        rec.duration_ms = (time.perf_counter() - rec.start_s) * 1e3
        with _records_lock:
            _records.append(rec)


def spans(name: Optional[str] = None) -> List[Span]:
    """Completed spans so far (oldest first), optionally filtered by name."""
    with _records_lock:
        out = list(_records)
    return out if name is None else [s for s in out if s.name == name]


def clear() -> None:
    with _records_lock:
        _records.clear()


def export() -> List[dict]:
    """JSON-serializable span records for telemetry.report."""
    return [s.asdict() for s in spans()]


@contextlib.contextmanager
def profile(log_dir: Optional[str]):
    """``jax.profiler.trace(log_dir)``, degraded to a no-op on None or on
    any start failure (warning, not an exception)."""
    if log_dir is None:
        yield
        return
    try:
        jax.profiler.start_trace(log_dir)
    except Exception as e:  # already tracing / unsupported backend
        warnings.warn(f"telemetry: profiler trace not captured: {e}")
        yield
        return
    try:
        yield
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            warnings.warn(f"telemetry: profiler trace not finalized: {e}")
