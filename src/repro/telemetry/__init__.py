"""repro.telemetry — device-side metrics, host-side spans, unified report
(DESIGN.md §9).

Three layers:

  metrics   the ``Metrics`` pytree carried through the jitted scan in
            ``BrainState.stats`` — per-phase counters, per-chunk ring
            buffers, fixed-size histograms; per-rank resolution preserved;
  trace     ``span(name)`` wall-clock records + jax.profiler trace
            annotations; ``profile(log_dir)`` guards a Perfetto capture;
  report    the single JSON schema all benchmarks emit and
            ``benchmarks/check_regression.py`` gates on.
"""
from repro.telemetry.metrics import (COUNTER_KEYS, GAUGE_KEYS, HIST_BUCKETS,
                                     LEGACY_KEYS, LIFECYCLE_KEYS, PHASE_OF,
                                     Metrics, Recorder, init_metrics,
                                     metrics_specs)
from repro.telemetry.trace import (Span, clear, export, profile, span, spans)
from repro.telemetry import report

__all__ = [
    "COUNTER_KEYS", "GAUGE_KEYS", "HIST_BUCKETS", "LEGACY_KEYS",
    "LIFECYCLE_KEYS", "PHASE_OF", "Metrics", "Recorder", "init_metrics",
    "metrics_specs", "Span", "clear", "export", "profile", "span", "spans",
    "report",
]
