"""Unified telemetry export: one JSON schema for every bench script and
the regression gate (DESIGN.md §9).

A report merges the three telemetry sources:

  * measured device counters/histograms (``telemetry.metrics.Metrics``,
    via ``counters_block``) — per-rank values preserved next to totals;
  * host-side span timings (``telemetry.trace.export``), with compile and
    steady-state explicitly separated by the bench harness
    (``benchmarks/_util.measure`` / ``brain_sim_timed``);
  * analytic bytes from ``launch/roofline.py`` and the kernels' closed-form
    traffic models, carried in each case's ``metrics``.

Schema (``repro.telemetry/v1``)::

    {"schema": "repro.telemetry/v1", "bench": "<family>", "smoke": bool,
     "mesh": {"num_ranks": R, "backend": "cpu"},
     "cases": {"<case>": {"params": {...},     # shapes: n_per_rank, ...
                          "metrics": {...}}},  # flat floats: compile_ms,
                                               # steady_us_per_*, ratios
     "counters": {...}?, "histograms": {...}?, "spans": [...]?,
     "lifecycle": {...}?}                      # runner fault-tolerance
                                               # counters (saves/restores/
                                               # rollbacks/restarts/degrades)

``normalize`` also reads the PRE-schema flat ``BENCH_*.json`` layouts, so
the regression gate compares old committed baselines and new smoke runs
interchangeably (the satellite contract: old keys stay readable).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

import numpy as np

SCHEMA = "repro.telemetry/v1"

# params are case *shape*, never regression-checked as metrics
PARAM_KEYS = ("n_per_rank", "num_ranks", "s_max", "delta", "chunks",
              "phase_b_queries")


def timing(compile_ms: float, steady_us: float, unit: str = "chunk") -> dict:
    """The compile/steady split every bench emits (satellite 2)."""
    return {"compile_ms": float(compile_ms),
            f"steady_us_per_{unit}": float(steady_us)}


def counters_block(metrics) -> dict:
    """Serialize a (host or device) ``telemetry.metrics.Metrics``:
    summed totals AND the per-rank vectors (nothing collapsed), plus the
    health gauges (``health_flags`` reduces with max — it is a psum'd
    replicated bitmask, not a per-rank total)."""
    tot, per_rank = {}, {}
    for k, v in metrics.counters.items():
        a = np.asarray(v)
        tot[k] = float(a.sum())
        per_rank[k] = [float(x) for x in a.reshape(-1)]
    out = {"total": tot, "per_rank": per_rank}
    gauges = getattr(metrics, "gauges", None)
    if gauges:
        out["gauges"] = {
            k: float(np.asarray(v).max() if k == "health_flags"
                     else np.asarray(v).sum())
            for k, v in gauges.items()}
    return out


def lifecycle_block(lifecycle: dict) -> dict:
    """Serialize the runner lifecycle counters (checkpoint saves/
    restores, rollbacks, restarts, degrade events) — host-side ints from
    ``Simulator.lifecycle`` / ``Simulator.stats()``."""
    return {k: int(v) for k, v in lifecycle.items()}


def service_block(stats: dict, handles=None) -> dict:
    """Serialize a multi-tenant service run (repro.service): the service
    lifecycle counters (admissions, completions, quarantines, rollbacks,
    sheds, ...) plus a per-terminal-status census of the submitted
    requests."""
    out = {"lifecycle": {k: int(v) for k, v in stats.items()}}
    if handles is not None:
        census: Dict[str, int] = {}
        for h in handles:
            s = h.status.value
            census[s] = census.get(s, 0) + 1
        out["requests"] = census
    return out


def quality_block(metrics: dict) -> dict:
    """Serialize workload *function* metrics (repro.workloads — engram
    recall overlap/selectivity, assimilation error): quality reported in
    the same schema as the perf counters, so every bench row can carry
    both speed and function (DESIGN.md §13). The same values also appear
    as case metrics — the regression gate compares cases."""
    return {k: float(v) for k, v in metrics.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)}


def histograms_block(metrics) -> dict:
    return {k: np.asarray(v).sum(axis=0).tolist()
            for k, v in metrics.hists.items()}


def roofline_block(hlo_text: str, num_ranks: int) -> dict:
    """Analytic bytes/FLOPs of one compiled sim chunk
    (``launch/roofline.py`` over the post-SPMD optimized HLO): collective
    wire bytes by kind, dot FLOPs, materialized HBM bytes, and the
    TPU-model roofline terms — the third telemetry source next to the
    measured counters and the wall-clock spans."""
    from repro.launch import roofline as rl
    ana = rl.analyze_hlo(hlo_text, num_ranks)
    mat = rl.materialized_bytes(hlo_text)
    terms = rl.roofline_terms(ana["dot_flops"], mat,
                              ana["collective_bytes_total"])
    return {"collective_wire_bytes": ana["collective_wire_bytes"],
            "collective_bytes_total": ana["collective_bytes_total"],
            "dot_flops": ana["dot_flops"],
            "materialized_hbm_bytes": mat,
            "terms": terms}


def make_report(bench: str, cases: Dict[str, dict], *, smoke: bool = False,
                mesh: Optional[dict] = None, counters: Optional[dict] = None,
                histograms: Optional[dict] = None,
                spans: Optional[list] = None,
                roofline: Optional[dict] = None,
                lifecycle: Optional[dict] = None,
                service: Optional[dict] = None,
                quality: Optional[dict] = None) -> dict:
    rep = {"schema": SCHEMA, "bench": bench, "smoke": bool(smoke),
           "cases": cases}
    if service is not None:
        rep["service"] = service
    if quality is not None:
        rep["quality"] = quality_block(quality)
    if mesh is not None:
        rep["mesh"] = mesh
    if counters is not None:
        rep["counters"] = counters
    if histograms is not None:
        rep["histograms"] = histograms
    if spans is not None:
        rep["spans"] = spans
    if roofline is not None:
        rep["roofline"] = roofline
    if lifecycle is not None:
        rep["lifecycle"] = lifecycle_block(lifecycle)
    return rep


def case(params: dict, metrics: dict) -> dict:
    return {"params": {k: _num(v) for k, v in params.items()},
            "metrics": {k: _num(v) for k, v in metrics.items()}}


def _num(v):
    if isinstance(v, (bool, str)):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return v


def write(path: str, report: dict) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------- normalize
def _split_case(d: dict) -> dict:
    params = {k: d[k] for k in PARAM_KEYS if k in d}
    metrics = {k: float(v) for k, v in d.items()
               if k not in params and isinstance(v, (int, float))
               and not isinstance(v, bool)}
    return {"params": params, "metrics": metrics}


def normalize(obj: dict, bench: Optional[str] = None) -> dict:
    """Canonical view ``{"bench", "smoke", "cases": {name: {"params",
    "metrics"}}}`` of either a v1 report or a pre-schema flat
    ``BENCH_*.json`` (old-activity: flat case at top level; old
    connectivity/spikes: {"smoke": bool, "<case>": {...}})."""
    if obj.get("schema") == SCHEMA:
        return {"bench": obj.get("bench", bench), "smoke": obj.get("smoke",
                False), "cases": obj["cases"]}
    if "n_per_rank" in obj:                       # old flat single-case
        name = f"n{int(obj['n_per_rank'])}"
        return {"bench": bench, "smoke": bool(obj.get("smoke", False)),
                "cases": {name: _split_case(obj)}}
    cases = {k: _split_case(v) for k, v in obj.items()
             if isinstance(v, dict)}
    return {"bench": bench, "smoke": bool(obj.get("smoke", False)),
            "cases": cases}
