"""AdamW with sharded state (m/v shard exactly like params; optional bf16
state so 480B-class models fit 16 GB/chip), cosine schedule, grad clipping,
and microbatch gradient accumulation.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"     # 'bfloat16' halves optimizer memory

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def lr_at(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.zeros(())))


def adamw_update(params, grads, opt_state, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"]
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.ones(())
    lr = lr_at(cfg, step)
    c1 = 1.0 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - cfg.b2 ** (step.astype(jnp.float32) + 1)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        u = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * pf
        return ((pf - lr * u).astype(p.dtype), mf.astype(sdt), vf.astype(sdt))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
