"""Delta-periodic cross-pod gradient synchronization — the paper's second
algorithm mapped onto distributed training (DESIGN.md §3).

The paper replaces per-step spike exchange with rate exchange every Delta
steps. Here: within-pod gradient reduction (cheap ICI) happens every step via
GSPMD; ACROSS pods (expensive DCI) gradients are only accumulated locally and
exchanged every Delta-th step — semantically exact large-batch training with
cross-pod collective bytes divided by Delta (optionally int8-compressed with
error feedback on top).

Mechanics: shard_map manual over ONLY the 'pod' axis (axis_names={'pod'});
'data'/'model' stay automatic inside, so the whole model code is unchanged.
The accumulator carries a leading (1,)-per-pod axis so pod-divergent sums are
representable. Two jitted steps:
  accum_step : grads -> acc (no cross-pod collective in its HLO at all)
  sync_step  : psum(acc, 'pod') (or int8 gather) + AdamW update
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.optim.optimizer import OptimizerConfig, adamw_update
from repro.parallel import compress
from repro.parallel import sharding as shd


def init_accumulator(params, mesh=None):
    """Per-pod grad accumulator: global leading axis = n_pods (each pod's
    shard_map slice is (1, ...) — pod-divergent sums are representable)."""
    pods = mesh.shape.get("pod", 1) if mesh is not None else 1
    return jax.tree.map(
        lambda p: jnp.zeros((pods,) + p.shape, jnp.float32), params)


def init_error(params, mesh=None):
    pods = mesh.shape.get("pod", 1) if mesh is not None else 1
    return jax.tree.map(
        lambda p: jnp.zeros((pods,) + p.shape, jnp.float32), params)


def make_periodic_steps(api, mesh, opt_cfg: OptimizerConfig, *,
                        compress_int8: bool = False):
    """Returns (accum_step, sync_step). Both jitted closures over mesh.

    accum_step(params, acc, batch)            -> (acc, metrics)
    sync_step(params, opt_state, acc, err)    -> (params, opt, acc, err, stats)
    """
    has_pod = "pod" in mesh.axis_names
    # the old toolchain cannot wrap scanned models in a PARTIAL-manual
    # shard_map (XLA check-fails on any scan-with-xs inside it); fall back to
    # accumulating the globally-reduced gradient — semantically identical
    # large-batch training, only without the cross-pod byte saving
    manual_pod = has_pod and compat.PARTIAL_MANUAL_CONSTRAINT_OK
    acc_spec = P("pod") if manual_pod else P()

    def _loss(p, b):
        with shd.use_mesh(mesh):
            loss, metrics = api.loss(p, b, mesh)
        return loss, metrics

    def accum_body(params, acc, batch):
        (loss, metrics), grads = jax.value_and_grad(
            _loss, has_aux=True)(params, batch)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32)[None], acc, grads)
        out = dict(metrics, loss=loss)
        if manual_pod:  # pods see different microbatches; replicate metrics
            out = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), out)
        return acc, out

    def sync_body(params, opt_state, acc, err):
        if manual_pod:
            if compress_int8:
                red, err = compress.tree_allreduce_int8(acc, err, "pod")
                grads = jax.tree.map(lambda g: g[0], red)
            else:
                grads = jax.tree.map(
                    lambda a: jax.lax.psum(a, "pod")[0] / mesh.shape["pod"],
                    acc)
        else:
            # fallback/no-pod: every slot of the leading axis holds the same
            # globally-reduced gradient
            grads = jax.tree.map(lambda a: jnp.mean(a, axis=0), acc)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        params, opt_state, stats = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        acc = jax.tree.map(jnp.zeros_like, acc)
        return params, opt_state, acc, err, stats

    if manual_pod:
        bspec = {"tokens": P(("pod",), None)}
        accum = jax.jit(compat.shard_map(
            accum_body, mesh=mesh, axis_names={"pod"},
            in_specs=(P(), acc_spec, bspec),
            out_specs=(acc_spec, P()), check_vma=False))
        sync = jax.jit(compat.shard_map(
            sync_body, mesh=mesh, axis_names={"pod"},
            in_specs=(P(), P(), acc_spec, acc_spec),
            out_specs=(P(), P(), acc_spec, acc_spec, P()), check_vma=False))
    else:
        accum = jax.jit(accum_body)
        sync = jax.jit(sync_body)
    return accum, sync
