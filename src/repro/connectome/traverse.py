"""Vectorized Barnes-Hut partner search (paper §III-B0c / §IV-A).

The paper's recursive search — collect nodes meeting the acceptance criterion
(cell_size / distance < theta), sample one by connection probability, restart
inside it if it is an inner node — is reformulated level-synchronously for the
TPU: a static-size frontier per searching neuron is expanded in lockstep
(rejected nodes are replaced by their 8 children), then one Gumbel-max sample
selects the target; sampling an inner node restarts the expansion from it.

Static-shape deviations (documented in DESIGN.md §2/§6): the frontier is
capped at F entries — parents whose children would overflow are kept as
sampling candidates at coarser granularity; overflow is counted and reported
by tests.

PRNG contract: every Gumbel draw comes from the counter-based Threefry hash
(kernels/hash.py) keyed by ``(seed, BH_DOMAIN, bh_ctr(chunk, round, draw),
source_gid)`` — pure integers, no key arrays. Because the *same* stream is
derived from the source gid wherever the search executes — locally after
downloading remote subtrees (old algorithm), on the owning rank (new
location-aware algorithm), in the jnp reference path, or inside the Pallas
traversal kernel (kernels/bh_traverse.py) — all four make bit-identical
choices. Round slots: phase A expands from round 0, phase B from
``PHASE_B_ROUND_BASE``, member selection uses the last round.

Distances use the ``bh_gauss`` MXU identity |x|^2+|y|^2-2<x,y> with the
coordinate axis zero-padded to 8 lanes (``pairwise_d2``) so the kernel's
systolic-array mapping and the reference see identical floats.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import morton
from repro.kernels import hash as chash
from repro.sim import registry

NEG = -1e30
PAD = 8   # coordinate lanes (3 -> 8), the bh_gauss MXU alignment

PHASE_A_ROUND_BASE = 0
PHASE_B_ROUND_BASE = 16
MEMBER_ROUND = chash.BH_ROUNDS - 1


class StackedTree(NamedTuple):
    """Uniform view of consecutive octree levels for traced indexing.
    counts: (L, C_max); centroids: (L, C_max, 3); sizes: STATIC tuple of L
    cell edge lengths (compile-time floats, so the Pallas kernel body closes
    over them instead of capturing a constant array).
    Level k covers absolute octree level (start_level + k); cell indices are
    relative to ``cell_base * 8^k`` (the owning subtree block)."""
    counts: jnp.ndarray
    centroids: jnp.ndarray
    sizes: tuple
    start_level: int


def stack_levels(counts_tuple, cents_tuple, start_level: int) -> StackedTree:
    lmax = max(c.shape[0] for c in counts_tuple)
    cs, zs = [], []
    for c, z in zip(counts_tuple, cents_tuple):
        pad = lmax - c.shape[0]
        cs.append(jnp.pad(c, (0, pad)))
        zs.append(jnp.pad(z, ((0, pad), (0, 0))))
    sizes = level_sizes(len(counts_tuple), start_level)
    return StackedTree(jnp.stack(cs), jnp.stack(zs), sizes, start_level)


def level_sizes(n_levels: int, start_level: int):
    """Static per-level cell edge lengths (the kernel takes these as a
    compile-time tuple)."""
    return tuple(morton.cell_size(start_level + k) for k in range(n_levels))


def _gauss(d2, sigma: float):
    return jnp.exp(-d2 / (sigma * sigma))


def _pad_lanes(x):
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, PAD - x.shape[-1])])


def pairwise_d2(x, y):
    """||x - y||^2 for x: (Q, 3) against y: (Q, K, 3), via the MXU identity
    |x|^2 + |y|^2 - 2<x,y> with the coordinate axis zero-padded to 8 lanes —
    the same systolic-array mapping as kernels/bh_gauss.py, shared by the
    Pallas traversal kernel and the jnp reference so both see identical
    floats (precision caveat for tiny sigma documented in bh_gauss)."""
    xp = _pad_lanes(x.astype(jnp.float32))
    yp = _pad_lanes(y.astype(jnp.float32))
    xx = jnp.sum(xp * xp, axis=-1)[:, None]
    yy = jnp.sum(yp * yp, axis=-1)
    xy = jax.lax.dot_general(xp, yp, (((1,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def _level_size_at(sizes, lvl_rel):
    """Per-entry cell edge length from the STATIC per-level tuple — a chain
    of scalar selects instead of a constant-array gather (Pallas kernel
    bodies may not capture constant arrays)."""
    out = jnp.full(lvl_rel.shape, jnp.float32(sizes[0]))
    for k in range(1, len(sizes)):
        out = jnp.where(lvl_rel == k, jnp.float32(sizes[k]), out)
    return out


def _node_stats(tree: StackedTree, lvl_rel, cell, x, sigma):
    """Vectorized gather of (count, prob-weight, size/dist) for entries.
    lvl_rel, cell: (Q, F) int; x: (Q, 3)."""
    cnt = tree.counts[lvl_rel, cell]
    cent = tree.centroids[lvl_rel, cell]
    center = cent / jnp.maximum(cnt, 1e-9)[..., None]
    d2 = pairwise_d2(x, center)
    size = _level_size_at(tree.sizes, lvl_rel)
    crit = size / jnp.sqrt(jnp.maximum(d2, 1e-12))
    prob = cnt * _gauss(d2, sigma)
    return cnt, prob, crit


def _check_caps(frontier: int, round_base: int, restarts: int):
    if frontier > chash.BH_DRAWS:
        raise ValueError(f"frontier_cap {frontier} exceeds the PRNG draw "
                         f"window ({chash.BH_DRAWS})")
    if round_base + restarts > MEMBER_ROUND:
        raise ValueError(f"{restarts} restarts from round base {round_base} "
                         f"would collide with the member-selection round")


def expand_and_sample(tree: StackedTree, x, root_cell, root_rel, src_gid, rnd,
                      *, seed: int, chunk, theta: float, sigma: float,
                      frontier: int, n_levels: int):
    """One paper 'round': expand from the root node until every frontier entry
    meets the acceptance criterion (or is a deepest-level cell), then sample.

    x: (Q, 3); root_cell/root_rel: (Q,) current node (relative level index);
    src_gid: (Q,) searcher gids keying the Gumbel stream; rnd: scalar round
    index. Returns (cell, rel_level, valid, overflowed): all (Q,).
    """
    q = x.shape[0]
    f = frontier
    last = n_levels - 1

    # init: children of root (or root itself if already deepest)
    at_leaf = root_rel >= last
    child_rel = jnp.where(at_leaf, root_rel, root_rel + 1)
    base8 = jnp.where(at_leaf, root_cell, root_cell * 8)
    cells0 = jnp.full((q, f), 0, jnp.int32)
    lvls0 = jnp.full((q, f), 0, jnp.int32)
    valid0 = jnp.zeros((q, f), bool)
    js = jnp.arange(8)
    cells0 = cells0.at[:, :8].set(base8[:, None] + jnp.where(
        at_leaf[:, None], 0, js[None, :]))
    lvls0 = lvls0.at[:, :8].set(child_rel[:, None])
    valid0 = valid0.at[:, :8].set(jnp.where(at_leaf[:, None], js[None] == 0,
                                            True))
    overflow0 = jnp.zeros((q,), bool)

    def round_fn(state, _):
        cells, lvls, valid, overflow = state
        cnt, prob, crit = _node_stats(tree, lvls, cells, x, sigma)
        nonempty = cnt > 1e-9
        accepted = (crit < theta) | (lvls >= last)
        expand = valid & nonempty & ~accepted
        keepers = valid & ~expand & nonempty
        need = jnp.where(expand, 8, jnp.where(keepers, 1, 0))
        off = jnp.cumsum(need, axis=1) - need
        fits = (off + need) <= f
        # pass 2: overflowing expanders retained as coarse candidates
        need2 = jnp.where(expand & fits, 8, jnp.where(
            (keepers | (expand & ~fits)), 1, 0))
        off2 = jnp.cumsum(need2, axis=1) - need2
        fits2 = (off2 + need2) <= f
        ncells = jnp.zeros((q, f), jnp.int32)
        nlvls = jnp.zeros((q, f), jnp.int32)
        nvalid = jnp.zeros((q, f), bool)
        qi = jnp.arange(q)[:, None]
        # singles
        single = (need2 == 1) & fits2
        tgt = jnp.where(single, off2, f)
        ncells = ncells.at[qi, tgt].set(cells, mode="drop")
        nlvls = nlvls.at[qi, tgt].set(lvls, mode="drop")
        nvalid = nvalid.at[qi, tgt].set(single, mode="drop")
        # expansions
        exp8 = (need2 == 8) & fits2
        qij = jnp.arange(q)[:, None, None]
        tgt8 = jnp.where(exp8[..., None], off2[..., None] + js, f)
        ncells = ncells.at[qij, tgt8].set(cells[..., None] * 8 + js,
                                          mode="drop")
        nlvls = nlvls.at[qij, tgt8].set((lvls + 1)[..., None]
                                        * jnp.ones_like(js), mode="drop")
        nvalid = nvalid.at[qij, tgt8].set(exp8[..., None] & jnp.ones_like(
            js, bool), mode="drop")
        overflow = overflow | jnp.any(expand & ~fits2, axis=1)
        return (ncells, nlvls, nvalid, overflow), None

    state = (cells0, lvls0, valid0, overflow0)
    state, _ = jax.lax.scan(round_fn, state, None, length=n_levels)
    cells, lvls, valid, overflow = state

    cnt, prob, _ = _node_stats(tree, lvls, cells, x, sigma)
    logits = jnp.where(valid & (cnt > 1e-9), jnp.log(jnp.maximum(prob, 1e-30)),
                       NEG)
    g = chash.gumbel(seed, chash.BH_DOMAIN,
                     chash.bh_ctr(chunk, rnd, jnp.arange(f))[None, :],
                     src_gid[:, None])
    pick = jnp.argmax(logits + g, axis=1)
    qi = jnp.arange(q)
    any_valid = jnp.any(logits > NEG / 2, axis=1)
    return (cells[qi, pick], lvls[qi, pick], any_valid, overflow)


def bh_search(tree: StackedTree, x, src_gid, start_cell, *, seed: int, chunk,
              theta, sigma, frontier, n_levels, round_base=0,
              max_restarts=None):
    """Full search: expand/sample, restarting inside sampled inner nodes until
    a deepest-level cell is returned (paper's 'process restarts' loop).

    x: (Q,3); src_gid: (Q,) searcher gids (PRNG entities); start_cell: (Q,)
    cell at tree level 0. Returns (leaf_cell (Q,), valid (Q,), overflow (Q,),
    depth (Q,) i32 — expand/sample rounds executed before the query settled,
    the paper's 'process restarts' count; fed to the telemetry frontier-depth
    histogram).
    """
    q = x.shape[0]
    last = n_levels - 1
    restarts = max_restarts or n_levels
    _check_caps(frontier, round_base, restarts)

    def body(i, st):
        cell, rel, valid, done, overflow, depth = st
        ncell, nrel, nvalid, noverf = expand_and_sample(
            tree, x, cell, rel, src_gid, round_base + i, seed=seed,
            chunk=chunk, theta=theta, sigma=sigma, frontier=frontier,
            n_levels=n_levels)
        # keep previous result where already done
        cell = jnp.where(done, cell, ncell)
        rel = jnp.where(done, rel, nrel)
        valid = jnp.where(done, valid, nvalid)
        overflow = overflow | jnp.where(done, False, noverf)
        depth = depth + jnp.where(done, 0, 1).astype(jnp.int32)
        done = done | (rel >= last) | ~valid
        return (cell, rel, valid, done, overflow, depth)

    st = (start_cell.astype(jnp.int32), jnp.zeros((q,), jnp.int32),
          jnp.ones((q,), bool), jnp.zeros((q,), bool), jnp.zeros((q,), bool),
          jnp.zeros((q,), jnp.int32))
    cell, rel, valid, done, overflow, depth = jax.lax.fori_loop(
        0, restarts, body, st)
    valid = valid & (rel >= last)
    return cell, valid, overflow, depth


def select_member(x, member_pos, member_weight, member_valid, src_gid, *,
                  seed: int, chunk, sigma):
    """Pick an actual neuron within the chosen leaf cell, kernel-weighted
    (paper: 'the new partner must be a genuine neuron').
    member_*: (Q, M, ...). Returns (idx (Q,), valid (Q,))."""
    m = member_pos.shape[1]
    if m > chash.BH_DRAWS:
        raise ValueError(f"members_cap {m} exceeds the PRNG draw window "
                         f"({chash.BH_DRAWS})")
    d2 = pairwise_d2(x, member_pos)
    w = member_weight * _gauss(d2, sigma)
    logits = jnp.where(member_valid & (w > 1e-12),
                       jnp.log(jnp.maximum(w, 1e-30)), NEG)
    g = chash.gumbel(seed, chash.BH_DOMAIN,
                     chash.bh_ctr(chunk, MEMBER_ROUND, jnp.arange(m))[None, :],
                     src_gid[:, None])
    pick = jnp.argmax(logits + g, axis=1)
    valid = jnp.any(logits > NEG / 2, axis=1)
    return pick, valid


# ---------------------------------------------------------------- phase A
def phase_a(top, pos, src_gid, cfg, num_ranks: int, *, chunk):
    """Search the replicated tree down to the branch level. pos: (Q,3);
    src_gid: (Q,). Returns (branch_cell (Q,), valid (Q,))."""
    b = morton.branch_level(num_ranks)
    if b == 0:
        q = pos.shape[0]
        return jnp.zeros((q,), jnp.int32), jnp.ones((q,), bool)
    tree = stack_levels(top.counts, top.centroids, 0)
    cell, valid, _, _ = bh_search(
        tree, pos, src_gid, jnp.zeros((pos.shape[0],), jnp.int32),
        seed=cfg.seed, chunk=chunk, theta=cfg.theta, sigma=cfg.sigma,
        frontier=cfg.frontier_cap, n_levels=b + 1,
        round_base=PHASE_A_ROUND_BASE)
    return cell, valid


# ---------------------------------------------------------------- phase B
def phase_b_core(counts, cents, leaf_members, neuron_pos, vacant_d, x,
                 start_cell_rel, src_gid, valid_in, chunk, gid_base, *,
                 seed: int, sizes, theta: float, sigma: float, frontier: int,
                 n_levels: int):
    """Finish the search inside one rank's subtree, raw stacked arrays — the
    single source of truth executed by the Pallas traversal kernel body
    (kernels/bh_traverse.py) and the jnp reference path, which is what makes
    ``connectivity_impl='fused'`` bit-identical to ``'reference'``. Every
    operation is row-independent over Q, so the kernel's query blocking
    cannot change results.

    counts: (L, C); cents: (L, C, 3); sizes: static tuple of per-level cell
    edge lengths; leaf_members: (n_leaf, M); neuron_pos/vacant_d: the
    subtree's neuron data; x/start_cell_rel/src_gid/valid_in: (Q, ...)
    queries; chunk/gid_base: traced i32 scalars.
    Returns (target_gid (Q,), valid (Q,), depth (Q,) i32 restart rounds)."""
    tree = StackedTree(counts, cents, tuple(sizes), 0)
    leaf_cell, valid, _, depth = bh_search(
        tree, x, src_gid, start_cell_rel, seed=seed, chunk=chunk, theta=theta,
        sigma=sigma, frontier=frontier, n_levels=n_levels,
        round_base=PHASE_B_ROUND_BASE)
    valid = valid & valid_in
    members = leaf_members[leaf_cell]                  # (Q, M) local ids
    mvalid = members >= 0
    msafe = jnp.where(mvalid, members, 0)
    mgid = gid_base + msafe
    # exclude self-connection (a neuron never proposes to itself)
    mvalid = mvalid & (mgid != src_gid[:, None])
    mpos = neuron_pos[msafe]
    mw = jnp.where(mvalid, vacant_d[msafe], 0.0)
    pick, pvalid = select_member(x, mpos, mw, mvalid, src_gid, seed=seed,
                                 chunk=chunk, sigma=sigma)
    tgt_local = jnp.take_along_axis(msafe, pick[:, None], axis=1)[:, 0]
    tgt_gid = gid_base + tgt_local
    ok = valid & pvalid
    return jnp.where(ok, tgt_gid, -1), ok, depth


@registry.register_phase("traversal", "reference")
def phase_b_reference(stacked, local, neuron_pos, vacant_d, pos,
                      start_cell_rel, src_gid, valid_in, chunk, gid_base,
                      kw, interpret=None):
    """The jnp ``phase_b_core`` over the full query batch."""
    return phase_b_core(stacked.counts, stacked.centroids,
                        local.leaf_members, neuron_pos, vacant_d, pos,
                        start_cell_rel, src_gid, valid_in, chunk, gid_base,
                        **kw)


@registry.register_phase("traversal", "fused")
def phase_b_fused(stacked, local, neuron_pos, vacant_d, pos,
                  start_cell_rel, src_gid, valid_in, chunk, gid_base, kw,
                  interpret=None):
    """The Pallas traversal kernel (kernels/bh_traverse.py), query-blocked,
    same core math — bit-identical to the reference."""
    from repro.kernels import ops as kops   # lazy: kernels import us
    return kops.bh_traverse(
        stacked.counts, stacked.centroids, local.leaf_members,
        neuron_pos, vacant_d, pos, start_cell_rel, src_gid, valid_in,
        chunk, gid_base, interpret=interpret, **kw)


def phase_b(local, neuron_pos, vacant_d, pos, src_gid, start_cell_rel,
            valid_in, cfg, num_ranks: int, gid_base, *, chunk,
            interpret=None):
    """Phase-B dispatch per ``cfg.connectivity_impl`` (phase-registry
    domain "traversal"): 'reference' vs 'fused' — bit-identical lowerings
    of the same core math.

    local: a tree.LocalTree (or the gathered global tree in the old
    algorithm, with gid_base = 0 and global leaf members)."""
    b = morton.branch_level(num_ranks)
    stacked = stack_levels(local.counts, local.centroids, b)
    kw = dict(seed=cfg.seed, sizes=stacked.sizes, theta=cfg.theta,
              sigma=cfg.sigma, frontier=cfg.frontier_cap,
              n_levels=cfg.local_levels + 1)
    impl = registry.resolve("traversal", cfg.connectivity_impl)
    return impl(stacked, local, neuron_pos, vacant_d, pos, start_cell_rel,
                src_gid, valid_in, chunk, gid_base, kw, interpret=interpret)
