"""Formation/deletion request routing over the ranks mesh — the paper's
byte-counted record exchanges (§IV-A):

OLD ("move data"): the searching rank downloads the remote subtrees (modeled
as the all-gather of every rank's local tree + leaf neuron data — the
cache-everything endpoint of the paper's RMA+cache scheme) and finishes the
search locally. Then a plain formation request (source id, target id, type:
17 B in the paper) is all-to-all exchanged for accept/decline.

NEW ("move compute", location-aware): the searching rank ships a
formation-AND-calculation request — source id, source position, target node,
node kind, cell type: 42 B — to the rank owning the branch cell; that rank
finishes the search against its own subtree (zero additional communication)
and answers with (found id, success): 9 B.

Both run the identical phase-B search code against the same tree content,
keyed to the searcher's gid (connectome.traverse), so they form bit-identical
synapses — tested in tests/test_multidevice.py and tests/test_connectome.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.connectome import synapses as syn
from repro.connectome import traverse
from repro.connectome import tree as ctree
from repro.sim import registry


def cap_requests(cfg, num_ranks: int):
    """Per-(source, dest)-rank request buffer capacity. Locality skews demand
    toward the home rank, so tests/benchmarks needing zero overflow set
    requests_cap_factor >= num_ranks (=> cap = n)."""
    n = cfg.neurons_per_rank
    per_dest = max(n // max(num_ranks, 1), 1) * cfg.requests_cap_factor
    return min(n, max(32, -(-per_dest // 8) * 8))


def subs_base(cfg, num_ranks: int) -> int:
    """The per-rank unique-remote-source estimate the subscription registry
    is sized from: the measured count baked into ``cfg.subs_cap_base`` by
    ``Simulator.from_connectome`` (heavy-tailed real connectomes), else the
    near-uniform synthetic default ``n // num_ranks``. ``cap_subs`` and the
    runner's degradation ladder (which inverts cap -> factor) must use the
    same base, so it lives in one place."""
    if getattr(cfg, "subs_cap_base", None) is not None:
        return max(int(cfg.subs_cap_base), 32)
    return max(cfg.neurons_per_rank // max(num_ranks, 1), 32)


def cap_subs(cfg, num_ranks: int):
    """Subscription-registry capacity for the sparse rate exchange. The hard
    ceiling is min(n * s_max, (R-1) * n) — a rank can never subscribe to more
    unique remote sources than it has in-edge slots or than exist remotely.
    ``subs_cap_factor`` scales the head-room over ``subs_base`` below that
    (tests and benchmarks that require sparse == dense bit-identity raise it
    until ``stats['request_overflow']`` stays zero, like
    requests_cap_factor; ``from_connectome`` instead measures the base)."""
    n = cfg.neurons_per_rank
    full = min(n * cfg.max_synapses, max(num_ranks - 1, 1) * n)
    per = subs_base(cfg, num_ranks) * cfg.subs_cap_factor
    return int(min(full, max(32, -(-per // 8) * 8)))


def push_subscribed_rates(subs, rate, axis_name, num_ranks: int, n: int):
    """Sparse exchange, per-Delta push: ship each rank's subscription
    requests to the owner ranks (tiled all_to_all, once per connectivity
    update — the registry only changes with the connectome) and have owners
    answer with exactly the subscribed rates.

    ``subs``: (subs_cap,) sorted unique remote gids (``spikes.NO_SUB`` pad);
    ``rate``: (n,) this rank's advertised rates. Returns ``(remote_rates,
    pushed)`` — the (subs_cap,) compact rate buffer aligned with ``subs``
    (0.0 on pads) and the number of rate records actually pushed to this
    rank (the real exchange volume, O(|subs|) instead of O(R·n))."""
    from repro.core.spikes import NO_SUB
    subs_cap = subs.shape[0]
    valid = subs != NO_SUB
    pushed = jnp.sum(valid).astype(jnp.float32)
    if num_ranks == 1:
        return jnp.zeros((subs_cap,), jnp.float32), pushed
    owner = jnp.where(valid, subs // n, num_ranks)
    # subs is sorted, so owners are contiguous; slot < subs_cap always holds
    # (at most subs_cap valid entries total) — per-owner cap never overflows
    slot = ctree.positions_within(owner, num_ranks + 1)
    req = jnp.full((num_ranks, subs_cap), -1, jnp.int32)
    req = req.at[jnp.where(valid, owner, num_ranks), slot].set(
        jnp.where(valid, subs % n, -1), mode="drop")
    req = jax.lax.all_to_all(req, axis_name, 0, 0, tiled=True)
    # req[p, j] is now the local id rank p subscribed to — answer with rates
    payload = jnp.where(req >= 0, rate[jnp.clip(req, 0, n - 1)], 0.0)
    payload = jax.lax.all_to_all(payload, axis_name, 0, 0, tiled=True)
    # payload[o, j] = rate of this rank's j-th request to owner o — realign
    remote_rates = jnp.where(
        valid, payload[jnp.where(valid, owner, 0), slot], 0.0)
    return remote_rates, pushed


def cap_deletions(cfg, lesions: bool = False):
    """Deletion-message buffer capacity. Lesion protocols retract EVERY edge
    of a dead neuron in one update, so the cap then scales with
    requests_cap_factor like the formation buffers (n * s_max is the most a
    rank can ever send to one destination); without lesions the seed's
    homeostatic trickle keeps the original small buffer (and its collective
    bytes) unchanged."""
    n = cfg.neurons_per_rank
    if not lesions:
        return max(16, n // 4)
    return min(n * cfg.max_synapses,
               max(16, (n // 4) * cfg.requests_cap_factor))


def route_build_core(flat_other, flat_mine, n: int, num_ranks: int, cap: int,
                     ranker):
    """Build the per-destination (num_ranks, cap, 2) notification buffers
    from the flattened (partner gid, my gid) pairs — the pre-collective half
    of ``route_deletions``, shared verbatim by the reference path and the
    fused kernel body (kernels/synapse_apply.py). ``ranker(ids, buckets)``
    supplies the stable within-destination slot ranks (``positions_within``
    or the kernel's per-bucket cumsum ``bucket_ranks`` — integer-identical).
    Returns (buf, dropped count)."""
    valid = flat_other >= 0
    dest = jnp.where(valid, flat_other // n, num_ranks)
    slot = ranker(dest, num_ranks + 1)
    ok = valid & (slot < cap)
    buf = jnp.full((num_ranks, cap, 2), -1, jnp.int32)
    buf = buf.at[jnp.where(ok, dest, num_ranks),
                 jnp.where(ok, slot, 0)].set(
        jnp.stack([jnp.where(ok, flat_other, -1),
                   jnp.where(ok, flat_mine, -1)], -1), mode="drop")
    return buf, jnp.sum(valid & ~ok).astype(jnp.float32)


def route_deletions(kill, edges, my_gid_col, cfg, axis_name, num_ranks: int,
                    lesions: bool):
    """All-to-all the (partner gid, my gid) retraction notifications (paper:
    'the affected partner gains a vacant element'). Returns the received
    (num_ranks * cap, 2) messages and the dropped-notification count."""
    n = cfg.neurons_per_rank
    flat_other = jnp.where(kill, edges, -1).reshape(-1)
    flat_mine = jnp.broadcast_to(my_gid_col, kill.shape).reshape(-1)
    cap = cap_deletions(cfg, lesions)
    buf, dropped = route_build_core(flat_other, flat_mine, n, num_ranks, cap,
                                    ctree.positions_within)
    if num_ranks > 1:
        buf = jax.lax.all_to_all(buf, axis_name, 0, 0, tiled=True)
    return buf.reshape(num_ranks * cap, 2), dropped


def formation_new(cfg, positions, local_tree, vacant_d, in_edges, gids,
                  branch_cell, owner, start_rel, valid_a, rank, axis_name,
                  num_ranks: int, key, chunk):
    """Location-aware algorithm: 42B requests out, local phase B + accept,
    9B responses back. Returns (tgt_gid, accept dict, overflow count,
    (depth, processed)) — the last pair is the per-received-request phase-B
    restart depth and its validity mask, recorded into the telemetry
    frontier-depth histogram by the caller."""
    n = cfg.neurons_per_rank
    cap = cap_requests(cfg, num_ranks)
    dest = jnp.where(valid_a, owner, num_ranks)
    slot = ctree.positions_within(dest, num_ranks + 1)
    ok = valid_a & (slot < cap)
    ovf = jnp.sum(valid_a & ~ok).astype(jnp.float32)

    ibuf = jnp.full((num_ranks, cap, 2), -1, jnp.int32)   # src_gid, start_cell
    fbuf = jnp.zeros((num_ranks, cap, 3), jnp.float32)    # position
    d_c = jnp.where(ok, dest, num_ranks)
    s_c = jnp.where(ok, slot, 0)
    ibuf = ibuf.at[d_c, s_c].set(
        jnp.stack([jnp.where(ok, gids, -1), start_rel], -1), mode="drop")
    fbuf = fbuf.at[d_c, s_c].set(positions, mode="drop")
    if num_ranks > 1:
        ibuf = jax.lax.all_to_all(ibuf, axis_name, 0, 0, tiled=True)
        fbuf = jax.lax.all_to_all(fbuf, axis_name, 0, 0, tiled=True)

    r_src = ibuf[..., 0].reshape(-1)
    r_cell = ibuf[..., 1].reshape(-1)
    r_pos = fbuf.reshape(-1, 3)
    r_valid = r_src >= 0
    # the receiver re-derives the SAME per-searcher Gumbel stream from the
    # shipped source gid (counter-hash keyed by (chunk, gid) — DESIGN.md §2)
    tgt, bvalid, depth = traverse.phase_b(
        local_tree, positions, vacant_d, r_pos,
        jnp.where(r_valid, r_src, -2), jnp.clip(r_cell, 0, None), r_valid,
        cfg, num_ranks, rank * n, chunk=chunk)
    # accept/decline where the target lives (same rank — no extra comms);
    # the table mutation dispatches through the "apply" registry domain
    apply_impl = registry.resolve("apply", cfg.apply_impl)
    acc, new_in = apply_impl.accept(
        jnp.clip(tgt - rank * n, 0, n - 1), r_src, bvalid & (tgt >= 0),
        vacant_d, in_edges, key)
    # 9B responses retrace the request route
    rbuf = jnp.stack([jnp.where(acc, tgt, -1),
                      acc.astype(jnp.int32)], -1).reshape(num_ranks, cap, 2)
    if num_ranks > 1:
        rbuf = jax.lax.all_to_all(rbuf, axis_name, 0, 0, tiled=True)
    resp_tgt = rbuf[d_c, s_c, 0]
    resp_ok = (rbuf[d_c, s_c, 1] > 0) & ok
    return resp_tgt, {"accepted": resp_ok, "in_edges": new_in}, ovf, \
        (depth, r_valid)


def formation_old(cfg, positions, local_tree, vacant_d, in_edges, gids,
                  branch_cell, valid_a, rank, axis_name, num_ranks: int, key,
                  chunk):
    """Baseline: download every rank's subtree + leaf data (RMA+cache
    endpoint), search locally, then exchange 17B formation requests.
    Returns (tgt_gid, accepted, new_in_edges, downloaded node count,
    (depth, searched)) — the last pair is the per-local-searcher phase-B
    restart depth and its mask, for the telemetry frontier-depth
    histogram."""
    n = cfg.neurons_per_rank
    # ---- the download: all levels, members, positions, weights ----
    if num_ranks > 1:
        g_counts = tuple(jax.lax.all_gather(c, axis_name, axis=0, tiled=True)
                         for c in local_tree.counts)
        g_cents = tuple(jax.lax.all_gather(z, axis_name, axis=0, tiled=True)
                        for z in local_tree.centroids)
        members_g = jnp.where(local_tree.leaf_members >= 0,
                              local_tree.leaf_members + rank * n, -1)
        g_members = jax.lax.all_gather(members_g, axis_name, axis=0,
                                       tiled=True)
        g_pos = jax.lax.all_gather(positions, axis_name, axis=0, tiled=True)
        g_vac = jax.lax.all_gather(vacant_d, axis_name, axis=0, tiled=True)
    else:
        g_counts, g_cents = local_tree.counts, local_tree.centroids
        g_members = local_tree.leaf_members
        g_pos, g_vac = positions, vacant_d
    downloaded = (sum(c.shape[0] for c in g_counts) + g_pos.shape[0]) \
        * (num_ranks - 1) / max(num_ranks, 1)
    g_tree = ctree.LocalTree(g_counts, g_cents, g_members,
                             jnp.zeros((), jnp.int32))
    # ---- phase B locally for my searchers (same PRNG stream as 'new') ----
    tgt, bvalid, depth = traverse.phase_b(g_tree, g_pos, g_vac, positions,
                                          gids, branch_cell, valid_a, cfg,
                                          num_ranks, 0, chunk=chunk)
    # ---- classic 17B formation request to the target's rank ----
    cap = cap_requests(cfg, num_ranks)
    dest = jnp.where(bvalid & (tgt >= 0), tgt // n, num_ranks)
    slot = ctree.positions_within(dest, num_ranks + 1)
    ok = (dest < num_ranks) & (slot < cap)
    ibuf = jnp.full((num_ranks, cap, 2), -1, jnp.int32)
    d_c = jnp.where(ok, dest, num_ranks)
    s_c = jnp.where(ok, slot, 0)
    ibuf = ibuf.at[d_c, s_c].set(
        jnp.stack([jnp.where(ok, gids, -1), jnp.where(ok, tgt, -1)], -1),
        mode="drop")
    if num_ranks > 1:
        ibuf = jax.lax.all_to_all(ibuf, axis_name, 0, 0, tiled=True)
    r_src = ibuf[..., 0].reshape(-1)
    r_tgt = ibuf[..., 1].reshape(-1)
    r_valid = (r_src >= 0) & (r_tgt >= 0)
    apply_impl = registry.resolve("apply", cfg.apply_impl)
    acc, new_in = apply_impl.accept(
        jnp.clip(r_tgt - rank * n, 0, n - 1), r_src, r_valid, vacant_d,
        in_edges, key)
    rbuf = acc.astype(jnp.int32).reshape(num_ranks, cap)
    if num_ranks > 1:
        rbuf = jax.lax.all_to_all(rbuf, axis_name, 0, 0, tiled=True)
    accepted = (rbuf[d_c, s_c] > 0) & ok
    return tgt, accepted, new_in, jnp.asarray(downloaded, jnp.float32), \
        (depth, valid_a)
