"""Level-array spatial octree (TPU-native adaptation of the paper's pointer
octree; DESIGN.md §2/§6).

A node at octree level L covering Morton cell c has children 8c..8c+7 at level
L+1 — the tree is a family of dense per-level arrays (vacant-element counts +
weighted centroids), and bottom-up aggregation is a reshape(-1, 8).sum trick
because Morton order keeps siblings contiguous.

Two trees exist (paper Fig. 1):
  * the rank-local tree: levels b .. b+local_levels over the rank's own cells;
  * the replicated upper tree: levels 0 .. b, built from the all-exchanged
    branch nodes (Alg. 1, line 3).

The build is a registered phase (registry domain "tree", selected by
``BrainConfig.tree_impl``): 'reference' computes the per-leaf slot ranks with
``positions_within`` (stable argsort + searchsorted), 'fused' gets the same
(rel, slot) pair from the Pallas Morton radix-sort kernel
(kernels/radix_sort.py) with the sort state VMEM-resident. Both feed the
identical scatter-add/aggregation expressions (``_assemble_tree``), and the
slot ranks are integer-exact by construction, so the two builds are
bit-identical (tests/test_radix_sort.py, tests/test_connectome.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import morton
from repro.sim import registry


class LocalTree(NamedTuple):
    """Per-rank subtree. levels[k] covers octree level (b + k); arrays are
    (n_cells_k,) counts and (n_cells_k, 3) centroid sums (weighted by counts).
    leaf_members: (n_leaf_cells, M) local neuron indices (-1 pad)."""
    counts: tuple           # tuple over relative level 0..L of (cells,) f32
    centroids: tuple        # matching (cells, 3) f32 (weighted position SUM)
    leaf_members: jnp.ndarray
    base_cell: jnp.ndarray  # first branch cell owned by this rank (scalar i32)


class TopTree(NamedTuple):
    """Replicated upper tree: levels 0..b (level k has 8^k cells)."""
    counts: tuple
    centroids: tuple


def positions_within(ids, num_buckets: int):
    """Rank of each element within its bucket (stable: elements with equal
    ids keep their original relative order, so ranks count earlier
    occurrences — tests/test_connectome.py holds this as a property)."""
    n = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    first = jnp.searchsorted(sorted_ids, jnp.arange(num_buckets), side="left")
    ranks = jnp.arange(n, dtype=jnp.int32) - first[sorted_ids].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks)


def _tree_geometry(rank, cfg, num_ranks: int):
    """(leaf_level, n_leaf, base_cell) of the rank's subdomain block."""
    b = morton.branch_level(num_ranks)
    c_per = morton.cells_per_rank(num_ranks)
    lloc = cfg.local_levels
    return b + lloc, c_per * 8 ** lloc, rank * c_per


def _assemble_tree(positions, weights, rel, slot, cfg, n_leaf: int,
                   base_cell, members_cap: int) -> LocalTree:
    """Shared back half of both builds: scatter-add the leaf level, aggregate
    parents (reshape(-1, 8).sum), and fill the capped membership table from
    the per-leaf slot ranks. Identical expressions for both impls — the
    builds can only differ through (rel, slot), which are integer-exact."""
    counts = [jnp.zeros((n_leaf,), jnp.float32).at[rel].add(weights)]
    centroids = [jnp.zeros((n_leaf, 3), jnp.float32).at[rel].add(
        positions * weights[:, None])]
    for _ in range(cfg.local_levels):
        counts.insert(0, counts[0].reshape(-1, 8).sum(1))
        centroids.insert(0, centroids[0].reshape(-1, 8, 3).sum(1))

    # leaf membership table (cap M per leaf; overflow dropped this round)
    m = members_cap
    ok = slot < m
    tbl = jnp.full((n_leaf, m), -1, jnp.int32)
    tbl = tbl.at[rel, jnp.where(ok, slot, m)].set(
        jnp.arange(positions.shape[0], dtype=jnp.int32), mode="drop")
    return LocalTree(tuple(counts), tuple(centroids), tbl,
                     jnp.asarray(base_cell, jnp.int32))


@registry.register_phase("tree", "reference")
def build_local_tree(positions, weights, rank, cfg, num_ranks: int,
                     members_cap: int = 4, interpret=None) -> LocalTree:
    """positions: (n,3); weights: (n,) vacant dendritic elements (>=0).
    rank: scalar int (traced ok). Returns the rank's subtree.

    ``members_cap`` bounds the per-leaf membership table: a leaf holding more
    than M neurons keeps the M lowest-indexed ones this round (the rest are
    invisible to member selection until the occupancy drops — a static-shape
    deviation, like the frontier cap)."""
    leaf_level, n_leaf, base_cell = _tree_geometry(rank, cfg, num_ranks)
    leaf_cells_abs = morton.morton_encode(positions, leaf_level)
    # relative leaf index within the rank's subdomain block
    rel = leaf_cells_abs - base_cell * 8 ** cfg.local_levels
    rel = jnp.clip(rel, 0, n_leaf - 1)
    slot = positions_within(rel, n_leaf)
    return _assemble_tree(positions, weights, rel, slot, cfg, n_leaf,
                          base_cell, members_cap)


@registry.register_phase("tree", "fused")
def build_local_tree_fused(positions, weights, rank, cfg, num_ranks: int,
                           members_cap: int = 4, interpret=None) -> LocalTree:
    """Same build with (rel, slot) from the Pallas Morton radix-sort kernel
    — encode, sort, and rank state never leave VMEM."""
    from repro.kernels import ops as kops  # lazy: kernels import us
    leaf_level, n_leaf, base_cell = _tree_geometry(rank, cfg, num_ranks)
    rel, slot = kops.morton_sort(
        positions, jnp.asarray(base_cell, jnp.int32) * 8 ** cfg.local_levels,
        leaf_level=leaf_level, n_leaf=n_leaf, interpret=interpret)
    return _assemble_tree(positions, weights, rel, slot, cfg, n_leaf,
                          base_cell, members_cap)


def build_tree(cfg, positions, weights, rank, num_ranks: int,
               members_cap: int = 4) -> LocalTree:
    """Registry dispatch on ``cfg.tree_impl`` ('reference' | 'fused')."""
    build = registry.resolve("tree", cfg.tree_impl)
    return build(positions, weights, rank, cfg, num_ranks, members_cap)


def build_top_tree(branch_counts, branch_centroids, num_ranks: int) -> TopTree:
    """branch_*: (8^b,) / (8^b, 3) — the all-exchanged branch nodes.
    Aggregates the replicated levels b-1 .. 0."""
    b = morton.branch_level(num_ranks)
    counts = [branch_counts]
    cents = [branch_centroids]
    for _ in range(b):
        counts.insert(0, counts[0].reshape(-1, 8).sum(1))
        cents.insert(0, cents[0].reshape(-1, 8, 3).sum(1))
    return TopTree(tuple(counts), tuple(cents))


def exchange_branch_nodes(local: LocalTree, axis_name: str,
                          num_ranks: int) -> TopTree:
    """Alg. 1 line 3: all_exchange_branch_nodes. The rank's level-0 (= branch)
    arrays are concatenated across ranks in Morton order."""
    bc = jax.lax.all_gather(local.counts[0], axis_name, axis=0, tiled=True)
    bz = jax.lax.all_gather(local.centroids[0], axis_name, axis=0, tiled=True)
    return build_top_tree(bc, bz, num_ranks)


def node_center(centroid_sum, count):
    """Weighted mean position of a node (centroid of vacant elements)."""
    return centroid_sum / jnp.maximum(count, 1e-9)[..., None]
