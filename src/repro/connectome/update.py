"""The per-chunk connectivity update (paper phase 3), orchestrated:

  3a  deletion by retraction — element loss breaks bound synapses, partners
      are notified via routed messages and regain vacant elements;
  3b  formation — octree build, branch-node exchange, phase-A search over
      the replicated top tree, then the algorithm pair (phase registry
      domain "connectivity"): 'old' downloads every subtree and searches
      locally, 'new' ships 42B requests to the owning rank (routing.py);
  3c  rate refresh + Delta-periodic rate exchange (registry domain
      "rate_exchange") — 'dense' all-gathers the replicated (R, n) table;
      'sparse' rebuilds the subscription registry from the just-updated
      in-edge table (subscriptions only change when the connectome does)
      and owners push only the subscribed rates (DESIGN.md §7).

All scenario effects (lesion masks) apply before the algorithm branch, so
old == new stays bit-identical under every protocol. Randomness: retraction
and acceptance use chunk-keyed jax.random priorities (rank-independent);
every Barnes-Hut draw uses the counter hash keyed by (chunk, source gid)
(connectome.traverse) — both reconstructible wherever the computation runs
(DESIGN.md §2/§6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.connectome import routing
from repro.connectome import synapses as syn
from repro.connectome import traverse
from repro.connectome import tree as ctree
from repro.core import morton, spikes
from repro.core.neuron import refresh_rate
from repro.scenarios import protocol as proto
from repro.sim import registry


# ---------------------------------------------------------------- formation
@registry.register_phase("connectivity", "new")
def formation_phase_new(ctx, state, local_tree, vac_d_pos, out_edges,
                        in_edges, gids, branch_cell, owner, start_rel,
                        valid_a, k_accept, stats):
    """Paper's NEW algorithm: ship 42B formation-and-calculation requests
    to the rank that owns the target subtree (move compute to the data)."""
    tgt_gid, accept, ovf, (depth, processed) = routing.formation_new(
        ctx.cfg, state.positions, local_tree, vac_d_pos, in_edges, gids,
        branch_cell, owner, start_rel, valid_a, ctx.rank, ctx.axis_name,
        ctx.num_ranks, k_accept, state.chunk)
    in_edges = accept.pop("in_edges")
    stats = stats.count("request_overflow", ovf)
    stats = stats.count("bh_responses", jnp.sum(accept["accepted"]))
    # restart depths of the phase-B searches THIS rank executed (the
    # received requests) — identical under both traversal lowerings
    stats = ctx.metrics.traversal(stats, depth, processed)
    out_edges = syn.add_out_edges(out_edges, tgt_gid, accept["accepted"])
    stats = stats.count("synapses_formed", jnp.sum(accept["accepted"]))
    return out_edges, in_edges, stats


@registry.register_phase("connectivity", "old")
def formation_phase_old(ctx, state, local_tree, vac_d_pos, out_edges,
                        in_edges, gids, branch_cell, owner, start_rel,
                        valid_a, k_accept, stats):
    """Paper's OLD baseline: download every remote subtree + leaf neuron
    data ("RMA download with caching") and finish the search locally."""
    tgt_gid, accepted, new_in, downloaded, (depth, searched) = \
        routing.formation_old(
            ctx.cfg, state.positions, local_tree, vac_d_pos, in_edges, gids,
            branch_cell, valid_a, ctx.rank, ctx.axis_name, ctx.num_ranks,
            k_accept, state.chunk)
    out_edges = syn.add_out_edges(out_edges, tgt_gid, accepted)
    stats = stats.count("tree_nodes_downloaded", downloaded)
    # restart depths of MY searchers against the downloaded global tree
    stats = ctx.metrics.traversal(stats, depth, searched)
    stats = stats.count("synapses_formed", jnp.sum(accepted))
    return out_edges, new_in, stats


# ---------------------------------------------------------------- exchange
@registry.register_phase("rate_exchange", "dense")
def exchange_dense(ctx, state, neurons, in_edges, stats):
    """All-gather every rank's full (n,) rate vector into the replicated
    (R, n) table — O(R*n) bytes per rank per Delta (reference layout)."""
    n = ctx.cfg.neurons_per_rank
    rates_table = spikes.exchange_rates(neurons.rate, ctx.axis_name,
                                        ctx.num_ranks)
    # every rank broadcasts its full n rates to the other R-1 ranks —
    # rates_sent counts rate records actually shipped over the wire
    stats = stats.count("rates_sent", float(n * max(ctx.num_ranks - 1, 0)))
    return rates_table, state.subs, state.rate_slots, state.remote_rates, \
        stats


@registry.register_phase("rate_exchange", "sparse")
def exchange_sparse(ctx, state, neurons, in_edges, stats):
    """Demand-driven push: rebuild the subscription registry from the
    just-updated in-edge table (subscriptions only change when the
    connectome does — computation moves to the data), then owners push
    exactly the subscribed rates — O(unique remote sources) instead of
    O(R*n)."""
    cfg, n = ctx.cfg, ctx.cfg.neurons_per_rank
    subs, rate_slots, ovf = spikes.build_subscriptions(
        in_edges, ctx.rank, n, routing.cap_subs(cfg, ctx.num_ranks))
    # counted both in the aggregate drop counter and in a dedicated key
    # (benchmarks must not infer it from the shared aggregate)
    stats = stats.count("request_overflow", ovf)
    stats = stats.count("subscription_overflow", ovf)
    # one registry-occupancy histogram entry per chunk (sparse only —
    # the dense layout has no registry and leaves the histogram zero)
    stats = ctx.metrics.subs_occupancy(stats, subs, spikes.NO_SUB)
    remote_rates, pushed = routing.push_subscribed_rates(
        subs, neurons.rate, ctx.axis_name, ctx.num_ranks, n)
    # the exchange ships one 4B request id out AND one 4B rate back per
    # subscription — both streams are counted (Tables I/II honesty)
    stats = stats.count("subscription_requests", pushed)
    stats = stats.count("rates_sent", pushed)
    return state.rates_table, subs, rate_slots, remote_rates, stats


# ---------------------------------------------------------------- update
def connectivity_update(state, ctx):
    """One structural-plasticity update. ``state`` is the engine's
    BrainState (any NamedTuple with neurons/out_edges/in_edges/positions,
    the rate-exchange fields rates_table (dense) or subs/rate_slots/
    remote_rates (sparse), chunk, and stats); ``ctx`` a
    ``repro.sim.phases.PhaseContext``. Returns the state updated with chunk
    advanced."""
    cfg, rank = ctx.cfg, ctx.rank
    axis_name, num_ranks = ctx.axis_name, ctx.num_ranks
    n = cfg.neurons_per_rank
    # chunk_key is rank-independent: every rank derives the same stream, so
    # per-(gid) sub-streams are reproducible wherever the computation runs —
    # the property that makes old == new bit-identical (DESIGN.md §2)
    chunk_key = jax.random.fold_in(jax.random.key(cfg.seed + 2), state.chunk)
    gid0 = rank * n
    gids = gid0 + jnp.arange(n, dtype=jnp.int32)
    stats = state.stats          # telemetry.metrics.Metrics (immutable)

    # lesion mask at the update instant (the step right after this chunk's
    # activity scan). Applied BEFORE the algorithm branch so 'old' and 'new'
    # see identical inputs — the bit-identity invariant holds per protocol.
    alive = proto.alive_mask(ctx.events, ctx.regions, state.positions,
                             (state.chunk + 1) * cfg.rate_period) \
        if ctx.events else None
    if alive is not None:
        # dead neurons lose all synaptic elements -> full retraction below,
        # partners are notified and regain vacant elements
        state = state._replace(neurons=state.neurons._replace(
            ax_elements=jnp.where(alive, state.neurons.ax_elements, 0.0),
            de_elements=jnp.where(alive, state.neurons.de_elements, 0.0)))

    # ---- deletion by retraction (phase 3a) -------------------------------
    with jax.named_scope("repro.conn.retraction"):
        out_edges, in_edges = state.out_edges, state.in_edges
        out_cnt, in_cnt = syn.counts(out_edges), syn.counts(in_edges)
        del_out = jnp.maximum(
            out_cnt - jnp.floor(state.neurons.ax_elements).astype(jnp.int32),
            0)
        del_in = jnp.maximum(
            in_cnt - jnp.floor(state.neurons.de_elements).astype(jnp.int32),
            0)
        k_out, k_in, k_accept = jax.random.split(chunk_key, 3)
        out_edges, kill_out = syn.retract_synapses(k_out, out_edges, del_out,
                                                   gids)
        in_edges, kill_in = syn.retract_synapses(k_in, in_edges, del_in, gids)
        stats = stats.count("synapses_deleted",
                            jnp.sum(kill_out) + jnp.sum(kill_in))

        # notify partners; kill masks index the PRE-retraction tables.
        # Routing + table mutation dispatch through the "apply" registry
        # domain ('fused' = the VMEM-resident kernels, bit-identical)
        apply_impl = registry.resolve("apply", cfg.apply_impl)
        lesions = proto.has_lesions(ctx.scenario)
        msgs_out, ovf_out = apply_impl.route(
            kill_out, state.out_edges, gids[:, None], cfg, axis_name,
            num_ranks, lesions)
        msgs_in, ovf_in = apply_impl.route(
            kill_in, state.in_edges, gids[:, None], cfg, axis_name, num_ranks,
            lesions)
        # dropped notifications leave stale partner edges — surface them
        stats = stats.count("request_overflow", ovf_out + ovf_in)
        # apply: partner of my out-edge removes its in-edge, and vice versa
        # (each table drains its messages and re-compacts in one stage)
        in_edges = apply_impl.deletion(
            in_edges, jnp.clip(msgs_out[:, 0] - gid0, 0, n - 1),
            msgs_out[:, 1],
            (msgs_out[:, 0] >= gid0) & (msgs_out[:, 0] < gid0 + n))
        out_edges = apply_impl.deletion(
            out_edges, jnp.clip(msgs_in[:, 0] - gid0, 0, n - 1),
            msgs_in[:, 1],
            (msgs_in[:, 0] >= gid0) & (msgs_in[:, 0] < gid0 + n))

    # ---- formation (phase 3b) --------------------------------------------
    out_cnt, in_cnt = syn.counts(out_edges), syn.counts(in_edges)
    vac_a = jnp.floor(state.neurons.ax_elements).astype(jnp.int32) - out_cnt
    vac_d = state.neurons.de_elements - in_cnt.astype(jnp.float32)
    vac_d_pos = jnp.maximum(vac_d, 0.0)

    with jax.named_scope("repro.conn.tree_build"):
        # registry domain "tree": 'reference' (jnp Morton sort) | 'fused'
        # (Pallas radix-sort kernel), bit-identical builds
        local_tree = ctree.build_tree(cfg, state.positions, vac_d_pos, rank,
                                      num_ranks)
        top = ctree.exchange_branch_nodes(local_tree, axis_name, num_ranks)
        stats = ctx.metrics.tree_built(stats, local_tree)

    searching = vac_a >= 1
    if alive is not None:
        # dead neurons neither search for partners nor offer vacancies
        searching = searching & alive
        vac_d_pos = jnp.where(alive, vac_d_pos, 0.0)
    with jax.named_scope("repro.conn.phase_a"):
        branch_cell, valid_a = traverse.phase_a(top, state.positions, gids,
                                                cfg, num_ranks,
                                                chunk=state.chunk)
    valid_a = valid_a & searching
    c_per = morton.cells_per_rank(num_ranks)
    owner = jnp.clip(branch_cell // c_per, 0, num_ranks - 1)
    start_rel = branch_cell - owner * c_per
    stats = stats.count("bh_requests", jnp.sum(valid_a))
    # either algorithm sends one formation request per valid searcher (17 B
    # plain / 42 B formation-and-calculation — Tables I/II accounting)
    stats = stats.count("formation_requests", jnp.sum(valid_a))

    formation = registry.resolve("connectivity", cfg.connectivity_alg)
    with jax.named_scope("repro.conn.formation"):
        out_edges, in_edges, stats = formation(
            ctx, state, local_tree, vac_d_pos, out_edges, in_edges, gids,
            branch_cell, owner, start_rel, valid_a, k_accept, stats)

    # ---- rate refresh + Delta-periodic exchange (phase 3c) ---------------
    neurons = refresh_rate(state.neurons, cfg, alive)
    rates_table = state.rates_table
    subs, rate_slots = state.subs, state.rate_slots
    remote_rates = state.remote_rates
    if cfg.spike_alg != "old":
        # (on the old spike path the rate state is dead — skip the
        # per-chunk exchange and its accounting entirely)
        exchange = registry.resolve("rate_exchange", cfg.rate_exchange)
        with jax.named_scope("repro.conn.exchange"):
            rates_table, subs, rate_slots, remote_rates, stats = exchange(
                ctx, state, neurons, in_edges, stats)
    return state._replace(neurons=neurons, out_edges=out_edges,
                          in_edges=in_edges, rates_table=rates_table,
                          subs=subs, rate_slots=rate_slots,
                          remote_rates=remote_rates,
                          chunk=state.chunk + 1, stats=stats)
