"""Connectome subsystem: the MSP connectivity update as a first-class data
structure + algorithm package (paper §III-B/§IV-A; DESIGN.md §6).

The paper's headline result is the 6x faster connectivity update, and its
Fig. 11 attributes ~55% of the optimized runtime to Barnes-Hut computation —
so the whole phase lives here, out of the engine:

  tree.py      level-array octree (rank-local subtree + replicated top tree)
  traverse.py  vectorized Barnes-Hut search — phase A over the top tree,
               phase B over one subtree; ``phase_b_core`` is the shared jnp
               math executed by both the reference path and the Pallas
               traversal kernel (kernels/bh_traverse.py), bit-identical
  synapses.py  synapse-table ops (counts/compact/accept/retract/remove),
               all vectorized segment/cumsum — no sequential loops
  routing.py   formation/deletion request routing over the ranks mesh
               (the paper's 17B/42B/9B record exchanges)
  update.py    the per-chunk connectivity update orchestration

Selection: ``BrainConfig.connectivity_impl ∈ {"reference", "fused"}``
(mirroring ``activity_impl``) picks the jnp phase-B or the Pallas kernel.
"""
from repro.connectome.synapses import SynapseTable, init_synapses
from repro.connectome.update import connectivity_update

__all__ = ["SynapseTable", "init_synapses", "connectivity_update"]
