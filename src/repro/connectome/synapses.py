"""Synapse-table ops: the (n, S_max) out/in edge tables and everything that
mutates them — accept, add, retract, compact, message-driven removal.

All ops are fully vectorized (segment ranks via stable sort + cumsum): the
seed's sequential ``fori_loop`` over deletion messages and the argsort-based
``compact`` are gone. Randomized choices (retraction, acceptance) use
keyed per-(src,tgt) priorities so they are independent of buffer ordering —
the property that lets two differently-routed request streams commit
identical edge tables (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.connectome.tree import positions_within


class SynapseTable(NamedTuple):
    out_edges: jnp.ndarray   # (n, S_max) target gids, -1 empty
    in_edges: jnp.ndarray    # (n, S_max) source gids, -1 empty


def init_synapses(n: int, s_max: int) -> SynapseTable:
    e = jnp.full((n, s_max), -1, jnp.int32)
    return SynapseTable(e, e)


def counts(edges):
    return jnp.sum(edges >= 0, axis=1)


def compact(edges):
    """Push occupied slots to the front of each row (stable). A row-wise
    cumsum gives each occupied slot its destination directly — no argsort."""
    n, s_max = edges.shape
    occ = edges >= 0
    dst = jnp.cumsum(occ, axis=1) - 1
    out = jnp.full_like(edges, -1)
    return out.at[jnp.arange(n)[:, None],
                  jnp.where(occ, dst, s_max)].set(edges, mode="drop")


def edge_priority(key, a_gid, b_gid):
    """Deterministic per-(a,b) uniform — independent of buffer ordering, so
    the old and new algorithms make identical accept/decline choices no
    matter how requests were routed."""
    k = jax.vmap(lambda a, b: jax.random.fold_in(jax.random.fold_in(key, a),
                                                 b))(a_gid, b_gid)
    return jax.vmap(lambda kk: jax.random.uniform(kk))(k)


def accept_requests(tgt_lid, src_gid, valid, vacant_d, in_edges, key):
    """Targets accept as many requests as they have vacant dendritic elements
    (random subset — paper §III-A(c)); accepted requests are written into
    in_edges (assumed compacted). Returns (accept (Q,) bool, new in_edges)."""
    n, s_max = in_edges.shape
    q = tgt_lid.shape[0]
    lid = jnp.where(valid, tgt_lid, n)                  # bucket n = invalid
    # acceptance rank within each target by keyed (src,tgt) priority —
    # ordering-independent (paper: 'accept ... randomly')
    prio = edge_priority(key, jnp.where(valid, src_gid, 0),
                         jnp.where(valid, lid, 0))
    order = jnp.lexsort((prio, lid))
    rank_p = positions_within(lid[order], n + 1)
    rank_in_tgt = jnp.zeros((q,), jnp.int32).at[order].set(rank_p)
    lid_c = jnp.clip(lid, 0, n - 1)
    base = counts(in_edges)
    free = s_max - base
    cap = jnp.minimum(jnp.floor(jnp.where(valid, vacant_d[lid_c], 0.0)),
                      free[lid_c].astype(jnp.float32))
    accept = valid & (rank_in_tgt < cap)
    slot = jnp.where(accept, base[lid_c] + rank_in_tgt, s_max)
    new_in = in_edges.at[lid_c, jnp.clip(slot, 0, s_max)].set(
        jnp.where(accept, src_gid, in_edges[lid_c, jnp.clip(slot, 0, s_max - 1)]),
        mode="drop")
    return accept, new_in


def add_out_edges(out_edges, tgt_gid, accept):
    """Write accepted targets into the source neurons' out-edge tables.
    tgt_gid/accept: (n_sources,) — one pending request per source neuron."""
    n, s_max = out_edges.shape
    base = counts(out_edges)
    slot = jnp.where(accept & (base < s_max), base, s_max)
    return out_edges.at[jnp.arange(n), slot].set(
        jnp.where(accept, tgt_gid, -1), mode="drop")


def retract_synapses(key, edges, n_delete, row_gids):
    """Randomly break ``n_delete[i]`` bound synapses of neuron i (paper: 'one
    is chosen randomly'). Priority is keyed by (row gid, edge gid) so the
    choice is independent of slot ordering. Returns (new_edges, kill mask)."""
    n, s_max = edges.shape
    occupied = edges >= 0
    flat_prio = edge_priority(
        key, jnp.broadcast_to(row_gids[:, None], edges.shape).reshape(-1),
        jnp.where(occupied, edges, 0).reshape(-1))
    prio = jnp.where(occupied, flat_prio.reshape(edges.shape), 2.0)
    order = jnp.argsort(prio, axis=1)                   # occupied first, random
    ranks = jnp.zeros_like(edges).at[
        jnp.arange(n)[:, None], order].set(jnp.arange(s_max)[None, :])
    kill = occupied & (ranks < n_delete[:, None])
    return jnp.where(kill, -1, edges), kill


def remove_edges_by_messages(edges, msg_lid, msg_gid, msg_valid):
    """Remove one occurrence of msg_gid from row msg_lid per message,
    earliest slots first — exactly the sequential drain semantics (each
    message removes the then-first matching slot), but computed in one
    vectorized pass: messages and edge slots are lex-sorted into
    (row, value) groups with messages leading, and an edge slot dies iff
    its occurrence rank within the group is below the group's message
    count (segment ranks via cummax/cumsum)."""
    n, s_max = edges.shape
    q = msg_lid.shape[0]
    e_flat = edges.reshape(-1)
    e_idx = jnp.arange(n * s_max, dtype=jnp.int32)
    # invalid messages bucket at row n, empty slots at row n+1: past every
    # real row, so neither can join a live (row, value) group
    rows = jnp.concatenate([
        jnp.where(msg_valid, msg_lid, n).astype(jnp.int32),
        jnp.where(e_flat >= 0, e_idx // s_max, n + 1)])
    vals = jnp.concatenate([msg_gid.astype(jnp.int32), e_flat])
    is_edge = jnp.concatenate([jnp.zeros((q,), bool),
                               jnp.ones((n * s_max,), bool)])
    slot = jnp.concatenate([jnp.zeros((q,), jnp.int32), e_idx % s_max])
    # (row, value, messages-first, slot order) — stable groups
    order = jnp.lexsort((slot, is_edge.astype(jnp.int32), vals, rows))
    r_s, v_s, e_s = rows[order], vals[order], is_edge[order]
    k = jnp.arange(rows.shape[0])
    newgrp = (k == 0) | (r_s != jnp.roll(r_s, 1)) | (v_s != jnp.roll(v_s, 1))
    start = jax.lax.cummax(jnp.where(newgrp, k, 0))
    is_msg = (~e_s).astype(jnp.int32)
    mcum = jnp.cumsum(is_msg)                 # inclusive message prefix count
    # messages precede edges inside a group, so for an edge item the group's
    # full message count has already accumulated by its position
    m_group = mcum - (mcum[start] - is_msg[start])
    occ_rank = (k - start) - m_group
    kill_sorted = e_s & (occ_rank < m_group)
    kill = jnp.zeros((q + n * s_max,), bool).at[order].set(kill_sorted)
    return jnp.where(kill[q:].reshape(n, s_max), -1, edges)
