"""Synapse-table ops: the (n, S_max) out/in edge tables and everything that
mutates them — accept, add, retract, compact, message-driven removal.

All ops are fully vectorized (segment ranks via stable sort + cumsum): the
seed's sequential ``fori_loop`` over deletion messages and the argsort-based
``compact`` are gone. Randomized choices (retraction, acceptance) use
keyed per-(src,tgt) priorities so they are independent of buffer ordering —
the property that lets two differently-routed request streams commit
identical edge tables (DESIGN.md §2).

The table-mutating stages are a registered phase (registry domain "apply",
selected by ``BrainConfig.apply_impl``): an ``ApplyImpl`` bundles the
deletion drain (``remove_edges_by_messages`` -> ``compact``), the formation
``accept``, and the deletion-routing buffer build. 'reference' runs the jnp
ops below; 'fused' runs the same shared cores inside one VMEM-resident
Pallas pass over the edge table per stage (kernels/synapse_apply.py) —
bit-identical because every rank/priority is either integer-exact or the
very same XLA expression on the same inputs (DESIGN.md §11).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.connectome.tree import positions_within
from repro.sim import registry


class SynapseTable(NamedTuple):
    out_edges: jnp.ndarray   # (n, S_max) target gids, -1 empty
    in_edges: jnp.ndarray    # (n, S_max) source gids, -1 empty


def init_synapses(n: int, s_max: int) -> SynapseTable:
    e = jnp.full((n, s_max), -1, jnp.int32)
    return SynapseTable(e, e)


def counts(edges):
    return jnp.sum(edges >= 0, axis=1)


def compact(edges):
    """Push occupied slots to the front of each row (stable). A row-wise
    cumsum gives each occupied slot its destination directly — no argsort."""
    n, s_max = edges.shape
    occ = edges >= 0
    dst = jnp.cumsum(occ, axis=1) - 1
    out = jnp.full_like(edges, -1)
    return out.at[jnp.arange(n)[:, None],
                  jnp.where(occ, dst, s_max)].set(edges, mode="drop")


def edge_priority(key, a_gid, b_gid):
    """Deterministic per-(a,b) uniform — independent of buffer ordering, so
    the old and new algorithms make identical accept/decline choices no
    matter how requests were routed."""
    k = jax.vmap(lambda a, b: jax.random.fold_in(jax.random.fold_in(key, a),
                                                 b))(a_gid, b_gid)
    return jax.vmap(lambda kk: jax.random.uniform(kk))(k)


def accept_core(tgt_lid, src_gid, valid, vacant_d, in_edges, prio):
    """Acceptance with the per-request priorities precomputed — the part
    shared verbatim by the reference path and the fused kernel body
    (kernels/synapse_apply.py), so the float priorities entering both are
    the same values and the decisions are bit-identical."""
    n, s_max = in_edges.shape
    q = tgt_lid.shape[0]
    lid = jnp.where(valid, tgt_lid, n)                  # bucket n = invalid
    order = jnp.lexsort((prio, lid))
    rank_p = positions_within(lid[order], n + 1)
    rank_in_tgt = jnp.zeros((q,), jnp.int32).at[order].set(rank_p)
    lid_c = jnp.clip(lid, 0, n - 1)
    base = counts(in_edges)
    free = s_max - base
    cap = jnp.minimum(jnp.floor(jnp.where(valid, vacant_d[lid_c], 0.0)),
                      free[lid_c].astype(jnp.float32))
    accept = valid & (rank_in_tgt < cap)
    slot = jnp.where(accept, base[lid_c] + rank_in_tgt, s_max)
    new_in = in_edges.at[lid_c, jnp.clip(slot, 0, s_max)].set(
        jnp.where(accept, src_gid, in_edges[lid_c, jnp.clip(slot, 0, s_max - 1)]),
        mode="drop")
    return accept, new_in


def request_priority(key, tgt_lid, src_gid, valid):
    """The keyed per-(src, tgt) acceptance priorities of a request buffer
    (invalid rows draw the (0, 0) stream — never accepted, value ignored)."""
    return edge_priority(key, jnp.where(valid, src_gid, 0),
                         jnp.where(valid, tgt_lid, 0))


def accept_requests(tgt_lid, src_gid, valid, vacant_d, in_edges, key):
    """Targets accept as many requests as they have vacant dendritic elements
    (random subset — paper §III-A(c)); accepted requests are written into
    in_edges (assumed compacted). Returns (accept (Q,) bool, new in_edges).

    Acceptance rank within each target is by keyed (src, tgt) priority —
    ordering-independent (paper: 'accept ... randomly')."""
    prio = request_priority(key, tgt_lid, src_gid, valid)
    return accept_core(tgt_lid, src_gid, valid, vacant_d, in_edges, prio)


def add_out_edges(out_edges, tgt_gid, accept):
    """Write accepted targets into the source neurons' out-edge tables.
    tgt_gid/accept: (n_sources,) — one pending request per source neuron."""
    n, s_max = out_edges.shape
    base = counts(out_edges)
    slot = jnp.where(accept & (base < s_max), base, s_max)
    return out_edges.at[jnp.arange(n), slot].set(
        jnp.where(accept, tgt_gid, -1), mode="drop")


def retract_synapses(key, edges, n_delete, row_gids):
    """Randomly break ``n_delete[i]`` bound synapses of neuron i (paper: 'one
    is chosen randomly'). Priority is keyed by (row gid, edge gid) so the
    choice is independent of slot ordering. Returns (new_edges, kill mask).

    Victims are the ``n_delete[i]`` lowest-priority occupied slots, found by
    rank-by-counting over the (s_max, s_max) pairwise comparisons with
    (priority, slot) lexicographic ties — the exact rank a stable per-row
    argsort would assign (property-tested against that oracle in
    tests/test_connectome.py), without the argsort or its full-table rank
    scatter. O(n * s_max^2) elementwise compares, all fused."""
    n, s_max = edges.shape
    occupied = edges >= 0
    flat_prio = edge_priority(
        key, jnp.broadcast_to(row_gids[:, None], edges.shape).reshape(-1),
        jnp.where(occupied, edges, 0).reshape(-1))
    prio = jnp.where(occupied, flat_prio.reshape(edges.shape), 2.0)
    # rank[i, j] = #{k: (prio[i, k], k) < (prio[i, j], j)} — occupied slots
    # (prio < 1) always rank below the 2.0 pads, exactly as under argsort
    lt = prio[:, :, None] < prio[:, None, :]
    tie = (prio[:, :, None] == prio[:, None, :]) & \
        (jnp.arange(s_max)[:, None] < jnp.arange(s_max)[None, :])
    ranks = jnp.sum(lt | tie, axis=1)
    kill = occupied & (ranks < n_delete[:, None])
    return jnp.where(kill, -1, edges), kill


def remove_edges_by_messages(edges, msg_lid, msg_gid, msg_valid):
    """Remove one occurrence of msg_gid from row msg_lid per message,
    earliest slots first — exactly the sequential drain semantics (each
    message removes the then-first matching slot), but computed in one
    vectorized pass: messages and edge slots are lex-sorted into
    (row, value) groups with messages leading, and an edge slot dies iff
    its occurrence rank within the group is below the group's message
    count (segment ranks via cummax/cumsum)."""
    n, s_max = edges.shape
    q = msg_lid.shape[0]
    e_flat = edges.reshape(-1)
    e_idx = jnp.arange(n * s_max, dtype=jnp.int32)
    # invalid messages bucket at row n, empty slots at row n+1: past every
    # real row, so neither can join a live (row, value) group
    rows = jnp.concatenate([
        jnp.where(msg_valid, msg_lid, n).astype(jnp.int32),
        jnp.where(e_flat >= 0, e_idx // s_max, n + 1)])
    vals = jnp.concatenate([msg_gid.astype(jnp.int32), e_flat])
    is_edge = jnp.concatenate([jnp.zeros((q,), bool),
                               jnp.ones((n * s_max,), bool)])
    slot = jnp.concatenate([jnp.zeros((q,), jnp.int32), e_idx % s_max])
    # (row, value, messages-first, slot order) — stable groups
    order = jnp.lexsort((slot, is_edge.astype(jnp.int32), vals, rows))
    r_s, v_s, e_s = rows[order], vals[order], is_edge[order]
    k = jnp.arange(rows.shape[0])
    newgrp = (k == 0) | (r_s != jnp.roll(r_s, 1)) | (v_s != jnp.roll(v_s, 1))
    start = jax.lax.cummax(jnp.where(newgrp, k, 0))
    is_msg = (~e_s).astype(jnp.int32)
    mcum = jnp.cumsum(is_msg)                 # inclusive message prefix count
    # messages precede edges inside a group, so for an edge item the group's
    # full message count has already accumulated by its position
    m_group = mcum - (mcum[start] - is_msg[start])
    occ_rank = (k - start) - m_group
    kill_sorted = e_s & (occ_rank < m_group)
    kill = jnp.zeros((q + n * s_max,), bool).at[order].set(kill_sorted)
    return jnp.where(kill[q:].reshape(n, s_max), -1, edges)


# ------------------------------------------------------------ apply registry
class ApplyImpl(NamedTuple):
    """One registered implementation of the synapse-apply stages (registry
    domain "apply"). ``deletion`` drains routed retraction messages out of
    one edge table and re-compacts it; ``accept`` admits formation requests
    into the (compacted) in-edge table; ``route`` builds + exchanges the
    per-destination deletion-notification buffers."""
    deletion: Callable   # (edges, msg_lid, msg_gid, msg_valid, interpret=None)
    accept: Callable     # (tgt_lid, src_gid, valid, vacant_d, in_edges, key,
    #                       interpret=None) -> (accept, new_in)
    route: Callable      # (kill, edges, my_gid_col, cfg, axis_name,
    #                       num_ranks, lesions, interpret=None)
    #                       -> (msgs (R*cap, 2), dropped)


def _deletion_reference(edges, msg_lid, msg_gid, msg_valid, interpret=None):
    return compact(remove_edges_by_messages(edges, msg_lid, msg_gid,
                                            msg_valid))


def _accept_reference(tgt_lid, src_gid, valid, vacant_d, in_edges, key,
                      interpret=None):
    return accept_requests(tgt_lid, src_gid, valid, vacant_d, in_edges, key)


def _route_reference(kill, edges, my_gid_col, cfg, axis_name, num_ranks,
                     lesions, interpret=None):
    from repro.connectome import routing  # lazy: routing imports us
    return routing.route_deletions(kill, edges, my_gid_col, cfg, axis_name,
                                   num_ranks, lesions)


def _deletion_fused(edges, msg_lid, msg_gid, msg_valid, interpret=None):
    """Kernel pass with the accept stage disabled (no valid requests): the
    shared core then leaves the table untouched after remove+compact."""
    from repro.kernels import ops as kops  # lazy: kernels import us
    n = edges.shape[0]
    zi = jnp.zeros((8,), jnp.int32)
    new_edges, _ = kops.synapse_apply(
        edges, msg_lid, msg_gid, msg_valid, zi, zi,
        jnp.zeros((8,), bool), jnp.zeros((8,), jnp.float32),
        jnp.zeros((n,), jnp.float32), interpret=interpret)
    return new_edges


def _accept_fused(tgt_lid, src_gid, valid, vacant_d, in_edges, key,
                  interpret=None):
    """Kernel pass with the deletion stage disabled (no valid messages).
    Priorities are drawn OUTSIDE the kernel by the very same
    ``request_priority`` expression the reference uses, so the floats
    entering ``accept_core`` are bit-equal; the table (compacted on entry,
    like the reference assumes) passes through remove+compact unchanged."""
    from repro.kernels import ops as kops  # lazy: kernels import us
    prio = request_priority(key, tgt_lid, src_gid, valid)
    zi = jnp.zeros((8,), jnp.int32)
    new_in, acc = kops.synapse_apply(
        in_edges, zi, zi, jnp.zeros((8,), bool),
        tgt_lid, src_gid, valid, prio, vacant_d, interpret=interpret)
    return acc, new_in


def _route_fused(kill, edges, my_gid_col, cfg, axis_name, num_ranks, lesions,
                 interpret=None):
    from repro.connectome import routing  # lazy: routing imports us
    from repro.kernels import ops as kops  # lazy: kernels import us
    cap = routing.cap_deletions(cfg, lesions)
    flat_other = jnp.where(kill, edges, -1).reshape(-1)
    flat_mine = jnp.broadcast_to(my_gid_col, kill.shape).reshape(-1)
    buf, dropped = kops.route_build(flat_other, flat_mine,
                                    n=cfg.neurons_per_rank,
                                    num_ranks=num_ranks, cap=cap,
                                    interpret=interpret)
    if num_ranks > 1:
        buf = jax.lax.all_to_all(buf, axis_name, 0, 0, tiled=True)
    return buf.reshape(num_ranks * cap, 2), dropped[0]


registry.register_phase("apply", "reference")(
    ApplyImpl(_deletion_reference, _accept_reference, _route_reference))
registry.register_phase("apply", "fused")(
    ApplyImpl(_deletion_fused, _accept_fused, _route_fused))
