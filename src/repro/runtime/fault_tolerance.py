"""Fault-tolerant training runner.

Mechanisms (each individually tested in tests/test_runtime.py):
  * periodic async checkpoints (atomic, keep-k) + resume-from-latest;
  * NaN/Inf-loss rollback: restore last checkpoint, skip the poisoned data
    window, continue (loss-spike protection);
  * simulated preemption (SIGTERM-style flag) -> final checkpoint + clean exit;
  * heartbeat file per step — an external watchdog restarts dead jobs;
  * elastic restart: ``elastic.remesh_restore`` reshards the latest checkpoint
    onto whatever devices survive (see runtime/elastic.py).

Straggler mitigation at this layer (single-controller JAX is bulk-synchronous;
per-step straggler *exclusion* is impossible without re-meshing): bounded data
prefetch + skip-batch on pipeline underrun, and the elastic path doubles as
slow-node ejection — documented in DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import AsyncCheckpointer


def write_heartbeat(path: str, payload: dict):
    """Atomically publish a heartbeat JSON (``payload`` + a ``t``
    timestamp): write a sibling temp file, then ``os.replace`` — readers
    see either the previous heartbeat or the new one, never a torn
    write. Shared by TrainingRunner and runtime.sim_runner."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(dict(payload, t=time.time()), f)
    os.replace(tmp, path)


def read_heartbeat(path: str, max_age_s: Optional[float] = None,
                   now: Optional[float] = None):
    """Watchdog-side read of an atomic heartbeat: returns
    ``(payload, age_s, verdict)`` with verdict one of ``'fresh'``,
    ``'stale'`` (age over ``max_age_s``), ``'missing'`` (no/garbled
    file — a torn write is impossible by construction, so unreadable
    JSON means the process never completed a heartbeat). ``now``
    overrides the clock for tests."""
    try:
        with open(path) as f:
            payload = json.load(f)
        t = float(payload["t"])
    except (OSError, ValueError, KeyError, TypeError):
        return None, None, "missing"
    age = (time.time() if now is None else now) - t
    if max_age_s is not None and age > max_age_s:
        return payload, age, "stale"
    return payload, age, "fresh"


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_rollbacks: int = 3
    heartbeat_path: Optional[str] = None


class TrainingRunner:
    """Wraps a jitted step function with checkpoint/restart + NaN rollback."""

    def __init__(self, cfg: RunnerConfig, step_fn: Callable,
                 params, opt_state, data_iter, shardings=None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data_iter
        self.shardings = shardings
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.step = 0
        self.rollbacks = 0
        self.preempted = False
        self.history = []

    # ---- lifecycle -------------------------------------------------------
    def try_resume(self):
        tree = {"params": self.params, "opt": self.opt_state}
        step, restored, manifest = self.ckpt.restore_latest(
            tree, self.shardings)
        if step is not None:
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = int(manifest["metadata"].get("next_step", step))
            return True
        return False

    def _checkpoint(self):
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       metadata={"next_step": self.step})

    def _heartbeat(self):
        # temp + os.replace: a watchdog polling the file must never see a
        # half-written JSON (plain open(path, "w") is not atomic)
        if self.cfg.heartbeat_path:
            write_heartbeat(self.cfg.heartbeat_path, {"step": self.step})

    def preempt(self):
        """External preemption signal (SIGTERM handler calls this)."""
        self.preempted = True

    # ---- main loop -------------------------------------------------------
    def run(self, num_steps: int, poison_hook: Optional[Callable] = None):
        """poison_hook(step, batch) -> batch lets tests inject NaNs."""
        end = self.step + num_steps
        while self.step < end:
            if self.preempted:
                self._checkpoint()
                self.ckpt.wait()
                return "preempted"
            batch = next(self.data)
            if poison_hook is not None:
                batch = poison_hook(self.step, batch)
            params, opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            if not np.isfinite(loss):
                # rollback: restore last good state; the poisoned batch is
                # consumed (skipped), so training continues past it
                self.rollbacks += 1
                if self.rollbacks > self.cfg.max_rollbacks:
                    raise RuntimeError("too many NaN rollbacks")
                self.ckpt.wait()
                if not self.try_resume():
                    raise RuntimeError("NaN before first checkpoint")
                continue
            self.params, self.opt_state = params, opt_state
            self.step += 1
            self.history.append(loss)
            self._heartbeat()
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint()
        self.ckpt.wait()
        return "done"
