"""Elastic re-meshing: resume a run on a different device count.

Checkpoints store full logical arrays (checkpoint/manager.py), so elasticity
is purely a sharding concern: build the new mesh from surviving devices,
recompute the sharding rules (they depend only on mesh axis sizes), and
device_put each restored array with its new sharding. Batch sizes stay global
(the data pipeline reshards rows by (seed, step, row) identity, so the token
stream is unchanged).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.manager import latest_step, restore
from repro.parallel import sharding as shd


def best_mesh_shape(n_devices: int, model_parallel: int = 0):
    """Factor n_devices into (data, model); model defaults to the largest
    power of two <= sqrt(n)."""
    if model_parallel <= 0:
        model_parallel = 1
        while model_parallel * 2 <= int(math.sqrt(n_devices)) and \
                n_devices % (model_parallel * 2) == 0:
            model_parallel *= 2
    assert n_devices % model_parallel == 0
    return (n_devices // model_parallel, model_parallel)


def make_elastic_mesh(devices=None, model_parallel: int = 0) -> Mesh:
    devs = jax.devices() if devices is None else devices
    da, mo = best_mesh_shape(len(devs), model_parallel)
    return Mesh(np.array(devs).reshape(da, mo), ("data", "model"))


def remesh_restore(ckpt_dir: str, target_tree, new_mesh: Mesh):
    """Load latest checkpoint and reshard every leaf onto ``new_mesh``."""
    step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    shardings = {
        "params": shd.make_param_shardings(
            jax.eval_shape(lambda t: t, target_tree["params"]), new_mesh),
        "opt": {
            "m": shd.make_param_shardings(
                jax.eval_shape(lambda t: t, target_tree["opt"]["m"]),
                new_mesh, opt_state=True),
            "v": shd.make_param_shardings(
                jax.eval_shape(lambda t: t, target_tree["opt"]["v"]),
                new_mesh, opt_state=True),
            "step": shd.replicated(new_mesh),
        },
    }
    tree, manifest = restore(ckpt_dir, step, target_tree, shardings)
    return step, tree, shardings


# ===================================================================== brain
def _latest_valid(ckpt_dir: str):
    """Newest step whose arrays pass verification, with its contents."""
    from repro.checkpoint import manager
    for step in reversed(manager.steps_available(ckpt_dir)):
        try:
            arrays, manifest = manager.load_arrays(ckpt_dir, step)
        except manager.CorruptCheckpointError:
            continue
        return step, arrays, manifest
    raise FileNotFoundError(f"no valid brain checkpoint in {ckpt_dir}")


def _collapse_ranks(key: str, arr: np.ndarray, r_old: int,
                    r_new: int) -> np.ndarray:
    """Fold a per-rank (R_old, ...) metrics leaf down to (R_new, ...):
    counters/rings/hists sum within each merged rank group (global sums —
    including the conservation-check inputs — are preserved); the psum'd
    ``health_flags`` gauge is a replicated bitmask and folds with max."""
    grouped = arr.reshape(r_new, r_old // r_new, *arr.shape[1:])
    if key.endswith("health_flags"):
        return np.asarray(grouped.max(axis=1))
    return np.asarray(grouped.sum(axis=1))


def remesh_restore_brain(ckpt_dir: str, cfg, mesh=None, step=None,
                         scenario=None, profile_dir=None):
    """Restore a brain checkpoint onto a Simulator built for ``cfg`` —
    possibly with a different rank count or exchange layout than the
    writer's. Returns ``(sim, step)``.

    Why this works (DESIGN.md §10): checkpoints store full logical arrays
    in gid order, and ``gid == global row index`` is invariant under
    re-meshing (gid = rank*n + lid with ranks owning consecutive rows), so
    the per-neuron state, positions, and the gid-valued edge tables pass
    through unchanged. The Morton domain decomposition of the new rank
    count covers the same contiguous cell span per merged rank group
    whenever ``R_new`` divides ``R_old`` (8^b' / R' cells starting at
    r'*8^b'/R' == the union of the old ranks' spans), so every neuron
    stays inside its owner's subdomain — the invariant the octree build
    needs. Growing the rank count would SPLIT ranks, and neuron order
    within a rank is not Morton-sorted, so growth is rejected.

    The rank-local exchange state is not resharded but re-derived: the
    dense (R, n) table is the gathered rate vector (reshape), and the
    sparse subscription registry / slot remap / rate buffer are rebuilt
    device-side by ``Simulator.rebuild_exchange`` — the same computation
    the chunk's exchange phase runs, hence bit-identical at a chunk
    boundary. Metrics leaves fold per merged rank group (sum; flags max).
    """
    from repro.checkpoint import manager
    from repro.core import spikes as core_spikes
    from repro.sim.api import Simulator

    if step is None:
        step, arrays, manifest = _latest_valid(ckpt_dir)
    else:
        arrays, manifest = manager.load_arrays(ckpt_dir, step)
    meta = manifest.get("metadata", {})

    sim = Simulator(cfg, scenario=scenario, mesh=mesh,
                    profile_dir=profile_dir)
    r_new, n_new = sim.num_ranks, cfg.neurons_per_rank
    n_total = arrays[".positions"].shape[0]
    r_old = int(meta.get("num_ranks",
                         n_total // int(meta.get("neurons_per_rank", n_new))))
    if r_new * n_new != n_total:
        raise ValueError(
            f"checkpoint holds {n_total} neurons; cfg gives "
            f"{r_new} ranks x {n_new} = {r_new * n_new}")
    if r_old % r_new != 0:
        raise ValueError(
            f"elastic brain resume requires the new rank count to divide "
            f"the old ({r_old} -> {r_new}): growing splits ranks whose "
            f"neurons are not Morton-sorted")

    target_leaves, treedef = manager._flatten(jax.eval_shape(sim.init_fn))
    shard_leaves, _ = manager._flatten(sim.shardings())
    out = []
    for i, (key, leaf) in enumerate(target_leaves):
        if key == ".rates_table":
            if ".rates_table" in arrays:           # dense -> dense
                arr = arrays[key].reshape(leaf.shape)
            else:                                   # sparse -> dense
                arr = arrays[".neurons/.rate"].reshape(leaf.shape)
        elif key == ".subs":
            arr = np.full(leaf.shape, int(core_spikes.NO_SUB), np.int32)
        elif key == ".rate_slots":
            arr = np.full(leaf.shape, -1, np.int32)
        elif key == ".remote_rates":
            arr = np.zeros(leaf.shape, np.float32)
        elif key.startswith(".stats/"):
            arr = _collapse_ranks(key, arrays[key], r_old, r_new)
        else:
            arr = arrays.get(key)
            if arr is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(
                    f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jax.device_put(np.asarray(arr), shard_leaves[i][1]))
    sim._state = jax.tree_util.tree_unflatten(treedef, out)
    # re-derive the sparse registry for THIS rank count (no-op for dense)
    sim.rebuild_exchange()
    sim.lifecycle.update({k: int(v) for k, v in
                          meta.get("lifecycle", {}).items()})
    sim.lifecycle["checkpoint_restores"] += 1
    return sim, step
