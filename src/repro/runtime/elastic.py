"""Elastic re-meshing: resume a run on a different device count.

Checkpoints store full logical arrays (checkpoint/manager.py), so elasticity
is purely a sharding concern: build the new mesh from surviving devices,
recompute the sharding rules (they depend only on mesh axis sizes), and
device_put each restored array with its new sharding. Batch sizes stay global
(the data pipeline reshards rows by (seed, step, row) identity, so the token
stream is unchanged).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.manager import latest_step, restore
from repro.parallel import sharding as shd


def best_mesh_shape(n_devices: int, model_parallel: int = 0):
    """Factor n_devices into (data, model); model defaults to the largest
    power of two <= sqrt(n)."""
    if model_parallel <= 0:
        model_parallel = 1
        while model_parallel * 2 <= int(math.sqrt(n_devices)) and \
                n_devices % (model_parallel * 2) == 0:
            model_parallel *= 2
    assert n_devices % model_parallel == 0
    return (n_devices // model_parallel, model_parallel)


def make_elastic_mesh(devices=None, model_parallel: int = 0) -> Mesh:
    devs = jax.devices() if devices is None else devices
    da, mo = best_mesh_shape(len(devs), model_parallel)
    return Mesh(np.array(devs).reshape(da, mo), ("data", "model"))


def remesh_restore(ckpt_dir: str, target_tree, new_mesh: Mesh):
    """Load latest checkpoint and reshard every leaf onto ``new_mesh``."""
    step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    shardings = {
        "params": shd.make_param_shardings(
            jax.eval_shape(lambda t: t, target_tree["params"]), new_mesh),
        "opt": {
            "m": shd.make_param_shardings(
                jax.eval_shape(lambda t: t, target_tree["opt"]["m"]),
                new_mesh, opt_state=True),
            "v": shd.make_param_shardings(
                jax.eval_shape(lambda t: t, target_tree["opt"]["v"]),
                new_mesh, opt_state=True),
            "step": shd.replicated(new_mesh),
        },
    }
    tree, manifest = restore(ckpt_dir, step, target_tree, shardings)
    return step, tree, shardings
