"""Chaos harness: deterministic fault injection for the fault-tolerant
runtime (DESIGN.md §10; driven by tests/test_runtime.py and
benchmarks/bench_runner.py).

Hooks attach to ``SimulationRunner.chaos_hooks`` and fire after every
completed segment, before the runner's health poll / checkpoint — the
same window a real fault would occupy. Each injector fires a bounded
number of times from a deterministic trigger (a chunk threshold), so
recovery tests are exactly reproducible:

  * ``poison_nan_once``     flip one element of a state field to NaN
                            (device-state corruption -> rollback);
  * ``preempt_after``       raise the runner's preemption flag
                            (SIGTERM drain -> final checkpoint + exit);
  * ``corrupt_checkpoint``  truncate / bit-flip / unlink pieces of an
                            on-disk checkpoint (the crc32 + typed-error
                            path: restores must skip to an older step);
  * ``drop_region_input``    zero one region's external drive for k
                            chunks (hook for the assimilation loop in
                            ``workloads.assimilate`` — its controller
                            must recover the target rate);
  * overflow pressure has no injector — build the config with a shrunken
    ``subs_cap_factor``/``requests_cap_factor`` (e.g. ``overflow_config``)
    and the exchange itself generates the persistent overflow that drives
    the degradation ladder.

Slot-targeted injectors attack a single tenant of the multi-tenant
service (``SimulationService.chaos_hooks``, fired after each tick's
step, before the health read):

  * ``poison_slot_nan``       NaN one element of ONE slot's lane — the
                              fault-isolation attack (co-tenants must
                              stay bit-identical to solo runs);
  * ``stall_slot``            freeze one slot's credited progress for N
                              ticks (the stall-watchdog attack);
  * ``overflow_slot_config``  mutate one request's chunk budget past the
                              admission cap (typed-rejection attack).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import numpy as np


def poison_nan_once(field: str = "v", index: int = 0,
                    after_chunk: int = 0):
    """Hook: once the state reaches ``after_chunk``, overwrite one
    element of ``state.neurons.<field>`` (or ``positions``) with NaN —
    exactly once. The runner's pre-checkpoint probe (or the next scan's
    in-chunk verdict) must flag HEALTH_NONFINITE and roll back."""
    fired = {"done": False}

    def hook(runner):
        if fired["done"]:
            return
        st = runner.sim.state
        if int(jax.device_get(st.chunk)) < after_chunk:
            return
        fired["done"] = True
        if field == "positions":
            leaf, put = st.positions, \
                lambda a: st._replace(positions=a)
        else:
            leaf = getattr(st.neurons, field)
            put = lambda a: st._replace(
                neurons=st.neurons._replace(**{field: a}))
        arr = np.array(jax.device_get(leaf))   # writable copy
        arr.reshape(-1)[index] = np.nan
        runner.sim._state = put(jax.device_put(arr, leaf.sharding))

    return hook


def preempt_after(chunk: int):
    """Hook: raise the preemption flag once the state reaches ``chunk``
    — the runner drains (final checkpoint) and returns "preempted"."""
    def hook(runner):
        if int(jax.device_get(runner.sim.state.chunk)) >= chunk:
            runner.preempt()

    return hook


def corrupt_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       mode: str = "flip"):
    """Damage the on-disk checkpoint at ``step`` (default: newest).
    ``mode``: 'flip' xors a byte in the middle of the first leaf file,
    'truncate' halves it, 'manifest' truncates manifest.json. Every mode
    must surface as ``CorruptCheckpointError`` on restore."""
    from repro.checkpoint import manager
    if step is None:
        step = manager.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    if mode == "manifest":
        mpath = os.path.join(path, "manifest.json")
        with open(mpath, "r+b") as f:
            f.truncate(max(os.path.getsize(mpath) // 2, 1))
        return step
    leaf = sorted(f for f in os.listdir(path) if f.endswith(".npy"))[0]
    lpath = os.path.join(path, leaf)
    if mode == "truncate":
        with open(lpath, "r+b") as f:
            f.truncate(max(os.path.getsize(lpath) // 2, 1))
    elif mode == "flip":
        with open(lpath, "r+b") as f:
            f.seek(os.path.getsize(lpath) // 2 + 64)
            b = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([b[0] ^ 0xFF]))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return step


def poison_slot_nan(slot: int, field: str = "v", index: int = 0,
                    after_chunk: int = 0):
    """Service hook: once slot ``slot``'s chunk counter reaches
    ``after_chunk``, overwrite one element of that lane's
    ``neurons.<field>`` (or ``positions``) with NaN — exactly once. Only
    lane ``slot`` is touched: the service must quarantine/roll back that
    slot while every co-tenant stays bit-identical to a solo run."""
    fired = {"done": False}

    def hook(service):
        if fired["done"]:
            return
        st = service.state
        if int(service.batch.chunks(st)[slot]) < after_chunk:
            return
        fired["done"] = True
        if field == "positions":
            leaf, put = st.positions, \
                lambda a: st._replace(positions=a)
        else:
            leaf = getattr(st.neurons, field)
            put = lambda a: st._replace(
                neurons=st.neurons._replace(**{field: a}))
        arr = np.array(jax.device_get(leaf))   # (B, ...) writable copy
        arr[slot].reshape(-1)[index] = np.nan
        service.state = put(jax.device_put(arr, leaf.sharding))

    return hook


def stall_slot(slot: int, ticks: int = 4, after_tick: int = 0):
    """Service hook: once the service reaches ``after_tick``, freeze slot
    ``slot``'s credited progress for ``ticks`` ticks — exactly once. The
    stall watchdog must quarantine (and eventually evict) only that
    slot."""
    fired = {"done": False}

    def hook(service):
        if fired["done"] or service.tick_count < after_tick:
            return
        fired["done"] = True
        service.slots[slot].stall_ticks = ticks

    return hook


def overflow_slot_config(request, max_chunks_per_request: int):
    """A copy of ``request`` whose chunk budget exceeds the service's
    admission cap — submitting it must raise the typed
    ``IncompatibleRequest``, never enqueue (the single-tenant overflow
    attack on admission control)."""
    return dataclasses.replace(request,
                               chunks=max_chunks_per_request + 1)


def drop_region_input(region, chunks: int = 2, after_chunk: int = 0):
    """Assimilation-loop hook: once the loop reaches ``after_chunk``,
    zero ``region``'s external background drive for ``chunks`` chunks —
    exactly once (``workloads.assimilate.AssimilationLoop.drop``). The
    controller must detect the rate collapse and wind the drive back up
    after the drop window closes (the recovery test in
    tests/test_workloads.py)."""
    fired = {"done": False}

    def hook(loop):
        if fired["done"] or loop.chunk_index < after_chunk:
            return
        fired["done"] = True
        loop.drop(region, chunks)

    return hook


def overflow_config(cfg, subs_cap_factor: float = 0.0001,
                    requests_cap_factor: Optional[float] = None):
    """A copy of ``cfg`` with the sparse-exchange subscription cap (and
    optionally the request routing cap) shrunk to the floor, so the
    registry overflows every chunk — the pressure source for the
    runner's degradation ladder."""
    kw = {"subs_cap_factor": subs_cap_factor}
    if requests_cap_factor is not None:
        kw["requests_cap_factor"] = requests_cap_factor
    return dataclasses.replace(cfg, **kw)
