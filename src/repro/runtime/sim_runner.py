"""Fault-tolerant simulation runner: ``SimulationRunner`` wraps
``Simulator.run`` with the full run lifecycle (DESIGN.md §10).

Mechanisms:
  * periodic async atomic keep-k checkpoints (``AsyncCheckpointer``) at
    chunk boundaries + resume-from-latest — the counter-keyed randomness
    contract (seed, chunk, per-step hash) makes kill-and-resume at any
    chunk boundary bit-identical to an uninterrupted run;
  * device-side health verdict: the jitted scan refreshes the health
    gauges every chunk (``sim.phases.health_verdict``); the runner polls
    the psum'd ``health_flags`` bitmask each checkpoint interval (a
    four-scalar transfer), and additionally *probes* the exact state it
    is about to save — every checkpoint on disk is verified-good, so a
    rollback target is never itself poisoned;
  * bounded rollback: on a bad verdict, restore the newest checkpoint
    that passes checksum + structure verification (walking past corrupt
    steps) and re-run; more than ``max_rollbacks`` raises;
  * graceful degradation: persistent ``subscription_overflow`` /
    ``request_overflow`` across ``overflow_patience`` intervals
    re-materializes the Simulator through the elastic restore path with a
    grown ``subs_cap_factor`` (then falls back to ``rate_exchange=
    'dense'``), or a grown ``requests_cap_factor`` — each escalation is a
    ``runner.degrade`` span and a ``degrade_events`` counter;
  * SIGTERM-style preemption draining: ``preempt()`` (signal-handler
    safe) makes the loop write a final checkpoint and return
    ``"preempted"`` at the next chunk boundary;
  * atomic heartbeat JSON per interval (``fault_tolerance
    .write_heartbeat``) for an external watchdog, with an optional
    self-check of the previous beat's age (``fault_tolerance
    .read_heartbeat``) surfacing a ``heartbeat_stale`` lifecycle count;
  * elastic resume: a fresh runner whose cfg disagrees with the
    checkpoint metadata (rank count after shrinking the job, exchange
    layout or caps after a degrade) routes through
    ``elastic.remesh_restore_brain`` instead of a direct reshard.

Lifecycle counters (``checkpoint_saves``/``checkpoint_restores``/
``rollbacks``/``restarts``/``degrade_events``) live on the Simulator and
surface through ``Simulator.stats()`` and the ``repro.telemetry/v1``
report. Fault injection for all of the above lives in ``runtime.chaos``;
deterministic recovery tests in tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax

from repro import telemetry
from repro.checkpoint import manager
from repro.checkpoint.manager import AsyncCheckpointer
from repro.runtime import elastic
from repro.runtime.fault_tolerance import read_heartbeat, write_heartbeat


@dataclasses.dataclass
class SimRunnerConfig:
    """Runner knobs. ``ckpt_every`` is in chunks (one chunk = Delta
    activity steps + one connectivity update); a smaller value narrows
    the re-run window after a fault at the cost of checkpoint I/O."""
    ckpt_dir: str
    ckpt_every: int = 10
    keep: int = 3
    max_rollbacks: int = 3
    heartbeat_path: Optional[str] = None
    # previous-beat age (s) beyond which the runner records a
    # heartbeat_stale lifecycle event before publishing a fresh beat —
    # the in-band echo of the external watchdog's verdict
    heartbeat_max_age_s: Optional[float] = None
    # degradation ladder
    max_degrades: int = 2
    overflow_patience: int = 2     # consecutive overflowing intervals
    # achieved-cap multiplier per escalation; 0 disables growth so the
    # first subscription-overflow escalation falls straight back to dense
    subs_growth_factor: int = 4
    requests_growth_factor: int = 4


# metadata keys that must agree for a direct (non-elastic) resume
_SHAPE_KEYS = ("num_ranks", "neurons_per_rank", "rate_exchange",
               "subs_cap_factor", "requests_cap_factor")


class SimulationRunner:
    """Drive a Simulator to a target chunk count, surviving preemption,
    state corruption, checkpoint corruption, and exchange-capacity
    exhaustion.

    >>> runner = SimulationRunner(SimRunnerConfig(ckpt_dir), cfg)
    >>> runner.run(100)      # resumes from ckpt_dir if checkpoints exist
    'done'
    """

    def __init__(self, run_cfg: SimRunnerConfig, cfg=None, sim=None,
                 scenario=None, mesh=None, resume: bool = True):
        from repro.sim.api import Simulator
        if (cfg is None) == (sim is None):
            raise ValueError("pass exactly one of cfg= or sim=")
        self.cfg = run_cfg
        self.scenario = scenario if sim is None else sim.scenario
        self.mesh_arg = mesh
        self.sim = sim if sim is not None else Simulator(
            cfg, scenario=scenario, mesh=mesh)
        self.ckpt = AsyncCheckpointer(run_cfg.ckpt_dir, keep=run_cfg.keep)
        self.preempted = False
        self.degrades = 0
        self._overflow_strikes = 0
        self._last_saved_chunk: Optional[int] = None
        # chaos hooks: callables(runner) invoked after every segment,
        # BEFORE the health poll/checkpoint — see runtime.chaos
        self.chaos_hooks: List[Callable] = []
        if resume:
            self.try_resume()

    # ---------------------------------------------------------- resume
    def _latest_valid_manifest(self):
        self.ckpt.wait()
        for step in reversed(manager.steps_available(self.cfg.ckpt_dir)):
            try:
                arrays, manifest = manager.load_arrays(self.cfg.ckpt_dir,
                                                       step)
            except manager.CorruptCheckpointError:
                continue
            return step, manifest
        return None, None

    def try_resume(self) -> bool:
        """Adopt the newest valid checkpoint, if any. Shape-compatible
        checkpoints reshard directly onto the runner's mesh; anything
        else (different rank count, exchange layout, or caps) goes
        through the elastic restore, which re-derives rank-local
        sharding and rebuilds the subscription registry."""
        step, manifest = self._latest_valid_manifest()
        if step is None:
            return False
        meta = manifest.get("metadata", {})
        mine = self.sim.ckpt_metadata()
        direct = all(meta.get(k) == mine[k] for k in _SHAPE_KEYS)
        with telemetry.span("runner.restore", step=step,
                            elastic=not direct):
            if direct:
                self.sim.restore(self.cfg.ckpt_dir, step)
                self.sim.lifecycle.update(
                    {k: int(v)
                     for k, v in meta.get("lifecycle", {}).items()})
                self.sim.lifecycle["checkpoint_restores"] += 1
            else:
                self.sim, step = elastic.remesh_restore_brain(
                    self.cfg.ckpt_dir, self.sim.cfg, mesh=self.mesh_arg,
                    step=step, scenario=self.scenario)
        self.sim.lifecycle["restarts"] += 1
        self._last_saved_chunk = step
        return True

    # ------------------------------------------------------- checkpoint
    def _checkpoint(self) -> bool:
        """Probe the current state and, if healthy, save it (async,
        atomic, keep-k). Returns False — save REFUSED — when the probe
        flags corruption, so a poisoned state can never become a
        rollback target."""
        if self.sim.probe_health() != 0:
            return False
        step = int(jax.device_get(self.sim.state.chunk))
        with telemetry.span("runner.checkpoint_save", step=step):
            self.ckpt.save(step, self.sim.state,
                           metadata=dict(self.sim.ckpt_metadata(),
                                         chunk=step))
        self.sim.lifecycle["checkpoint_saves"] += 1
        self._last_saved_chunk = step
        return True

    def _rollback(self):
        """Restore the newest checkpoint that verifies AND matches the
        current state structure (post-degrade runners skip pre-degrade
        shapes), bounded by ``max_rollbacks``."""
        self.sim.lifecycle["rollbacks"] += 1
        if self.sim.lifecycle["rollbacks"] > self.cfg.max_rollbacks:
            raise RuntimeError(
                f"giving up after {self.cfg.max_rollbacks} rollbacks")
        self.ckpt.wait()
        target = jax.eval_shape(self.sim.init_fn)
        shardings = self.sim.shardings()
        with telemetry.span("runner.rollback"):
            for step in reversed(
                    manager.steps_available(self.cfg.ckpt_dir)):
                try:
                    tree, _ = manager.restore(self.cfg.ckpt_dir, step,
                                              target, shardings)
                except (manager.CorruptCheckpointError, KeyError,
                        ValueError):
                    continue
                self.sim._state = tree
                self.sim.lifecycle["checkpoint_restores"] += 1
                if self.sim.probe_health() == 0:
                    return step
        raise RuntimeError("no healthy checkpoint to roll back to")

    # ---------------------------------------------------------- degrade
    def _maybe_degrade(self, stats_before: dict, stats_after: dict):
        """Escalate when the exchange keeps overflowing: every dropped
        subscription/request this interval counts a strike; after
        ``overflow_patience`` consecutive strikes, re-materialize the
        Simulator one rung down the ladder (grown sparse caps -> dense
        fallback / grown request caps) via the elastic restore at the
        same rank count."""
        keys = ("subscription_overflow", "request_overflow")
        delta = {k: stats_after[k] - stats_before[k] for k in keys}
        if not any(v > 0 for v in delta.values()):
            self._overflow_strikes = 0
            return
        self._overflow_strikes += 1
        if self._overflow_strikes < self.cfg.overflow_patience:
            return
        self._overflow_strikes = 0
        if self.degrades >= self.cfg.max_degrades:
            return
        from repro.connectome import routing
        cfg = self.sim.cfg
        if cfg.rate_exchange == "sparse" and \
                delta["subscription_overflow"] > 0:
            # grow the ACHIEVED cap (cap_subs floors/ceils the factor),
            # not the raw factor: pick the smallest integer factor whose
            # cap is >= growth x the current cap
            cap_old = routing.cap_subs(cfg, self.sim.num_ranks)
            denom = routing.subs_base(cfg, self.sim.num_ranks)
            new_factor = -(-cap_old * self.cfg.subs_growth_factor
                           // denom)
            new_cfg = dataclasses.replace(cfg,
                                          subs_cap_factor=int(new_factor))
            if routing.cap_subs(new_cfg, self.sim.num_ranks) <= cap_old:
                # cap already at its hard ceiling (or growth disabled):
                # last rung — the dense reference layout never overflows
                new_cfg = dataclasses.replace(cfg, rate_exchange="dense")
                action = "dense_fallback"
            else:
                action = "grow_subs_cap"
        else:
            new_cfg = dataclasses.replace(
                cfg, requests_cap_factor=(cfg.requests_cap_factor
                                          * self.cfg.requests_growth_factor))
            action = "grow_requests_cap"
        # checkpoint the (healthy) current state so the elastic path has
        # a boundary to restore from, then swap in the re-materialized
        # Simulator and checkpoint again under the NEW shapes so later
        # rollbacks stay structure-compatible
        if not self._checkpoint():
            return    # poisoned right now: let the health path roll back
        self.ckpt.wait()
        with telemetry.span("runner.degrade", action=action,
                            chunk=self._last_saved_chunk):
            self.sim, _ = elastic.remesh_restore_brain(
                self.cfg.ckpt_dir, new_cfg, mesh=self.mesh_arg,
                step=self._last_saved_chunk, scenario=self.scenario)
        self.degrades += 1
        self.sim.lifecycle["degrade_events"] += 1
        self._checkpoint()

    # ------------------------------------------------------------- misc
    def preempt(self):
        """External preemption signal (a SIGTERM handler calls this);
        the loop drains at the next chunk boundary."""
        self.preempted = True

    def _heartbeat(self, chunk: int):
        if self.cfg.heartbeat_path:
            # staleness self-check: if the previous beat aged past the
            # watchdog threshold, the interval overran — record it as a
            # lifecycle event (the in-band echo of read_heartbeat's
            # 'stale' verdict) before publishing the fresh beat
            if self.cfg.heartbeat_max_age_s is not None:
                _, _, verdict = read_heartbeat(
                    self.cfg.heartbeat_path,
                    max_age_s=self.cfg.heartbeat_max_age_s)
                if verdict == "stale":
                    self.sim.lifecycle["heartbeat_stale"] += 1
            write_heartbeat(self.cfg.heartbeat_path,
                            {"chunk": chunk,
                             "lifecycle": dict(self.sim.lifecycle)})

    # -------------------------------------------------------- main loop
    def run(self, num_chunks: int) -> str:
        """Advance ``num_chunks`` chunks past the CURRENT chunk (resumed
        runs count from where the checkpoint left off... i.e. a fresh
        runner resumed at chunk j with run(k-j) lands exactly on chunk
        k). Returns "done" or "preempted"."""
        end = int(jax.device_get(self.sim.state.chunk)) + int(num_chunks)
        if self._last_saved_chunk is None:
            # an initial verified checkpoint: rollback always has a target
            if not self._checkpoint():
                raise RuntimeError("initial state is unhealthy")
        while True:
            cur = int(jax.device_get(self.sim.state.chunk))
            if self.preempted:
                self._checkpoint()
                self.ckpt.wait()
                return "preempted"
            if cur >= end:
                break
            stats_before = self.sim.stats()
            self.sim.run(min(self.cfg.ckpt_every, end - cur))
            for hook in list(self.chaos_hooks):
                hook(self)
            cur = int(jax.device_get(self.sim.state.chunk))
            self._heartbeat(cur)
            # cheap per-interval poll of the in-scan verdict
            if self.sim.health()["health_flags"] != 0:
                self._rollback()
                continue
            self._maybe_degrade(stats_before, self.sim.stats())
            if not self._checkpoint():
                # state was poisoned between the scan and the save
                self._rollback()
        if self._last_saved_chunk != int(
                jax.device_get(self.sim.state.chunk)):
            self._checkpoint()
        self.ckpt.wait()
        return "done"
