# The paper's primary contribution: MSP structural-plasticity simulation with
# the location-aware Barnes-Hut connectivity update ("move computation instead
# of data") and the Delta-periodic firing-rate spike approximation.
from repro.core import (barnes_hut, connectivity, engine, morton, neuron,
                        octree, spikes)
