# The paper's primary contribution: MSP structural-plasticity simulation with
# the location-aware Barnes-Hut connectivity update ("move computation instead
# of data") and the Delta-periodic firing-rate spike approximation.
#
# Submodules are imported on demand (`from repro.core import engine`), not
# eagerly: the connectivity update lives in repro.connectome (PR 3) and the
# compat shims here (barnes_hut/connectivity/octree) import back from it —
# eager imports would make package initialization order-dependent.
