"""MSP phase 1+2: electrical activity (Izhikevich), calcium trace, and
synaptic-element growth (paper §III-A; parameters from §V-D).

All update rules are written against ``NeuronParams`` — either the scalar
BrainConfig constants (legacy homogeneous sheet) or per-neuron ``(n,)``
arrays compiled from a scenario's population table
(repro.scenarios.populations). Scalars and arrays trace to bitwise-identical
programs when the values agree, so the default path reproduces the seed
simulation exactly.

``alive`` is the scenario lesion mask (None when no protocol): dead neurons
hold their membrane at the reset potential, never spike, stop accumulating
calcium, and have their synaptic elements forced to zero — which makes the
connectivity phase retract every synapse they own.

NOTE: the engine's activity phase no longer calls ``update_activity`` /
``update_elements`` step by step — their math was absorbed (verbatim) into
``repro.kernels.activity_fused.step_core``, the single per-step function
shared by the reference scan and the fused Pallas megakernel (DESIGN.md
§5). The functions here remain the standalone, documented form of the
model (used by ``kernels/ref.neuron_step_ref`` and the kernel tests);
``init_neurons`` and ``refresh_rate`` are still the engine entry points.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs.msp_brain import BrainConfig

Param = Union[float, jnp.ndarray]   # scalar constant or per-neuron (n,)


class NeuronParams(NamedTuple):
    """Izhikevich + plasticity constants, scalar or per-neuron (n,)."""
    izh_a: Param
    izh_b: Param
    izh_c: Param
    izh_d: Param
    growth_rate: Param       # nu
    target_calcium: Param    # epsilon


def params_from_config(cfg: BrainConfig) -> NeuronParams:
    return NeuronParams(cfg.izh_a, cfg.izh_b, cfg.izh_c, cfg.izh_d,
                        cfg.element_growth_rate, cfg.target_calcium)


class NeuronState(NamedTuple):
    v: jnp.ndarray          # (n,) membrane potential
    u: jnp.ndarray          # (n,) recovery variable
    calcium: jnp.ndarray    # (n,) intracellular calcium (activity trace)
    ax_elements: jnp.ndarray   # (n,) axonal synaptic elements (continuous)
    de_elements: jnp.ndarray   # (n,) dendritic synaptic elements
    spiked: jnp.ndarray     # (n,) bool — fired in the *last* step
    spike_count: jnp.ndarray   # (n,) spikes in the current rate window
    rate: jnp.ndarray       # (n,) advertised firing rate (new algorithm)
    is_excitatory: jnp.ndarray  # (n,) bool


def init_neurons(key, cfg: BrainConfig, n: int,
                 params: Optional[NeuronParams] = None,
                 is_excitatory=None) -> NeuronState:
    p = params or params_from_config(cfg)
    k1, k2 = jax.random.split(key)
    vac = jax.random.uniform(k1, (n, 2), minval=cfg.initial_vacant_low,
                             maxval=cfg.initial_vacant_high)
    exc = jnp.arange(n) < int(n * cfg.fraction_excitatory) \
        if is_excitatory is None else is_excitatory
    return NeuronState(
        v=jnp.broadcast_to(jnp.asarray(p.izh_c, jnp.float32), (n,)),
        u=jnp.broadcast_to(jnp.asarray(p.izh_b * p.izh_c, jnp.float32), (n,)),
        calcium=jnp.zeros((n,), jnp.float32),
        ax_elements=vac[:, 0], de_elements=vac[:, 1],
        spiked=jnp.zeros((n,), bool),
        spike_count=jnp.zeros((n,), jnp.float32),
        rate=jnp.zeros((n,), jnp.float32),
        is_excitatory=exc)


def izhikevich_step(st: NeuronState, syn_input, noise, cfg: BrainConfig,
                    params: Optional[NeuronParams] = None):
    """One 1 ms step (two 0.5 ms Euler halves for stability, as in the
    reference Izhikevich implementation)."""
    p = params or params_from_config(cfg)
    i_t = syn_input + noise
    v, u = st.v, st.u
    for _ in range(2):
        v = v + 0.5 * (0.04 * v * v + 5.0 * v + 140.0 - u + i_t)
    u = u + p.izh_a * (p.izh_b * v - u)
    spiked = v >= 30.0
    v = jnp.where(spiked, p.izh_c, v)
    u = jnp.where(spiked, u + p.izh_d, u)
    return v, u, spiked


def update_activity(st: NeuronState, syn_input, noise, cfg: BrainConfig,
                    params: Optional[NeuronParams] = None,
                    alive=None) -> NeuronState:
    p = params or params_from_config(cfg)
    v, u, spiked = izhikevich_step(st, syn_input, noise, cfg, p)
    if alive is not None:
        spiked = spiked & alive
        # dead neurons sit at the reset potential, frozen
        v = jnp.where(alive, v, jnp.broadcast_to(
            jnp.asarray(p.izh_c, jnp.float32), v.shape))
        u = jnp.where(alive, u, st.u)
    calcium = st.calcium + (-st.calcium * cfg.calcium_decay
                            + cfg.calcium_beta * spiked)
    return st._replace(v=v, u=u, spiked=spiked, calcium=calcium,
                       spike_count=st.spike_count + spiked)


def update_elements(st: NeuronState, cfg: BrainConfig,
                    params: Optional[NeuronParams] = None,
                    alive=None) -> NeuronState:
    """Homeostasis: grow elements below target calcium, retract above
    (paper §III-A(b); linear rule with nu = element_growth_rate). Lesioned
    neurons lose all elements (-> full synapse retraction next update)."""
    p = params or params_from_config(cfg)
    drive = 1.0 - st.calcium / p.target_calcium
    grow = p.growth_rate * drive
    ax = jnp.maximum(st.ax_elements + grow, 0.0)
    de = jnp.maximum(st.de_elements + grow, 0.0)
    if alive is not None:
        ax = jnp.where(alive, ax, 0.0)
        de = jnp.where(alive, de, 0.0)
    return st._replace(ax_elements=ax, de_elements=de)


def refresh_rate(st: NeuronState, cfg: BrainConfig, alive=None) -> NeuronState:
    """Close a rate window: advertised rate = spikes / Delta (new algorithm).
    Dead neurons advertise zero (their pre-death spikes in this window must
    not be replayed by remote PRNG reconstruction)."""
    rate = st.spike_count / cfg.rate_period
    if alive is not None:
        rate = jnp.where(alive, rate, 0.0)
    return st._replace(rate=rate, spike_count=jnp.zeros_like(st.spike_count))
