"""MSP phase 1+2: electrical activity (Izhikevich), calcium trace, and
synaptic-element growth (paper §III-A; parameters from §V-D)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.msp_brain import BrainConfig


class NeuronState(NamedTuple):
    v: jnp.ndarray          # (n,) membrane potential
    u: jnp.ndarray          # (n,) recovery variable
    calcium: jnp.ndarray    # (n,) intracellular calcium (activity trace)
    ax_elements: jnp.ndarray   # (n,) axonal synaptic elements (continuous)
    de_elements: jnp.ndarray   # (n,) dendritic synaptic elements
    spiked: jnp.ndarray     # (n,) bool — fired in the *last* step
    spike_count: jnp.ndarray   # (n,) spikes in the current rate window
    rate: jnp.ndarray       # (n,) advertised firing rate (new algorithm)
    is_excitatory: jnp.ndarray  # (n,) bool


def init_neurons(key, cfg: BrainConfig, n: int) -> NeuronState:
    k1, k2 = jax.random.split(key)
    vac = jax.random.uniform(k1, (n, 2), minval=cfg.initial_vacant_low,
                             maxval=cfg.initial_vacant_high)
    exc = jnp.arange(n) < int(n * cfg.fraction_excitatory)
    return NeuronState(
        v=jnp.full((n,), cfg.izh_c, jnp.float32),
        u=jnp.full((n,), cfg.izh_b * cfg.izh_c, jnp.float32),
        calcium=jnp.zeros((n,), jnp.float32),
        ax_elements=vac[:, 0], de_elements=vac[:, 1],
        spiked=jnp.zeros((n,), bool),
        spike_count=jnp.zeros((n,), jnp.float32),
        rate=jnp.zeros((n,), jnp.float32),
        is_excitatory=exc)


def izhikevich_step(st: NeuronState, syn_input, noise, cfg: BrainConfig):
    """One 1 ms step (two 0.5 ms Euler halves for stability, as in the
    reference Izhikevich implementation)."""
    i_t = syn_input + noise
    v, u = st.v, st.u
    for _ in range(2):
        v = v + 0.5 * (0.04 * v * v + 5.0 * v + 140.0 - u + i_t)
    u = u + cfg.izh_a * (cfg.izh_b * v - u)
    spiked = v >= 30.0
    v = jnp.where(spiked, cfg.izh_c, v)
    u = jnp.where(spiked, u + cfg.izh_d, u)
    return v, u, spiked


def update_activity(st: NeuronState, syn_input, noise,
                    cfg: BrainConfig) -> NeuronState:
    v, u, spiked = izhikevich_step(st, syn_input, noise, cfg)
    calcium = st.calcium + (-st.calcium * cfg.calcium_decay
                            + cfg.calcium_beta * spiked)
    return st._replace(v=v, u=u, spiked=spiked, calcium=calcium,
                       spike_count=st.spike_count + spiked)


def update_elements(st: NeuronState, cfg: BrainConfig) -> NeuronState:
    """Homeostasis: grow elements below target calcium, retract above
    (paper §III-A(b); linear rule with nu = element_growth_rate)."""
    drive = 1.0 - st.calcium / cfg.target_calcium
    grow = cfg.element_growth_rate * drive
    return st._replace(
        ax_elements=jnp.maximum(st.ax_elements + grow, 0.0),
        de_elements=jnp.maximum(st.de_elements + grow, 0.0))


def refresh_rate(st: NeuronState, cfg: BrainConfig) -> NeuronState:
    """Close a rate window: advertised rate = spikes / Delta (new algorithm)."""
    rate = st.spike_count / cfg.rate_period
    return st._replace(rate=rate, spike_count=jnp.zeros_like(st.spike_count))
