"""MSP simulation engine: the paper's three-phase loop under jax.shard_map.

One *chunk* = rate_period (Delta=100) activity steps + one connectivity update
(the paper uses the same cadence: plasticity every 100th step). All state is
rank-local inside shard_map over a 1-D 'ranks' mesh; the only cross-rank
traffic is exactly the paper's:

  old spikes   : all-gather of sorted spiked-ID buffers, every step
  new spikes   : rate exchange, once per chunk — 'dense' all-gathers every
                 rank's full (n,) rate vector into a replicated (R, n)
                 table; 'sparse' all_to_alls subscription requests (unique
                 remote in-edge sources, rebuilt with the connectome) and
                 owners push only the subscribed rates (DESIGN.md §7)
  old conn.    : all-gather of every rank's subtree + leaf neuron data ("RMA
                 download with caching"), + 17B formation requests / 1B replies
  new conn.    : 42B formation-and-calculation requests / 9B replies,
                 all_to_all

Counters for the paper's byte accounting (Tables I/II) are accumulated in
state.stats; HLO-level collective bytes come from the roofline parser.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.msp_brain import BrainConfig
from repro.connectome import init_synapses, routing
from repro.connectome.update import connectivity_update
from repro.core import morton, spikes
from repro.core.neuron import NeuronParams, NeuronState, init_neurons
from repro.kernels import ops as kops
from repro.kernels.activity_fused import step_core
from repro.scenarios import populations as pops
from repro.scenarios import protocol as proto
from repro.scenarios import regions as regions_mod

STAT_KEYS = ("spikes_sent", "rates_sent", "subscription_requests",
             "subscription_overflow", "bh_requests", "bh_responses",
             "formation_requests", "synapses_formed", "synapses_deleted",
             "tree_nodes_downloaded", "request_overflow")


class BrainState(NamedTuple):
    """Engine state. The rate-exchange fields are layout-dependent
    (cfg.rate_exchange): 'dense' holds the replicated all-gathered
    ``rates_table`` and the sparse fields are None; 'sparse' drops the
    table and holds the rank-sharded subscription registry instead."""
    neurons: NeuronState
    out_edges: jnp.ndarray
    in_edges: jnp.ndarray
    positions: jnp.ndarray
    rates_table: jnp.ndarray     # (R, n) gathered rates (dense) | None
    subs: jnp.ndarray            # (subs_cap,) sorted unique remote source
                                 # gids, NO_SUB pad (sparse) | None
    rate_slots: jnp.ndarray      # (n, S) in-edge -> subs slot, -1 local/
                                 # empty/overflow (sparse) | None
    remote_rates: jnp.ndarray    # (subs_cap,) pushed rates aligned with
                                 # subs (sparse) | None
    chunk: jnp.ndarray           # scalar i32
    stats: dict


def _neuron_params(table: "pops.PopulationTable") -> NeuronParams:
    return NeuronParams(table.izh_a, table.izh_b, table.izh_c, table.izh_d,
                        table.growth_rate, table.target_calcium)


# ================================================================ init
def init_state(cfg: BrainConfig, rank, num_ranks: int,
               scenario=None) -> BrainState:
    if cfg.rate_exchange not in ("dense", "sparse"):
        raise ValueError(f"unknown rate_exchange {cfg.rate_exchange!r}; "
                         f"expected 'dense' or 'sparse'")
    n = cfg.neurons_per_rank
    key = jax.random.fold_in(jax.random.key(cfg.seed), rank)
    kp, kn = jax.random.split(key)
    b = morton.branch_level(num_ranks)
    c_per = morton.cells_per_rank(num_ranks)
    pos = morton.sample_positions_in_cells(kp, rank * c_per, c_per, n, b)
    table = pops.table_for(cfg, scenario, n)
    neurons = init_neurons(kn, cfg, n, params=_neuron_params(table),
                           is_excitatory=table.is_excitatory)
    syn = init_synapses(n, cfg.max_synapses)
    # (1,)-shaped per-rank counters: sharded over 'ranks', summed at read time
    stats = {k: jnp.zeros((1,), jnp.float32) for k in STAT_KEYS}
    rates_table = subs = rate_slots = remote_rates = None
    if cfg.rate_exchange == "dense":
        rates_table = jnp.zeros((num_ranks, n), jnp.float32)
    else:
        cap = routing.cap_subs(cfg, num_ranks)
        subs = jnp.full((cap,), spikes.NO_SUB, jnp.int32)
        rate_slots = jnp.full((n, cfg.max_synapses), -1, jnp.int32)
        remote_rates = jnp.zeros((cap,), jnp.float32)
    return BrainState(neurons, syn.out_edges, syn.in_edges, pos,
                      rates_table, subs, rate_slots, remote_rates,
                      jnp.zeros((), jnp.int32), stats)


# ================================================================ activity
def activity_phase(state: BrainState, cfg: BrainConfig, rank, axis_name,
                   num_ranks: int, scenario=None):
    """rate_period electrical steps. Spike exchange per cfg.spike_alg; the
    lowering per cfg.activity_impl:

      'reference'  jax.lax.scan over steps, each step the shared
                   ``kernels.activity_fused.step_core`` jnp math (~6 fused
                   passes per step, (n, s_max) temporaries in HBM);
      'fused'      one Pallas megakernel per window (grid over steps,
                   Delta-resident state — zero per-step HBM temporaries).
                   Requires spike_alg='new': the old algorithm's per-step
                   spiked-ID all-gather cannot live inside a kernel.

    Both draw noise/remote spikes from the same counter-based hash keyed by
    (seed, chunk*Delta + t, neuron/edge id), so the two lowerings are
    bit-identical (tests/test_activity_fused.py). A scenario contributes
    per-neuron parameters (population table), per-region background drive,
    stimulation currents, and lesion masks — all trace-stable (the event
    list is a static Python constant)."""
    n = cfg.neurons_per_rank
    table = pops.table_for(cfg, scenario, n)
    izh = (table.izh_a, table.izh_b, table.izh_c, table.izh_d,
           table.growth_rate, table.target_calcium)
    ca_consts = (cfg.calcium_decay, cfg.calcium_beta)
    regions = scenario.regions if scenario is not None else ()
    events = scenario.events if scenario is not None else ()
    bg_mean, bg_std = regions_mod.background_tables(state.positions, regions,
                                                    cfg)
    stim = proto.stim_tables(events, regions, state.positions) \
        if events else None
    lesions = proto.lesion_tables(events, regions, state.positions) \
        if events else None
    ns = state.neurons
    st7 = (ns.v, ns.u, ns.calcium, ns.ax_elements, ns.de_elements,
           ns.spiked, ns.spike_count)

    if cfg.activity_impl not in ("reference", "fused"):
        raise ValueError(f"unknown activity_impl {cfg.activity_impl!r}; "
                         f"expected 'reference' or 'fused'")
    # rate-exchange layout: dense reads the replicated (R, n) table with a
    # 2-D (src rank, src lid) gather; sparse reads the compact per-rank
    # subscribed-rate buffer through the (n, S) edge->slot remap
    if cfg.rate_exchange == "sparse":
        rates, rate_slots = state.remote_rates, state.rate_slots
    else:
        rates, rate_slots = state.rates_table, None
    if cfg.activity_impl == "fused":
        if cfg.spike_alg != "new":
            raise ValueError(
                "activity_impl='fused' requires spike_alg='new' — the old "
                "algorithm exchanges spiked IDs every step (a collective), "
                "which cannot run inside the megakernel")
        out = kops.fused_activity_window(
            st7, state.in_edges, table.synapse_weight, rates,
            bg_mean, bg_std, state.chunk, rank, seed=cfg.seed,
            num_steps=cfg.rate_period, izh=izh, ca_consts=ca_consts,
            stim=stim, lesions=lesions, rate_slots=rate_slots)
        neurons = ns._replace(v=out[0], u=out[1], calcium=out[2],
                              ax_elements=out[3], de_elements=out[4],
                              spiked=out[5], spike_count=out[6])
        return state._replace(neurons=neurons)

    def step(carry, t):
        st, stats = carry
        if cfg.spike_alg == "old":
            all_ids, _ = spikes.exchange_spiked_ids(
                st[5], rank, n, axis_name, num_ranks)
            hits = spikes.lookup_spikes(all_ids, state.in_edges, n)
            remote_in = hits & ((state.in_edges // n) != rank) \
                & (state.in_edges >= 0)
            stats = dict(stats, spikes_sent=stats["spikes_sent"]
                         + jnp.sum(st[5]).astype(jnp.float32))
        else:
            remote_in = None   # step_core reconstructs from the hash
        st = step_core(st, state.in_edges, table.synapse_weight,
                       rates, bg_mean, bg_std, izh, ca_consts,
                       cfg.seed, state.chunk * cfg.rate_period + t, rank, n,
                       stim=stim, lesions=lesions, remote_override=remote_in,
                       rate_slots=rate_slots)
        return (st, stats), None

    (out, stats), _ = jax.lax.scan(
        step, (st7, state.stats),
        jnp.arange(cfg.rate_period, dtype=jnp.int32))
    neurons = ns._replace(v=out[0], u=out[1], calcium=out[2],
                          ax_elements=out[3], de_elements=out[4],
                          spiked=out[5], spike_count=out[6])
    return state._replace(neurons=neurons, stats=stats)


# ================================================================ connectivity
def connectivity_phase(state: BrainState, cfg: BrainConfig, rank, axis_name,
                       num_ranks: int, scenario=None):
    """One structural-plasticity update — owned by the connectome subsystem
    (repro.connectome: tree build, Barnes-Hut traversal, request routing,
    synapse-table ops; DESIGN.md §6). ``cfg.connectivity_alg`` picks the
    paper's algorithm pair (old = move data, new = move compute);
    ``cfg.connectivity_impl`` picks the phase-B lowering (reference jnp vs
    the Pallas traversal kernel — bit-identical)."""
    return connectivity_update(state, cfg, rank, axis_name, num_ranks,
                               scenario)


# ================================================================ driver
def sim_chunk(state: BrainState, cfg: BrainConfig, rank, axis_name,
              num_ranks: int, scenario=None) -> BrainState:
    state = activity_phase(state, cfg, rank, axis_name, num_ranks, scenario)
    state = connectivity_phase(state, cfg, rank, axis_name, num_ranks,
                               scenario)
    return state


def make_brain_mesh(devices=None):
    devs = jax.devices() if devices is None else devices
    return Mesh(np.array(devs), ("ranks",))


def _state_specs(state, num_ranks):
    def spec(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if "rates_table" in name or "chunk" in name:
            return P()  # replicated (all_gather result / scalar step counter)
        # everything else — including the sparse-exchange subs/rate_slots/
        # remote_rates registry — is rank-sharded on the leading dim
        return P("ranks", *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, state)


def build_sim(cfg: BrainConfig, mesh: Mesh, scenario=None):
    """Returns (init_fn, chunk_fn) jitted over the 'ranks' mesh.
    ``scenario`` (repro.scenarios.protocol.Scenario) is a static experiment
    description: heterogeneous populations, regions, and event protocols all
    compile into the same single trace as the default simulation."""
    num_ranks = mesh.shape["ranks"]

    def sharded_init():
        def body():
            rank = jax.lax.axis_index("ranks")
            st = init_state(cfg, rank, num_ranks, scenario)
            return st
        shapes = jax.eval_shape(lambda: init_state(cfg, 0, num_ranks,
                                                   scenario))
        out_specs = _state_specs(shapes, num_ranks)
        return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(),
                                        out_specs=out_specs,
                                        check_vma=False))()

    shapes = jax.eval_shape(lambda: init_state(cfg, 0, num_ranks, scenario))
    specs = _state_specs(shapes, num_ranks)

    def chunk_body(st):
        rank = jax.lax.axis_index("ranks")
        return sim_chunk(st, cfg, rank, "ranks", num_ranks, scenario)

    chunk = jax.jit(compat.shard_map(chunk_body, mesh=mesh, in_specs=(specs,),
                                     out_specs=specs, check_vma=False),
                    donate_argnums=(0,))
    return sharded_init, chunk


def lower_sim_step(cfg: BrainConfig, mesh):
    """Dry-run entry: lower one sim chunk on all devices of ``mesh``."""
    bmesh = make_brain_mesh(list(mesh.devices.flat))
    init_fn, chunk = build_sim(cfg, bmesh)
    num_ranks = bmesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: init_state(cfg, 0, num_ranks))
    # global view: leading rank-local dim concatenated across ranks
    global_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            (l.shape[0] * num_ranks,) + l.shape[1:] if l.ndim >= 1 else
            l.shape, l.dtype), shapes)
    # the dense rates_table & the step counter are replicated (not
    # concatenated); sparse-mode registry fields are rank-sharded like the
    # rest (and rates_table is None then — _replace is a no-op on it)
    global_shapes = global_shapes._replace(
        rates_table=shapes.rates_table, chunk=shapes.chunk)
    return chunk.lower(global_shapes)
