"""MSP simulation engine: the paper's three-phase loop under jax.shard_map.

One *chunk* = rate_period (Delta=100) activity steps + one connectivity update
(the paper uses the same cadence: plasticity every 100th step). All state is
rank-local inside shard_map over a 1-D 'ranks' mesh; the only cross-rank
traffic is exactly the paper's:

  old spikes : all-gather of sorted spiked-ID buffers, every step
  new spikes : all-gather of rates, once per chunk
  old conn.  : all-gather of every rank's subtree + leaf neuron data ("RMA
               download with caching"), + 17B formation requests / 1B replies
  new conn.  : 42B formation-and-calculation requests / 9B replies, all_to_all

Counters for the paper's byte accounting (Tables I/II) are accumulated in
state.stats; HLO-level collective bytes come from the roofline parser.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.configs.msp_brain import BrainConfig
from repro.core import connectivity as conn
from repro.core import morton, octree, spikes
from repro.core.neuron import (NeuronParams, NeuronState, init_neurons,
                               refresh_rate)
from repro.kernels import ops as kops
from repro.kernels.activity_fused import step_core
from repro.scenarios import populations as pops
from repro.scenarios import protocol as proto
from repro.scenarios import regions as regions_mod

STAT_KEYS = ("spikes_sent", "rates_sent", "bh_requests", "bh_responses",
             "formation_requests", "synapses_formed", "synapses_deleted",
             "tree_nodes_downloaded", "request_overflow")


class BrainState(NamedTuple):
    neurons: NeuronState
    out_edges: jnp.ndarray
    in_edges: jnp.ndarray
    positions: jnp.ndarray
    rates_table: jnp.ndarray     # (R, n) gathered rates (new alg)
    chunk: jnp.ndarray           # scalar i32
    stats: dict


def _neuron_params(table: "pops.PopulationTable") -> NeuronParams:
    return NeuronParams(table.izh_a, table.izh_b, table.izh_c, table.izh_d,
                        table.growth_rate, table.target_calcium)


def _cap_requests(cfg, num_ranks):
    """Per-(source, dest)-rank request buffer capacity. Locality skews demand
    toward the home rank, so tests/benchmarks needing zero overflow set
    requests_cap_factor >= num_ranks (=> cap = n)."""
    n = cfg.neurons_per_rank
    per_dest = max(n // max(num_ranks, 1), 1) * cfg.requests_cap_factor
    return min(n, max(32, -(-per_dest // 8) * 8))


def _cap_deletions(cfg, lesions: bool = False):
    """Deletion-message buffer capacity. Lesion protocols retract EVERY edge
    of a dead neuron in one update, so the cap then scales with
    requests_cap_factor like the formation buffers (n * s_max is the most a
    rank can ever send to one destination); without lesions the seed's
    homeostatic trickle keeps the original small buffer (and its collective
    bytes) unchanged."""
    n = cfg.neurons_per_rank
    if not lesions:
        return max(16, n // 4)
    return min(n * cfg.max_synapses,
               max(16, (n // 4) * cfg.requests_cap_factor))


# ================================================================ init
def init_state(cfg: BrainConfig, rank, num_ranks: int,
               scenario=None) -> BrainState:
    n = cfg.neurons_per_rank
    key = jax.random.fold_in(jax.random.key(cfg.seed), rank)
    kp, kn = jax.random.split(key)
    b = morton.branch_level(num_ranks)
    c_per = morton.cells_per_rank(num_ranks)
    pos = morton.sample_positions_in_cells(kp, rank * c_per, c_per, n, b)
    table = pops.table_for(cfg, scenario, n)
    neurons = init_neurons(kn, cfg, n, params=_neuron_params(table),
                           is_excitatory=table.is_excitatory)
    syn = conn.init_synapses(n, cfg.max_synapses)
    # (1,)-shaped per-rank counters: sharded over 'ranks', summed at read time
    stats = {k: jnp.zeros((1,), jnp.float32) for k in STAT_KEYS}
    return BrainState(neurons, syn.out_edges, syn.in_edges, pos,
                      jnp.zeros((num_ranks, n), jnp.float32),
                      jnp.zeros((), jnp.int32), stats)


# ================================================================ activity
def activity_phase(state: BrainState, cfg: BrainConfig, rank, axis_name,
                   num_ranks: int, scenario=None):
    """rate_period electrical steps. Spike exchange per cfg.spike_alg; the
    lowering per cfg.activity_impl:

      'reference'  jax.lax.scan over steps, each step the shared
                   ``kernels.activity_fused.step_core`` jnp math (~6 fused
                   passes per step, (n, s_max) temporaries in HBM);
      'fused'      one Pallas megakernel per window (grid over steps,
                   Delta-resident state — zero per-step HBM temporaries).
                   Requires spike_alg='new': the old algorithm's per-step
                   spiked-ID all-gather cannot live inside a kernel.

    Both draw noise/remote spikes from the same counter-based hash keyed by
    (seed, chunk*Delta + t, neuron/edge id), so the two lowerings are
    bit-identical (tests/test_activity_fused.py). A scenario contributes
    per-neuron parameters (population table), per-region background drive,
    stimulation currents, and lesion masks — all trace-stable (the event
    list is a static Python constant)."""
    n = cfg.neurons_per_rank
    table = pops.table_for(cfg, scenario, n)
    izh = (table.izh_a, table.izh_b, table.izh_c, table.izh_d,
           table.growth_rate, table.target_calcium)
    ca_consts = (cfg.calcium_decay, cfg.calcium_beta)
    regions = scenario.regions if scenario is not None else ()
    events = scenario.events if scenario is not None else ()
    bg_mean, bg_std = regions_mod.background_tables(state.positions, regions,
                                                    cfg)
    stim = proto.stim_tables(events, regions, state.positions) \
        if events else None
    lesions = proto.lesion_tables(events, regions, state.positions) \
        if events else None
    ns = state.neurons
    st7 = (ns.v, ns.u, ns.calcium, ns.ax_elements, ns.de_elements,
           ns.spiked, ns.spike_count)

    if cfg.activity_impl not in ("reference", "fused"):
        raise ValueError(f"unknown activity_impl {cfg.activity_impl!r}; "
                         f"expected 'reference' or 'fused'")
    if cfg.activity_impl == "fused":
        if cfg.spike_alg != "new":
            raise ValueError(
                "activity_impl='fused' requires spike_alg='new' — the old "
                "algorithm exchanges spiked IDs every step (a collective), "
                "which cannot run inside the megakernel")
        out = kops.fused_activity_window(
            st7, state.in_edges, table.synapse_weight, state.rates_table,
            bg_mean, bg_std, state.chunk, rank, seed=cfg.seed,
            num_steps=cfg.rate_period, izh=izh, ca_consts=ca_consts,
            stim=stim, lesions=lesions)
        neurons = ns._replace(v=out[0], u=out[1], calcium=out[2],
                              ax_elements=out[3], de_elements=out[4],
                              spiked=out[5], spike_count=out[6])
        return state._replace(neurons=neurons)

    def step(carry, t):
        st, stats = carry
        if cfg.spike_alg == "old":
            all_ids, counts_ = spikes.exchange_spiked_ids(
                st[5], rank, n, axis_name, num_ranks)
            hits = spikes.lookup_spikes(all_ids, state.in_edges, n)
            remote_in = hits & ((state.in_edges // n) != rank) \
                & (state.in_edges >= 0)
            stats = dict(stats, spikes_sent=stats["spikes_sent"]
                         + jnp.sum(st[5]).astype(jnp.float32))
        else:
            remote_in = None   # step_core reconstructs from the hash
        st = step_core(st, state.in_edges, table.synapse_weight,
                       state.rates_table, bg_mean, bg_std, izh, ca_consts,
                       cfg.seed, state.chunk * cfg.rate_period + t, rank, n,
                       stim=stim, lesions=lesions, remote_override=remote_in)
        return (st, stats), None

    (out, stats), _ = jax.lax.scan(
        step, (st7, state.stats),
        jnp.arange(cfg.rate_period, dtype=jnp.int32))
    neurons = ns._replace(v=out[0], u=out[1], calcium=out[2],
                          ax_elements=out[3], de_elements=out[4],
                          spiked=out[5], spike_count=out[6])
    return state._replace(neurons=neurons, stats=stats)


# ================================================================ connectivity
def connectivity_phase(state: BrainState, cfg: BrainConfig, rank, axis_name,
                       num_ranks: int, scenario=None):
    n = cfg.neurons_per_rank
    s_max = cfg.max_synapses
    # chunk_key is rank-independent: every rank derives the same stream, so
    # per-(gid) sub-streams are reproducible wherever the computation runs —
    # the property that makes old == new bit-identical (DESIGN.md §2)
    chunk_key = jax.random.fold_in(jax.random.key(cfg.seed + 2), state.chunk)
    key = chunk_key
    gid0 = rank * n
    gids = gid0 + jnp.arange(n, dtype=jnp.int32)
    stats = dict(state.stats)

    # lesion mask at the update instant (the step right after this chunk's
    # activity scan). Applied BEFORE the algorithm branch so 'old' and 'new'
    # see identical inputs — the bit-identity invariant holds per protocol.
    events = scenario.events if scenario is not None else ()
    alive = proto.alive_mask(events, scenario.regions, state.positions,
                             (state.chunk + 1) * cfg.rate_period) \
        if events else None
    if alive is not None:
        # dead neurons lose all synaptic elements -> full retraction below,
        # partners are notified and regain vacant elements
        state = state._replace(neurons=state.neurons._replace(
            ax_elements=jnp.where(alive, state.neurons.ax_elements, 0.0),
            de_elements=jnp.where(alive, state.neurons.de_elements, 0.0)))

    # ---- deletion by retraction (phase 3a) -------------------------------
    out_edges, in_edges = state.out_edges, state.in_edges
    out_cnt, in_cnt = conn.counts(out_edges), conn.counts(in_edges)
    del_out = jnp.maximum(
        out_cnt - jnp.floor(state.neurons.ax_elements).astype(jnp.int32), 0)
    del_in = jnp.maximum(
        in_cnt - jnp.floor(state.neurons.de_elements).astype(jnp.int32), 0)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    out_edges, kill_out = conn.retract_synapses(k1, out_edges, del_out, gids)
    in_edges, kill_in = conn.retract_synapses(k2, in_edges, del_in, gids)
    stats["synapses_deleted"] = stats["synapses_deleted"] + \
        jnp.sum(kill_out) + jnp.sum(kill_in)

    # notify partners (paper: 'the affected partner gains a vacant element')
    def route_deletions(kill, edges, my_gid_col):
        flat_other = jnp.where(kill, edges, -1).reshape(-1)
        flat_mine = jnp.broadcast_to(my_gid_col, kill.shape).reshape(-1)
        valid = flat_other >= 0
        dest = jnp.where(valid, flat_other // n, num_ranks)
        cap = _cap_deletions(cfg, proto.has_lesions(scenario))
        slot = octree.positions_within(dest, num_ranks + 1)
        ok = valid & (slot < cap)
        buf = jnp.full((num_ranks, cap, 2), -1, jnp.int32)
        buf = buf.at[jnp.where(ok, dest, num_ranks),
                     jnp.where(ok, slot, 0)].set(
            jnp.stack([jnp.where(ok, flat_other, -1),
                       jnp.where(ok, flat_mine, -1)], -1), mode="drop")
        if num_ranks > 1:
            buf = jax.lax.all_to_all(buf, axis_name, 0, 0, tiled=True)
        return buf.reshape(num_ranks * cap, 2), \
            jnp.sum(valid & ~ok).astype(jnp.float32)

    # old edges (pre-retraction) were already overwritten; use kill masks on
    # the pre-retraction tables captured above via state
    msgs_out, ovf_out = route_deletions(kill_out, state.out_edges,
                                        gids[:, None])
    msgs_in, ovf_in = route_deletions(kill_in, state.in_edges, gids[:, None])
    # dropped notifications leave stale partner edges — surface them
    stats["request_overflow"] = stats["request_overflow"] + ovf_out + ovf_in
    # apply: partner of my out-edge removes its in-edge, and vice versa
    in_edges = conn.remove_edges_by_messages(
        in_edges, jnp.clip(msgs_out[:, 0] - gid0, 0, n - 1), msgs_out[:, 1],
        (msgs_out[:, 0] >= gid0) & (msgs_out[:, 0] < gid0 + n))
    out_edges = conn.remove_edges_by_messages(
        out_edges, jnp.clip(msgs_in[:, 0] - gid0, 0, n - 1), msgs_in[:, 1],
        (msgs_in[:, 0] >= gid0) & (msgs_in[:, 0] < gid0 + n))
    out_edges, in_edges = conn.compact(out_edges), conn.compact(in_edges)

    # ---- formation (phase 3b) --------------------------------------------
    out_cnt, in_cnt = conn.counts(out_edges), conn.counts(in_edges)
    vac_a = jnp.floor(state.neurons.ax_elements).astype(jnp.int32) - out_cnt
    vac_d = state.neurons.de_elements - in_cnt.astype(jnp.float32)
    vac_d_pos = jnp.maximum(vac_d, 0.0)

    local_tree = octree.build_local_tree(state.positions, vac_d_pos, rank,
                                         cfg, num_ranks)
    top = octree.exchange_branch_nodes(local_tree, axis_name, num_ranks)

    searching = vac_a >= 1
    if alive is not None:
        # dead neurons neither search for partners nor offer vacancies
        searching = searching & alive
        vac_d_pos = jnp.where(alive, vac_d_pos, 0.0)
    # per-searcher stream derived from (chunk_key, gid) — reconstructible on
    # the owning rank in the new algorithm (see _formation_new)
    skeys = jax.vmap(lambda g: jax.random.fold_in(chunk_key, g))(gids)
    branch_cell, valid_a = conn.phase_a(top, state.positions, skeys, cfg,
                                        num_ranks)
    valid_a = valid_a & searching
    c_per = morton.cells_per_rank(num_ranks)
    owner = jnp.clip(branch_cell // c_per, 0, num_ranks - 1)
    start_rel = branch_cell - owner * c_per
    stats["bh_requests"] = stats["bh_requests"] + jnp.sum(valid_a)

    if cfg.connectivity_alg == "new":
        tgt_gid, accept, ovf = _formation_new(
            cfg, state, local_tree, vac_d_pos, in_edges, gids, skeys,
            branch_cell, owner, start_rel, valid_a, rank, axis_name,
            num_ranks, k4)
        in_edges_new = accept.pop("in_edges")
        stats["request_overflow"] = stats["request_overflow"] + ovf
        stats["bh_responses"] = stats["bh_responses"] + jnp.sum(
            accept["accepted"])
        out_edges = conn.add_out_edges(out_edges, tgt_gid, accept["accepted"])
        in_edges = in_edges_new
        stats["synapses_formed"] = stats["synapses_formed"] + jnp.sum(
            accept["accepted"])
    else:
        tgt_gid, accepted, new_in, downloaded = _formation_old(
            cfg, state, local_tree, vac_d_pos, in_edges, gids, skeys,
            branch_cell, owner, start_rel, valid_a, rank, axis_name,
            num_ranks, k4)
        out_edges = conn.add_out_edges(out_edges, tgt_gid, accepted)
        in_edges = new_in
        stats["tree_nodes_downloaded"] = stats["tree_nodes_downloaded"] \
            + downloaded
        stats["formation_requests"] = stats["formation_requests"] + jnp.sum(
            valid_a)
        stats["synapses_formed"] = stats["synapses_formed"] + jnp.sum(accepted)

    neurons = refresh_rate(state.neurons, cfg, alive)
    if cfg.spike_alg == "old":
        # the rates table is dead state on the old spike path — skip the
        # per-chunk all-gather (and its accounting) entirely
        rates_table = state.rates_table
    else:
        rates_table = spikes.exchange_rates(neurons.rate, axis_name,
                                            num_ranks)
        stats["rates_sent"] = stats["rates_sent"] + float(n)
    return state._replace(neurons=neurons, out_edges=out_edges,
                          in_edges=in_edges, rates_table=rates_table,
                          chunk=state.chunk + 1, stats=stats)


def _formation_new(cfg, state, local_tree, vac_d_pos, in_edges, gids, skeys,
                   branch_cell, owner, start_rel, valid_a, rank, axis_name,
                   num_ranks, key):
    """Location-aware algorithm: 42B requests out, local phase B + accept,
    9B responses back."""
    n = cfg.neurons_per_rank
    cap = _cap_requests(cfg, num_ranks)
    dest = jnp.where(valid_a, owner, num_ranks)
    slot = octree.positions_within(dest, num_ranks + 1)
    ok = valid_a & (slot < cap)
    ovf = jnp.sum(valid_a & ~ok).astype(jnp.float32)

    ibuf = jnp.full((num_ranks, cap, 2), -1, jnp.int32)   # src_gid, start_cell
    fbuf = jnp.zeros((num_ranks, cap, 3), jnp.float32)    # position
    d_c = jnp.where(ok, dest, num_ranks)
    s_c = jnp.where(ok, slot, 0)
    ibuf = ibuf.at[d_c, s_c].set(
        jnp.stack([jnp.where(ok, gids, -1), start_rel], -1), mode="drop")
    fbuf = fbuf.at[d_c, s_c].set(state.positions, mode="drop")
    if num_ranks > 1:
        ibuf = jax.lax.all_to_all(ibuf, axis_name, 0, 0, tiled=True)
        fbuf = jax.lax.all_to_all(fbuf, axis_name, 0, 0, tiled=True)

    r_src = ibuf[..., 0].reshape(-1)
    r_cell = ibuf[..., 1].reshape(-1)
    r_pos = fbuf.reshape(-1, 3)
    r_valid = r_src >= 0
    # receiver reconstructs the SAME per-searcher stream from the source gid
    chunk_key = jax.random.fold_in(jax.random.key(cfg.seed + 2), state.chunk)
    rkeys = jax.vmap(lambda g: jax.random.fold_in(chunk_key, g))(
        jnp.where(r_valid, r_src, 0))
    # continue traversal on the owning rank (phase B)
    tgt, bvalid = conn.phase_b(local_tree, state.positions, vac_d_pos, r_pos,
                               rkeys, jnp.clip(r_cell, 0, None), r_valid,
                               cfg, num_ranks, rank * n,
                               jnp.where(r_valid, r_src, -2))
    # accept/decline where the target lives (same rank — no extra comms)
    acc, new_in = conn.accept_requests(
        jnp.clip(tgt - rank * n, 0, n - 1), r_src, bvalid & (tgt >= 0),
        vac_d_pos, in_edges, key)
    # 9B responses retrace the request route
    rbuf = jnp.stack([jnp.where(acc, tgt, -1),
                      acc.astype(jnp.int32)], -1).reshape(num_ranks, cap, 2)
    if num_ranks > 1:
        rbuf = jax.lax.all_to_all(rbuf, axis_name, 0, 0, tiled=True)
    resp_tgt = rbuf[d_c, s_c, 0]
    resp_ok = (rbuf[d_c, s_c, 1] > 0) & ok
    return resp_tgt, {"accepted": resp_ok, "in_edges": new_in}, ovf


def _formation_old(cfg, state, local_tree, vac_d_pos, in_edges, gids, skeys,
                   branch_cell, owner, start_rel, valid_a, rank, axis_name,
                   num_ranks, key):
    """Baseline: download every rank's subtree + leaf data (RMA+cache
    endpoint), search locally, then exchange 17B formation requests."""
    n = cfg.neurons_per_rank
    # ---- the download: all levels, members, positions, weights ----
    if num_ranks > 1:
        g_counts = tuple(jax.lax.all_gather(c, axis_name, axis=0, tiled=True)
                         for c in local_tree.counts)
        g_cents = tuple(jax.lax.all_gather(z, axis_name, axis=0, tiled=True)
                        for z in local_tree.centroids)
        members_g = jnp.where(local_tree.leaf_members >= 0,
                              local_tree.leaf_members + rank * n, -1)
        g_members = jax.lax.all_gather(members_g, axis_name, axis=0,
                                       tiled=True)
        g_pos = jax.lax.all_gather(state.positions, axis_name, axis=0,
                                   tiled=True)
        g_vac = jax.lax.all_gather(vac_d_pos, axis_name, axis=0, tiled=True)
    else:
        g_counts, g_cents = local_tree.counts, local_tree.centroids
        g_members = local_tree.leaf_members
        g_pos, g_vac = state.positions, vac_d_pos
    downloaded = (sum(c.shape[0] for c in g_counts) + g_pos.shape[0]) \
        * (num_ranks - 1) / max(num_ranks, 1)
    g_tree = octree.LocalTree(g_counts, g_cents, g_members,
                              jnp.zeros((), jnp.int32))
    # ---- phase B locally for my searchers (same PRNG stream as 'new') ----
    tgt, bvalid = conn.phase_b(g_tree, g_pos, g_vac, state.positions, skeys,
                               branch_cell, valid_a, cfg, num_ranks, 0, gids)
    # ---- classic 17B formation request to the target's rank ----
    cap = _cap_requests(cfg, num_ranks)
    dest = jnp.where(bvalid & (tgt >= 0), tgt // n, num_ranks)
    slot = octree.positions_within(dest, num_ranks + 1)
    ok = (dest < num_ranks) & (slot < cap)
    ibuf = jnp.full((num_ranks, cap, 2), -1, jnp.int32)
    d_c = jnp.where(ok, dest, num_ranks)
    s_c = jnp.where(ok, slot, 0)
    ibuf = ibuf.at[d_c, s_c].set(
        jnp.stack([jnp.where(ok, gids, -1), jnp.where(ok, tgt, -1)], -1),
        mode="drop")
    if num_ranks > 1:
        ibuf = jax.lax.all_to_all(ibuf, axis_name, 0, 0, tiled=True)
    r_src = ibuf[..., 0].reshape(-1)
    r_tgt = ibuf[..., 1].reshape(-1)
    r_valid = (r_src >= 0) & (r_tgt >= 0)
    acc, new_in = conn.accept_requests(
        jnp.clip(r_tgt - rank * n, 0, n - 1), r_src, r_valid, vac_d_pos,
        in_edges, key)
    rbuf = acc.astype(jnp.int32).reshape(num_ranks, cap)
    if num_ranks > 1:
        rbuf = jax.lax.all_to_all(rbuf, axis_name, 0, 0, tiled=True)
    accepted = (rbuf[d_c, s_c] > 0) & ok
    return tgt, accepted, new_in, jnp.asarray(downloaded, jnp.float32)


# ================================================================ driver
def sim_chunk(state: BrainState, cfg: BrainConfig, rank, axis_name,
              num_ranks: int, scenario=None) -> BrainState:
    state = activity_phase(state, cfg, rank, axis_name, num_ranks, scenario)
    state = connectivity_phase(state, cfg, rank, axis_name, num_ranks,
                               scenario)
    return state


def make_brain_mesh(devices=None):
    devs = jax.devices() if devices is None else devices
    return Mesh(np.array(devs), ("ranks",))


def _state_specs(state, num_ranks):
    def spec(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if "rates_table" in name or "chunk" in name:
            return P()  # replicated (all_gather result / scalar step counter)
        return P("ranks", *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, state)


def build_sim(cfg: BrainConfig, mesh: Mesh, scenario=None):
    """Returns (init_fn, chunk_fn) jitted over the 'ranks' mesh.
    ``scenario`` (repro.scenarios.protocol.Scenario) is a static experiment
    description: heterogeneous populations, regions, and event protocols all
    compile into the same single trace as the default simulation."""
    num_ranks = mesh.shape["ranks"]

    def sharded_init():
        def body():
            rank = jax.lax.axis_index("ranks")
            st = init_state(cfg, rank, num_ranks, scenario)
            return st
        shapes = jax.eval_shape(lambda: init_state(cfg, 0, num_ranks,
                                                   scenario))
        out_specs = _state_specs(shapes, num_ranks)
        return jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(),
                                        out_specs=out_specs,
                                        check_vma=False))()

    shapes = jax.eval_shape(lambda: init_state(cfg, 0, num_ranks, scenario))
    specs = _state_specs(shapes, num_ranks)

    def chunk_body(st):
        rank = jax.lax.axis_index("ranks")
        return sim_chunk(st, cfg, rank, "ranks", num_ranks, scenario)

    chunk = jax.jit(compat.shard_map(chunk_body, mesh=mesh, in_specs=(specs,),
                                     out_specs=specs, check_vma=False),
                    donate_argnums=(0,))
    return sharded_init, chunk


def lower_sim_step(cfg: BrainConfig, mesh):
    """Dry-run entry: lower one sim chunk on all devices of ``mesh``."""
    bmesh = make_brain_mesh(list(mesh.devices.flat))
    init_fn, chunk = build_sim(cfg, bmesh)
    num_ranks = bmesh.shape["ranks"]
    shapes = jax.eval_shape(lambda: init_state(cfg, 0, num_ranks))
    # global view: leading rank-local dim concatenated across ranks
    global_shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            (l.shape[0] * num_ranks,) + l.shape[1:] if l.ndim >= 1 else
            l.shape, l.dtype), shapes)
    # rates_table & the step counter are replicated (not concatenated)
    global_shapes = global_shapes._replace(
        rates_table=shapes.rates_table, chunk=shapes.chunk)
    return chunk.lower(global_shapes)
