"""MSP simulation engine: state, init, and sharding for the paper's
three-phase loop under jax.shard_map.

One *chunk* = rate_period (Delta=100) activity steps + one connectivity update
(the paper uses the same cadence: plasticity every 100th step). All state is
rank-local inside shard_map over a 1-D 'ranks' mesh; the only cross-rank
traffic is exactly the paper's:

  old spikes   : all-gather of sorted spiked-ID buffers, every step
  new spikes   : rate exchange, once per chunk — 'dense' all-gathers every
                 rank's full (n,) rate vector into a replicated (R, n)
                 table; 'sparse' all_to_alls subscription requests (unique
                 remote in-edge sources, rebuilt with the connectome) and
                 owners push only the subscribed rates (DESIGN.md §7)
  old conn.    : all-gather of every rank's subtree + leaf neuron data ("RMA
                 download with caching"), + 17B formation requests / 1B replies
  new conn.    : 42B formation-and-calculation requests / 9B replies,
                 all_to_all

Counters for the paper's byte accounting (Tables I/II) are accumulated in
state.stats; HLO-level collective bytes come from the roofline parser.

The phase implementations live in ``repro.sim.phases`` (selected through
the phase registry; DESIGN.md §8) and the user-facing driver is
``repro.sim.api.Simulator``. This module keeps the state definition,
sharded init, the per-field PartitionSpecs, and thin compat shims
(``build_sim``, ``activity_phase``, ``connectivity_phase``, ``sim_chunk``)
with the pre-facade signatures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.msp_brain import BrainConfig
from repro.connectome import init_synapses, routing
from repro.core import morton, spikes
from repro.core.neuron import NeuronParams, NeuronState, init_neurons
from repro.scenarios import populations as pops
from repro.sim import phases as sim_phases
from repro.telemetry import metrics as telemetry_metrics

# every device-side counter key (legacy byte accounting + per-phase work
# counters) — the single source of truth lives in repro.telemetry.metrics
STAT_KEYS = telemetry_metrics.COUNTER_KEYS


class BrainState(NamedTuple):
    """Engine state. The rate-exchange fields are layout-dependent
    (cfg.rate_exchange): 'dense' holds the replicated all-gathered
    ``rates_table`` and the sparse fields are None; 'sparse' drops the
    table and holds the rank-sharded subscription registry instead.

    Sharding: every field's PartitionSpec is declared explicitly in
    ``state_specs`` below — adding a field here without declaring its spec
    there is a hard error (no path-name inference)."""
    neurons: NeuronState
    out_edges: jnp.ndarray
    in_edges: jnp.ndarray
    positions: jnp.ndarray
    rates_table: jnp.ndarray     # (R, n) gathered rates (dense) | None
    subs: jnp.ndarray            # (subs_cap,) sorted unique remote source
                                 # gids, NO_SUB pad (sparse) | None
    rate_slots: jnp.ndarray      # (n, S) in-edge -> subs slot, -1 local/
                                 # empty/overflow (sparse) | None
    remote_rates: jnp.ndarray    # (subs_cap,) pushed rates aligned with
                                 # subs (sparse) | None
    chunk: jnp.ndarray           # scalar i32
    stats: "telemetry_metrics.Metrics"   # per-rank counters/rings/hists


_RANKS = P("ranks")
# NeuronState: every field is a (n,) per-neuron array, rank-sharded on its
# only dim. Declared field-by-field so a new field must be placed here.
_NEURON_SPECS = NeuronState(
    v=_RANKS, u=_RANKS, calcium=_RANKS, ax_elements=_RANKS,
    de_elements=_RANKS, spiked=_RANKS, spike_count=_RANKS, rate=_RANKS,
    is_excitatory=_RANKS)


def state_specs(state) -> BrainState:
    """Explicit per-field PartitionSpecs for ``state`` (a BrainState of
    arrays or ShapeDtypeStructs). The layout-dependent rate-exchange fields
    keep None where the state holds None, so the spec tree always matches
    the state tree."""
    def opt(leaf, spec):
        return None if leaf is None else spec
    return BrainState(
        neurons=_NEURON_SPECS,
        out_edges=P("ranks", None),       # (n, S) synapse tables
        in_edges=P("ranks", None),
        positions=P("ranks", None),       # (n, 3)
        rates_table=opt(state.rates_table, P()),   # replicated all-gather
        subs=opt(state.subs, _RANKS),              # (subs_cap,) per rank
        rate_slots=opt(state.rate_slots, P("ranks", None)),   # (n, S)
        remote_rates=opt(state.remote_rates, _RANKS),
        chunk=P(),                        # replicated scalar step counter
        # the metrics tree: every leaf per-rank on its leading axis
        stats=telemetry_metrics.metrics_specs(state.stats),
    )


def _neuron_params(table: "pops.PopulationTable") -> NeuronParams:
    return NeuronParams(table.izh_a, table.izh_b, table.izh_c, table.izh_d,
                        table.growth_rate, table.target_calcium)


# ================================================================ init
def init_state(cfg: BrainConfig, rank, num_ranks: int,
               scenario=None) -> BrainState:
    n = cfg.neurons_per_rank
    key = jax.random.fold_in(jax.random.key(cfg.seed), rank)
    kp, kn = jax.random.split(key)
    b = morton.branch_level(num_ranks)
    c_per = morton.cells_per_rank(num_ranks)
    pos = morton.sample_positions_in_cells(kp, rank * c_per, c_per, n, b)
    table = pops.table_for(cfg, scenario, n)
    neurons = init_neurons(kn, cfg, n, params=_neuron_params(table),
                           is_excitatory=table.is_excitatory)
    syn = init_synapses(n, cfg.max_synapses)
    # the telemetry tree: (1,)-leading per-rank leaves, sharded over 'ranks';
    # reductions happen at read time (Simulator.stats / .metrics), on device
    stats = telemetry_metrics.init_metrics(cfg.metrics_history)
    rates_table = subs = rate_slots = remote_rates = None
    if cfg.rate_exchange == "dense":
        rates_table = jnp.zeros((num_ranks, n), jnp.float32)
    else:
        cap = routing.cap_subs(cfg, num_ranks)
        subs = jnp.full((cap,), spikes.NO_SUB, jnp.int32)
        rate_slots = jnp.full((n, cfg.max_synapses), -1, jnp.int32)
        remote_rates = jnp.zeros((cap,), jnp.float32)
    return BrainState(neurons, syn.out_edges, syn.in_edges, pos,
                      rates_table, subs, rate_slots, remote_rates,
                      jnp.zeros((), jnp.int32), stats)


# ================================================================ phases
# Compat shims with the pre-facade six-arg signatures; the implementations
# live in repro.sim.phases behind the phase registry.
def activity_phase(state: BrainState, cfg: BrainConfig, rank, axis_name,
                   num_ranks: int, scenario=None):
    ctx = sim_phases.make_context(cfg, rank, axis_name, num_ranks, scenario)
    return sim_phases.activity_phase(state, ctx)


def connectivity_phase(state: BrainState, cfg: BrainConfig, rank, axis_name,
                       num_ranks: int, scenario=None):
    ctx = sim_phases.make_context(cfg, rank, axis_name, num_ranks, scenario)
    return sim_phases.connectivity_phase(state, ctx)


def sim_chunk(state: BrainState, cfg: BrainConfig, rank, axis_name,
              num_ranks: int, scenario=None) -> BrainState:
    ctx = sim_phases.make_context(cfg, rank, axis_name, num_ranks, scenario)
    return sim_phases.sim_chunk(state, ctx)


# ================================================================ driver
def make_brain_mesh(devices=None):
    devs = jax.devices() if devices is None else devices
    return Mesh(np.array(devs), ("ranks",))


def build_sim(cfg: BrainConfig, mesh: Mesh, scenario=None):
    """DEPRECATED compat shim: returns (init_fn, chunk_fn) jitted over the
    'ranks' mesh — the exact jitted callables ``repro.sim.api.Simulator``
    drives, so the two entry points share one trace and stay bit-identical.
    New code should construct a ``Simulator`` directly."""
    from repro.sim.api import Simulator
    sim = Simulator(cfg, scenario=scenario, mesh=mesh)
    return sim.init_fn, sim.chunk_fn


def lower_sim_step(cfg: BrainConfig, mesh, scenario=None):
    """Dry-run entry: lower one sim chunk on all devices of ``mesh``.
    Routed through ``Simulator.lower()`` so a scenario lowers its own
    trace (stimulus/lesion tables and population parameters included)."""
    from repro.sim.api import Simulator
    bmesh = make_brain_mesh(list(mesh.devices.flat))
    return Simulator(cfg, scenario=scenario, mesh=bmesh).lower()
