"""Morton (Z-order) codes over the unit cube and the rank decomposition.

The simulation domain [0,1]^3 is split at the *branch level* b — the smallest
b with 8^b >= R ranks — into 8^b subdomains indexed by their Morton code.
Each rank owns ``8^b // R`` consecutive subdomains (1, 2 or 4 for power-of-two
R), exactly the decomposition of the paper (§III-B0a).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

MAX_LEVEL = 9  # 2^27 cells max — plenty below float32 position resolution


def branch_level(num_ranks: int) -> int:
    """Smallest b with 8^b >= R (paper: 8^(b-1) <= k < 8^b with k rounded up)."""
    b = 0
    while 8 ** b < num_ranks:
        b += 1
    return max(b, 1) if num_ranks > 1 else 0


def cells_per_rank(num_ranks: int) -> int:
    return 8 ** branch_level(num_ranks) // num_ranks


def _part1by2(x):
    """Spread bits of x so there are two zeros between each (for interleave)."""
    x = x.astype(jnp.uint32)
    x &= jnp.uint32(0x3FF)
    x = (x | (x << 16)) & jnp.uint32(0x030000FF)
    x = (x | (x << 8)) & jnp.uint32(0x0300F00F)
    x = (x | (x << 4)) & jnp.uint32(0x030C30C3)
    x = (x | (x << 2)) & jnp.uint32(0x09249249)
    return x


def _compact1by2(x):
    x = x.astype(jnp.uint32) & jnp.uint32(0x09249249)
    x = (x ^ (x >> 2)) & jnp.uint32(0x030C30C3)
    x = (x ^ (x >> 4)) & jnp.uint32(0x0300F00F)
    x = (x ^ (x >> 8)) & jnp.uint32(0x030000FF)
    x = (x ^ (x >> 16)) & jnp.uint32(0x000003FF)
    return x


def morton_encode(pos, level: int):
    """pos: (..., 3) in [0,1) -> Morton cell index at ``level`` (int32)."""
    g = 1 << level
    ijk = jnp.clip((pos * g).astype(jnp.int32), 0, g - 1)
    code = (_part1by2(ijk[..., 0]) | (_part1by2(ijk[..., 1]) << 1)
            | (_part1by2(ijk[..., 2]) << 2))
    return code.astype(jnp.int32)


def morton_cell_center(cell, level: int):
    """cell index at ``level`` -> center position (..., 3)."""
    c = cell.astype(jnp.uint32)
    i = _compact1by2(c)
    j = _compact1by2(c >> 1)
    k = _compact1by2(c >> 2)
    g = float(1 << level)
    return (jnp.stack([i, j, k], axis=-1).astype(jnp.float32) + 0.5) / g


def cell_size(level: int) -> float:
    """Cell edge length at octree level (cube => single scalar)."""
    return 1.0 / (1 << level)


def sample_positions_in_cells(key, base_cell: int, n_cells: int, n: int,
                              level: int):
    """Uniformly sample n positions within Morton cells
    [base_cell, base_cell + n_cells) at ``level`` (a rank's subdomains)."""
    kc, kp = jax.random.split(key)
    cells = base_cell + jax.random.randint(kc, (n,), 0, n_cells)
    centers = morton_cell_center(cells, level)
    off = (jax.random.uniform(kp, (n, 3)) - 0.5) * cell_size(level)
    return jnp.clip(centers + off, 0.0, 1.0 - 1e-6)
