"""Compat shim — the Barnes-Hut search moved to ``repro.connectome.traverse``
(PR 3: the connectome subsystem owns the whole connectivity update; the
randomness contract changed from fold_in key chains to the counter-based
Threefry hash keyed by (seed, chunk, source_gid, round, draw)). Pruned to
the names still imported (tests/test_brain.py, tests/test_kernels.py) —
new code imports ``repro.connectome.traverse`` directly."""
from repro.connectome.traverse import _gauss, bh_search, stack_levels

__all__ = ["bh_search", "stack_levels"]
