"""Vectorized Barnes-Hut partner search (paper §III-B0c / §IV-A).

The paper's recursive search — collect nodes meeting the acceptance criterion
(cell_size / distance < theta), sample one by connection probability, restart
inside it if it is an inner node — is reformulated level-synchronously for the
TPU: a static-size frontier per searching neuron is expanded in lockstep
(rejected nodes are replaced by their 8 children), then one Gumbel-max sample
selects the target; sampling an inner node restarts the expansion from it.

Static-shape deviations (documented in DESIGN.md §2): the frontier is capped at
F entries — parents whose children would overflow are kept as sampling
candidates at coarser granularity; overflow is counted and reported by tests.

Randomness is a keyed stream: fold_in(key, source_gid, restart_round). Because
the *same* stream is used whether the search continues locally (old algorithm,
after downloading remote subtrees) or on the owning rank (new location-aware
algorithm), both algorithms make bit-identical choices — stronger than the
paper, which only argues qualitative equivalence.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import morton

NEG = -1e30


class StackedTree(NamedTuple):
    """Uniform view of consecutive octree levels for traced indexing.
    counts: (L, C_max); centroids: (L, C_max, 3); sizes: (L,) cell edge len.
    Level k covers absolute octree level (start_level + k); cell indices are
    relative to ``cell_base * 8^k`` (the owning subtree block)."""
    counts: jnp.ndarray
    centroids: jnp.ndarray
    sizes: jnp.ndarray
    start_level: int


def stack_levels(counts_tuple, cents_tuple, start_level: int) -> StackedTree:
    lmax = max(c.shape[0] for c in counts_tuple)
    cs, zs = [], []
    for c, z in zip(counts_tuple, cents_tuple):
        pad = lmax - c.shape[0]
        cs.append(jnp.pad(c, (0, pad)))
        zs.append(jnp.pad(z, ((0, pad), (0, 0))))
    sizes = jnp.asarray([morton.cell_size(start_level + k)
                         for k in range(len(counts_tuple))], jnp.float32)
    return StackedTree(jnp.stack(cs), jnp.stack(zs), sizes, start_level)


def _gauss(d2, sigma: float):
    return jnp.exp(-d2 / (sigma * sigma))


def _node_stats(tree: StackedTree, lvl_rel, cell, x, sigma):
    """Vectorized gather of (count, prob-weight, size/dist) for entries.
    lvl_rel, cell: (...,) int; x: (..., 3)."""
    cnt = tree.counts[lvl_rel, cell]
    cent = tree.centroids[lvl_rel, cell]
    center = cent / jnp.maximum(cnt, 1e-9)[..., None]
    d2 = jnp.sum(jnp.square(x - center), axis=-1)
    size = tree.sizes[lvl_rel]
    crit = size / jnp.sqrt(jnp.maximum(d2, 1e-12))
    prob = cnt * _gauss(d2, sigma)
    return cnt, prob, crit


def expand_and_sample(tree: StackedTree, x, root_cell, root_rel, key,
                      *, theta: float, sigma: float, frontier: int,
                      n_levels: int):
    """One paper 'round': expand from the root node until every frontier entry
    meets the acceptance criterion (or is a deepest-level cell), then sample.

    x: (Q, 3); root_cell/root_rel: (Q,) current node (relative level index).
    Returns (cell, rel_level, valid, overflowed): all (Q,).
    """
    q = x.shape[0]
    f = frontier
    last = n_levels - 1

    # init: children of root (or root itself if already deepest)
    at_leaf = root_rel >= last
    child_rel = jnp.where(at_leaf, root_rel, root_rel + 1)
    base8 = jnp.where(at_leaf, root_cell, root_cell * 8)
    cells0 = jnp.full((q, f), 0, jnp.int32)
    lvls0 = jnp.full((q, f), 0, jnp.int32)
    valid0 = jnp.zeros((q, f), bool)
    js = jnp.arange(8)
    cells0 = cells0.at[:, :8].set(base8[:, None] + jnp.where(
        at_leaf[:, None], 0, js[None, :]))
    lvls0 = lvls0.at[:, :8].set(child_rel[:, None])
    valid0 = valid0.at[:, :8].set(jnp.where(at_leaf[:, None], js[None] == 0,
                                            True))
    overflow0 = jnp.zeros((q,), bool)

    def round_fn(state, _):
        cells, lvls, valid, overflow = state
        cnt, prob, crit = _node_stats(tree, lvls, cells, x[:, None, :], sigma)
        nonempty = cnt > 1e-9
        accepted = (crit < theta) | (lvls >= last)
        expand = valid & nonempty & ~accepted
        keepers = valid & ~expand & nonempty
        need = jnp.where(expand, 8, jnp.where(keepers, 1, 0))
        off = jnp.cumsum(need, axis=1) - need
        fits = (off + need) <= f
        # pass 2: overflowing expanders retained as coarse candidates
        need2 = jnp.where(expand & fits, 8, jnp.where(
            (keepers | (expand & ~fits)), 1, 0))
        off2 = jnp.cumsum(need2, axis=1) - need2
        fits2 = (off2 + need2) <= f
        ncells = jnp.zeros((q, f), jnp.int32)
        nlvls = jnp.zeros((q, f), jnp.int32)
        nvalid = jnp.zeros((q, f), bool)
        qi = jnp.arange(q)[:, None]
        # singles
        single = (need2 == 1) & fits2
        tgt = jnp.where(single, off2, f)
        ncells = ncells.at[qi, tgt].set(cells, mode="drop")
        nlvls = nlvls.at[qi, tgt].set(lvls, mode="drop")
        nvalid = nvalid.at[qi, tgt].set(single, mode="drop")
        # expansions
        exp8 = (need2 == 8) & fits2
        qij = jnp.arange(q)[:, None, None]
        tgt8 = jnp.where(exp8[..., None], off2[..., None] + js, f)
        ncells = ncells.at[qij, tgt8].set(cells[..., None] * 8 + js,
                                          mode="drop")
        nlvls = nlvls.at[qij, tgt8].set((lvls + 1)[..., None]
                                        * jnp.ones_like(js), mode="drop")
        nvalid = nvalid.at[qij, tgt8].set(exp8[..., None] & jnp.ones_like(
            js, bool), mode="drop")
        overflow = overflow | jnp.any(expand & ~fits2, axis=1)
        return (ncells, nlvls, nvalid, overflow), None

    state = (cells0, lvls0, valid0, overflow0)
    state, _ = jax.lax.scan(round_fn, state, None, length=n_levels)
    cells, lvls, valid, overflow = state

    cnt, prob, _ = _node_stats(tree, lvls, cells, x[:, None, :], sigma)
    logits = jnp.where(valid & (cnt > 1e-9), jnp.log(jnp.maximum(prob, 1e-30)),
                       NEG)
    g = jax.vmap(lambda k: jax.random.gumbel(k, (f,)))(key)  # per-query keys
    pick = jnp.argmax(logits + g, axis=1)
    qi = jnp.arange(q)
    any_valid = jnp.any(logits > NEG / 2, axis=1)
    return (cells[qi, pick], lvls[qi, pick], any_valid, overflow)


def bh_search(tree: StackedTree, x, keys, start_cell, *, theta, sigma,
              frontier, n_levels, max_restarts=None):
    """Full search: expand/sample, restarting inside sampled inner nodes until
    a deepest-level cell is returned (paper's 'process restarts' loop).

    x: (Q,3); keys: (Q,) PRNG keys; start_cell: (Q,) cell at tree level 0.
    Returns (leaf_cell (Q,), valid (Q,), overflow (Q,))."""
    q = x.shape[0]
    last = n_levels - 1
    restarts = max_restarts or n_levels

    def body(i, st):
        cell, rel, valid, done, overflow = st
        kk = jax.vmap(lambda k: jax.random.fold_in(k, i))(keys)
        ncell, nrel, nvalid, noverf = expand_and_sample(
            tree, x, cell, rel, kk, theta=theta,
            sigma=sigma, frontier=frontier, n_levels=n_levels)
        # keep previous result where already done
        cell = jnp.where(done, cell, ncell)
        rel = jnp.where(done, rel, nrel)
        valid = jnp.where(done, valid, nvalid)
        overflow = overflow | jnp.where(done, False, noverf)
        done = done | (rel >= last) | ~valid
        return (cell, rel, valid, done, overflow)

    st = (start_cell.astype(jnp.int32), jnp.zeros((q,), jnp.int32),
          jnp.ones((q,), bool), jnp.zeros((q,), bool), jnp.zeros((q,), bool))
    cell, rel, valid, done, overflow = jax.lax.fori_loop(0, restarts, body, st)
    valid = valid & (rel >= last)
    return cell, valid, overflow


def select_member(key, x, member_pos, member_weight, member_valid, sigma):
    """Pick an actual neuron within the chosen leaf cell, kernel-weighted
    (paper: 'the new partner must be a genuine neuron').
    member_*: (Q, M, ...). Returns (idx (Q,), valid (Q,))."""
    d2 = jnp.sum(jnp.square(x[:, None, :] - member_pos), axis=-1)
    w = member_weight * _gauss(d2, sigma)
    logits = jnp.where(member_valid & (w > 1e-12),
                       jnp.log(jnp.maximum(w, 1e-30)), NEG)
    m = logits.shape[1]
    g = jax.vmap(lambda k: jax.random.gumbel(k, (m,)))(key)  # per-query keys
    pick = jnp.argmax(logits + g, axis=1)
    valid = jnp.any(logits > NEG / 2, axis=1)
    return pick, valid
