"""Compat shim — the Barnes-Hut search moved to ``repro.connectome.traverse``
(PR 3: the connectome subsystem owns the whole connectivity update; the
randomness contract changed from fold_in key chains to the counter-based
Threefry hash keyed by (seed, chunk, source_gid, round, draw)). This module
re-exports the public surface so existing imports keep working."""
from repro.connectome.traverse import (NEG, StackedTree, _gauss, bh_search,
                                       expand_and_sample, pairwise_d2,
                                       phase_a, phase_b, phase_b_core,
                                       select_member, stack_levels)

__all__ = ["NEG", "StackedTree", "bh_search", "expand_and_sample",
           "pairwise_d2", "phase_a", "phase_b", "phase_b_core",
           "select_member", "stack_levels"]
