"""Compat shim — the connectivity update moved to ``repro.connectome`` (PR 3):
synapse-table ops in ``connectome.synapses``, the phase-A/B search in
``connectome.traverse``, request routing in ``connectome.routing``, and the
per-chunk orchestration in ``connectome.update``. Pruned to the names still
imported (tests/test_brain.py) — new code imports the ``repro.connectome``
modules directly."""
from repro.connectome.synapses import (accept_requests, compact,
                                       remove_edges_by_messages,
                                       retract_synapses)

__all__ = ["accept_requests", "compact", "remove_edges_by_messages",
           "retract_synapses"]
