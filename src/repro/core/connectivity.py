"""Compat shim — the connectivity update moved to ``repro.connectome`` (PR 3):
synapse-table ops in ``connectome.synapses``, the phase-A/B search in
``connectome.traverse``, request routing in ``connectome.routing``, and the
per-chunk orchestration in ``connectome.update``. This module re-exports the
public surface so existing imports keep working."""
from repro.connectome.routing import (cap_deletions, cap_requests,
                                      formation_new, formation_old,
                                      route_deletions)
from repro.connectome.synapses import (SynapseTable, accept_requests,
                                       add_out_edges, compact, counts,
                                       edge_priority, init_synapses,
                                       remove_edges_by_messages,
                                       retract_synapses)
from repro.connectome.traverse import phase_a, phase_b, phase_b_core

__all__ = ["SynapseTable", "accept_requests", "add_out_edges",
           "cap_deletions", "cap_requests", "compact", "counts",
           "edge_priority", "formation_new", "formation_old",
           "init_synapses", "phase_a", "phase_b", "phase_b_core",
           "remove_edges_by_messages", "retract_synapses",
           "route_deletions"]
