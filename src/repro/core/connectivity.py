"""MSP phase 3: connectivity update — the paper's core contribution.

Both algorithms share phase A (search the replicated upper tree down to the
branch level). They differ in phase B exactly as the paper describes (§IV-A):

OLD ("move data"): the searching rank downloads the remote subtrees (modeled
as the all-gather of every rank's local tree + leaf neuron data — the
cache-everything endpoint of the paper's RMA+cache scheme) and finishes the
search locally. Then a plain formation request (source id, target id, type:
17 B in the paper) is all-to-all exchanged for accept/decline.

NEW ("move compute", location-aware): the searching rank ships a
formation-AND-calculation request — source id, source position, target node,
node kind, cell type: 42 B — to the rank owning the branch cell; that rank
finishes the search against its own subtree (zero additional communication)
and answers with (found id, success): 9 B.

Both use the same keyed PRNG stream (source gid, restart round), so they form
bit-identical synapses — tested in tests/test_brain_equivalence.py.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.msp_brain import BrainConfig
from repro.core import barnes_hut as bh
from repro.core import morton, octree


class SynapseTable(NamedTuple):
    out_edges: jnp.ndarray   # (n, S_max) target gids, -1 empty
    in_edges: jnp.ndarray    # (n, S_max) source gids, -1 empty


def init_synapses(n: int, s_max: int) -> SynapseTable:
    e = jnp.full((n, s_max), -1, jnp.int32)
    return SynapseTable(e, e)


def counts(edges):
    return jnp.sum(edges >= 0, axis=1)


# ---------------------------------------------------------------- phase A
def phase_a(top: octree.TopTree, pos, keys, cfg: BrainConfig, num_ranks: int):
    """Search the replicated tree down to the branch level. pos: (Q,3).
    Returns (branch_cell (Q,), valid (Q,))."""
    b = morton.branch_level(num_ranks)
    if b == 0:
        q = pos.shape[0]
        return jnp.zeros((q,), jnp.int32), jnp.ones((q,), bool)
    tree = bh.stack_levels(top.counts, top.centroids, 0)
    cell, valid, _ = bh.bh_search(
        tree, pos, keys, jnp.zeros((pos.shape[0],), jnp.int32),
        theta=cfg.theta, sigma=cfg.sigma, frontier=cfg.frontier_cap,
        n_levels=b + 1)
    return cell, valid


# ---------------------------------------------------------------- phase B
def phase_b(local: octree.LocalTree, neuron_pos, vacant_d, pos, keys,
            start_cell_rel, valid_in, cfg: BrainConfig, num_ranks: int,
            gid_base, src_gid):
    """Finish the search inside one rank's subtree. start_cell_rel: (Q,) cell
    index relative to this rank's branch cells. Returns (target_gid (Q,),
    valid (Q,))."""
    tree = bh.stack_levels(local.counts, local.centroids,
                           morton.branch_level(num_ranks))
    leaf_cell, valid, _ = bh.bh_search(
        tree, pos, keys, start_cell_rel, theta=cfg.theta, sigma=cfg.sigma,
        frontier=cfg.frontier_cap, n_levels=cfg.local_levels + 1)
    valid = valid & valid_in
    members = local.leaf_members[leaf_cell]            # (Q, M) local ids
    mvalid = members >= 0
    msafe = jnp.where(mvalid, members, 0)
    mgid = gid_base + msafe
    # exclude self-connection (a neuron never proposes to itself)
    mvalid = mvalid & (mgid != src_gid[:, None])
    mpos = neuron_pos[msafe]
    mw = jnp.where(mvalid, vacant_d[msafe], 0.0)
    kk = jax.vmap(lambda k: jax.random.fold_in(k, 1000))(keys)
    pick, pvalid = bh.select_member(kk, pos, mpos, mw, mvalid, cfg.sigma)
    tgt_local = jnp.take_along_axis(msafe, pick[:, None], axis=1)[:, 0]
    tgt_gid = gid_base + tgt_local
    return jnp.where(valid & pvalid, tgt_gid, -1), valid & pvalid


# ---------------------------------------------------------------- accept
def compact(edges):
    """Push occupied slots to the front of each row (stable)."""
    n, s_max = edges.shape
    key = jnp.where(edges >= 0, jnp.arange(s_max)[None, :], s_max * 2)
    order = jnp.argsort(key, axis=1)
    return jnp.take_along_axis(edges, order, axis=1)


def edge_priority(key, a_gid, b_gid):
    """Deterministic per-(a,b) uniform — independent of buffer ordering, so
    the old and new algorithms make identical accept/decline choices no
    matter how requests were routed."""
    k = jax.vmap(lambda a, b: jax.random.fold_in(jax.random.fold_in(key, a),
                                                 b))(a_gid, b_gid)
    return jax.vmap(lambda kk: jax.random.uniform(kk))(k)


def accept_requests(tgt_lid, src_gid, valid, vacant_d, in_edges, key):
    """Targets accept as many requests as they have vacant dendritic elements
    (random subset — paper §III-A(c)); accepted requests are written into
    in_edges (assumed compacted). Returns (accept (Q,) bool, new in_edges)."""
    n, s_max = in_edges.shape
    q = tgt_lid.shape[0]
    lid = jnp.where(valid, tgt_lid, n)                  # bucket n = invalid
    # acceptance rank within each target by keyed (src,tgt) priority —
    # ordering-independent (paper: 'accept ... randomly')
    prio = edge_priority(key, jnp.where(valid, src_gid, 0),
                         jnp.where(valid, lid, 0))
    order = jnp.lexsort((prio, lid))
    rank_p = octree.positions_within(lid[order], n + 1)
    rank_in_tgt = jnp.zeros((q,), jnp.int32).at[order].set(rank_p)
    lid_c = jnp.clip(lid, 0, n - 1)
    base = counts(in_edges)
    free = s_max - base
    cap = jnp.minimum(jnp.floor(jnp.where(valid, vacant_d[lid_c], 0.0)),
                      free[lid_c].astype(jnp.float32))
    accept = valid & (rank_in_tgt < cap)
    slot = jnp.where(accept, base[lid_c] + rank_in_tgt, s_max)
    new_in = in_edges.at[lid_c, jnp.clip(slot, 0, s_max)].set(
        jnp.where(accept, src_gid, in_edges[lid_c, jnp.clip(slot, 0, s_max - 1)]),
        mode="drop")
    return accept, new_in


def add_out_edges(out_edges, tgt_gid, accept):
    """Write accepted targets into the source neurons' out-edge tables.
    tgt_gid/accept: (n_sources,) — one pending request per source neuron."""
    n, s_max = out_edges.shape
    base = counts(out_edges)
    slot = jnp.where(accept & (base < s_max), base, s_max)
    return out_edges.at[jnp.arange(n), slot].set(
        jnp.where(accept, tgt_gid, -1), mode="drop")


# ---------------------------------------------------------------- deletion
def retract_synapses(key, edges, n_delete, row_gids):
    """Randomly break ``n_delete[i]`` bound synapses of neuron i (paper: 'one
    is chosen randomly'). Priority is keyed by (row gid, edge gid) so the
    choice is independent of slot ordering. Returns (new_edges, kill mask)."""
    n, s_max = edges.shape
    occupied = edges >= 0
    flat_prio = edge_priority(
        key, jnp.broadcast_to(row_gids[:, None], edges.shape).reshape(-1),
        jnp.where(occupied, edges, 0).reshape(-1))
    prio = jnp.where(occupied, flat_prio.reshape(edges.shape), 2.0)
    order = jnp.argsort(prio, axis=1)                   # occupied first, random
    ranks = jnp.zeros_like(edges).at[
        jnp.arange(n)[:, None], order].set(jnp.arange(s_max)[None, :])
    kill = occupied & (ranks < n_delete[:, None])
    return jnp.where(kill, -1, edges), kill


def remove_edges_by_messages(edges, msg_lid, msg_gid, msg_valid):
    """Remove the first slot equal to msg_gid from row msg_lid, sequentially
    (messages may target the same row)."""
    def body(i, e):
        lid = msg_lid[i]
        gid = msg_gid[i]
        row = e[lid]
        hit = row == gid
        first = jnp.argmax(hit)
        do = msg_valid[i] & jnp.any(hit)
        row = row.at[first].set(jnp.where(do, -1, row[first]))
        return e.at[lid].set(jnp.where(do, row, e[lid]))
    return jax.lax.fori_loop(0, msg_lid.shape[0], body, edges)
