"""Compat shim — the level-array octree moved to ``repro.connectome.tree``
(PR 3: the connectome subsystem owns the whole connectivity update). Pruned
to the name still imported (tests/test_brain.py) — new code imports
``repro.connectome.tree`` directly (``build_tree`` dispatches on
``BrainConfig.tree_impl``)."""
from repro.connectome.tree import build_local_tree

__all__ = ["build_local_tree"]
