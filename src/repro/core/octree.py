"""Compat shim — the level-array octree moved to ``repro.connectome.tree``
(PR 3: the connectome subsystem owns the whole connectivity update). This
module re-exports the public surface so existing imports keep working."""
from repro.connectome.tree import (LocalTree, TopTree, build_local_tree,
                                   build_top_tree, exchange_branch_nodes,
                                   node_center, positions_within)

__all__ = ["LocalTree", "TopTree", "build_local_tree", "build_top_tree",
           "exchange_branch_nodes", "node_center", "positions_within"]
