"""Spike transmission: the paper's OLD per-step spiked-ID exchange with binary
search lookup, vs the NEW Delta-periodic firing-rate exchange with PRNG
reconstruction (paper §IV-B).

Old (every step):  ranks all-exchange the sorted IDs of neurons that fired;
receivers binary-search (searchsorted) each remote in-edge. Padded static
buffers model the variable-length ID lists; the benchmarks count the paper's
8 B/ID alongside the HLO buffer bytes.

New (every Delta): ranks exchange per-neuron rates (4 B each); between
exchanges each receiver draws Bernoulli(rate) per remote edge from a
counter-based hash keyed by ``(seed, step, edge)`` — no per-step
synchronization at all, and (being pure integer math, ``kernels/hash.py``)
the same stream is reproduced bit-for-bit by the fused activity megakernel
and the jnp reference path. Local edges always see true spikes (the paper
applies the approximation only across ranks).

The new algorithm's exchange layout is ``BrainConfig.rate_exchange``:
``dense`` all-gathers every rank's full rate vector into a replicated
``(R, n)`` table; ``sparse`` derives a per-rank *subscription registry*
(``build_subscriptions``: the sorted unique remote source gids of the
in-edge table, plus the edge→slot remap) and owners push only the
subscribed rates (``connectome.routing.push_subscribed_rates``) — O(unique
remote sources) instead of O(R·n), bit-identical because the Bernoulli
stream is keyed by the edge id, independent of where the rate came from
(DESIGN.md §7).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.activity_fused import (local_spike_hits,
                                          reconstruct_remote_spikes)


def exchange_spiked_ids(spiked, rank, n: int, axis_name, num_ranks: int):
    """OLD algorithm, send side. spiked: (n,) bool.
    Returns (ids (R, n) sorted global ids with n as +inf pad, counts (R,))."""
    lid = jnp.arange(n, dtype=jnp.int32)
    gid = rank * n + lid
    # sorted spiked ids, padded with INT32_MAX (keeps searchsorted semantics)
    key_sort = jnp.where(spiked, gid, jnp.iinfo(jnp.int32).max)
    ids = jnp.sort(key_sort)
    count = jnp.sum(spiked.astype(jnp.int32))
    if num_ranks == 1:
        return ids[None], count[None]
    all_ids = jax.lax.all_gather(ids, axis_name)        # (R, n)
    all_counts = jax.lax.all_gather(count, axis_name)   # (R,)
    return all_ids, all_counts


def lookup_spikes(all_ids, in_edges, n: int):
    """OLD algorithm, receive side: binary-search each in-edge's source gid in
    the sender rank's sorted spiked-ID list (paper: 'These are sorted, so this
    uses binary search'). Vectorized explicit binary search — O(S log n) per
    neuron, no row materialization.
    in_edges: (n, S) source gids (-1 empty). Returns (n, S) bool."""
    src = in_edges
    valid = src >= 0
    src_rank = jnp.where(valid, src // n, 0)
    n_ids = all_ids.shape[1]
    lo = jnp.zeros(src.shape, jnp.int32)
    hi = jnp.full(src.shape, n_ids, jnp.int32)
    n_iter = int(math.ceil(math.log2(max(n_ids, 2)))) + 1

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        v = all_ids[src_rank, jnp.clip(mid, 0, n_ids - 1)]
        go_right = v < src
        return (jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid))

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    v = all_ids[src_rank, jnp.clip(lo, 0, n_ids - 1)]
    return valid & (v == src)


def exchange_rates(rate, axis_name, num_ranks: int):
    """NEW algorithm, send side (every Delta steps): all-exchange rates."""
    if num_ranks == 1:
        return rate[None]
    return jax.lax.all_gather(rate, axis_name)          # (R, n)


NO_SUB = jnp.iinfo(jnp.int32).max   # registry pad (sorts after every gid)


def build_subscriptions(in_edges, rank, n: int, subs_cap: int):
    """Sparse exchange, receive side: derive this rank's subscription
    registry from its in-edge table.

    Returns ``(subs, rate_slots, overflow)``:

      subs        (subs_cap,) i32 — the sorted unique REMOTE source gids this
                  rank consumes, padded with ``NO_SUB``. Sorted ⇒ owner ranks
                  are contiguous and slot lookup is a binary search;
      rate_slots  (n, S) i32 — per in-edge index into ``subs`` (and into the
                  compact pushed-rate buffer aligned with it); -1 for local,
                  empty, or overflowed edges;
      overflow    f32 scalar — unique remote sources that did not fit
                  ``subs_cap`` (their edges see rate 0 until the registry has
                  room; counted into ``stats['request_overflow']``).

    Pure rank-local compute — subscriptions only change when the connectome
    does, so this runs once per connectivity update (computation moves to
    the data)."""
    src = in_edges.reshape(-1)
    remote = (src >= 0) & ((src // n) != rank)
    s = jnp.sort(jnp.where(remote, src, NO_SUB))
    first = (s != NO_SUB) & jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]])
    uidx = jnp.cumsum(first.astype(jnp.int32)) - 1
    subs = jnp.full((subs_cap,), NO_SUB, jnp.int32)
    subs = subs.at[jnp.where(first, uidx, subs_cap)].set(
        jnp.where(first, s, NO_SUB), mode="drop")
    n_unique = jnp.sum(first.astype(jnp.int32))
    overflow = jnp.maximum(n_unique - subs_cap, 0).astype(jnp.float32)
    # edge -> slot: binary-search each in-edge source in the registry
    slot = jnp.clip(jnp.searchsorted(subs, in_edges).astype(jnp.int32),
                    0, subs_cap - 1)
    found = subs[slot] == in_edges
    rem2 = (in_edges >= 0) & ((in_edges // n) != rank)
    rate_slots = jnp.where(rem2 & found, slot, -1)
    return subs, rate_slots, overflow


def reconstruct_spikes(seed: int, gstep, all_rates, in_edges, rank, n: int,
                       rate_slots=None):
    """NEW algorithm, receive side: Bernoulli(rate) per REMOTE edge, from
    the counter hash keyed by ``(seed, gstep, edge)``; local edges use true
    spikes (caller merges). Thin alias of the kernel-side implementation —
    the fused megakernel and this jnp path are the same code.
    Returns (n, S) bool for remote edges (False on local/empty)."""
    return reconstruct_remote_spikes(seed, gstep, all_rates, in_edges,
                                     rank, n, rate_slots=rate_slots)


def local_spikes(spiked_last, in_edges, rank, n: int):
    """True spikes for same-rank edges ('virtually free' in the paper)."""
    return local_spike_hits(spiked_last, in_edges, rank, n)
